"""NAS search graph: gradient correctness + objective semantics."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, diffusion, model, search_graph

STEPS = 3  # tiny unroll for finite differences


@pytest.fixture(scope="module")
def setup():
    cfg = model.DIT_S
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    # perturb so the zero-init heads produce signal
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
    leaves = [l + 0.05 * jax.random.normal(k, l.shape)
              for l, k in zip(leaves, keys)]
    params = jax.tree_util.tree_unflatten(treedef, leaves)
    x_t = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 3))
    toks = jnp.asarray(np.stack([p.tokens() for p in
                                 data.ALL_PROMPTS[:2]]))
    return cfg, params, x_t, toks


def _loss_fn(cfg, params, **kw):
    defaults = dict(num_steps=STEPS, s_base=7.5, lam_cost=0.02,
                    cost_target=4.0)
    defaults.update(kw)
    return functools.partial(search_graph.search_loss, params=params,
                             cfg=cfg, **defaults)


def test_option_stack_affine_identities():
    ec = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    eu = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    opts = search_graph._options(ec, eu, 7.5)
    assert opts.shape == (5, 2, 8)
    np.testing.assert_allclose(opts[0], eu)
    np.testing.assert_allclose(opts[1], ec)
    np.testing.assert_allclose(opts[3], eu + 7.5 * (ec - eu), rtol=1e-6)


def test_gradient_matches_finite_differences(setup):
    cfg, params, x_t, toks = setup
    loss = _loss_fn(cfg, params)
    alpha = 0.3 * jax.random.normal(jax.random.PRNGKey(3),
                                    (STEPS, search_graph.NUM_OPTIONS))
    gumbel = jnp.zeros_like(alpha)

    def f(a):
        return loss(a, gumbel, x_t, toks)[0]

    grad = jax.grad(f)(alpha)
    eps = 1e-3
    for (i, j) in [(0, 0), (1, 3), (2, 4)]:
        d = jnp.zeros_like(alpha).at[i, j].set(eps)
        fd = (float(f(alpha + d)) - float(f(alpha - d))) / (2 * eps)
        assert abs(fd - float(grad[i, j])) < 5e-3 * max(1.0, abs(fd)), \
            (i, j, fd, float(grad[i, j]))


def test_pure_cfg_alpha_replicates_teacher(setup):
    # alpha concentrated on option 3 (cfg at s_base) → student == teacher.
    cfg, params, x_t, toks = setup
    loss = _loss_fn(cfg, params, lam_cost=0.0)
    alpha = jnp.full((STEPS, 5), -40.0).at[:, 3].set(40.0)
    val, (mse, _) = loss(alpha, jnp.zeros_like(alpha), x_t, toks)
    assert float(mse) < 1e-8, float(mse)


def test_cost_penalty_kicks_in_above_target(setup):
    cfg, params, x_t, toks = setup
    alpha_cheap = jnp.full((STEPS, 5), -40.0).at[:, 1].set(40.0)  # all cond
    alpha_rich = jnp.full((STEPS, 5), -40.0).at[:, 3].set(40.0)   # all cfg
    gum = jnp.zeros_like(alpha_cheap)
    # target below the all-CFG cost (2*STEPS) but above all-cond (STEPS)
    loss = _loss_fn(cfg, params, lam_cost=1.0, cost_target=STEPS + 0.5)
    _, (_, nfe_cheap) = loss(alpha_cheap, gum, x_t, toks)
    _, (_, nfe_rich) = loss(alpha_rich, gum, x_t, toks)
    assert float(nfe_cheap) == pytest.approx(STEPS, abs=1e-3)
    assert float(nfe_rich) == pytest.approx(2 * STEPS, abs=1e-3)


def test_soft_nfe_grad_pushes_toward_cheap_options(setup):
    cfg, params, x_t, toks = setup
    loss = _loss_fn(cfg, params, lam_cost=10.0, cost_target=0.0)
    alpha = jnp.zeros((STEPS, 5))
    grad = jax.grad(lambda a: loss(a, jnp.zeros_like(a), x_t, toks)[0])(alpha)
    # cost gradient must favor (make more positive) the expensive options.
    assert float(grad[:, 3].mean()) > float(grad[:, 1].mean())


def test_build_search_fn_outputs(setup):
    cfg, params, x_t, toks = setup
    fn = search_graph.build_search_fn(params, cfg, num_steps=STEPS,
                                      cost_target=4.0)
    alpha = jnp.zeros((STEPS, 5))
    loss, grad, mse, nfe = jax.jit(fn)(alpha, alpha, x_t, toks)
    assert grad.shape == (STEPS, 5)
    assert np.isfinite(float(loss)) and np.isfinite(float(mse))
    assert 0.0 < float(nfe) <= 2 * STEPS + 1e-3
