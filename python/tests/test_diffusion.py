"""Schedule math + reference-sampler correctness (L2 oracles)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import diffusion
from compile.kernels import ref


def test_alpha_sigma_vp_identity():
    for t in np.linspace(0.0, 1.0, 33):
        a, s = diffusion.alpha_sigma(float(t))
        assert abs(a * a + s * s - 1.0) < 1e-9


def test_alpha_bar_monotone_decreasing():
    ts = np.linspace(0.0, 1.0, 101)
    ab = [diffusion.alpha_bar(float(t)) for t in ts]
    assert all(x >= y - 1e-12 for x, y in zip(ab, ab[1:]))
    assert abs(ab[0] - 1.0) < 1e-9
    assert ab[-1] < 1e-3


def test_timesteps_grid():
    ts = diffusion.timesteps(20)
    assert len(ts) == 21
    assert ts[0] == diffusion.T_MAX and ts[-1] == diffusion.T_MIN
    assert np.all(np.diff(ts) < 0)


def test_fold_coefs_euler_has_no_prev_term():
    ts = diffusion.timesteps(20)
    c = diffusion.fold_coefs(ts[0], ts[1], None)
    assert c[2] == 0.0


def test_fold_coefs_x0_row_is_data_prediction():
    # j_x * x + j_eps * eps must equal (x - sigma*eps)/alpha.
    t = 0.6
    a, s = diffusion.alpha_sigma(t)
    c = diffusion.fold_coefs(t, 0.55, 0.65)
    assert abs(c[3] - 1.0 / a) < 1e-12
    assert abs(c[4] + s / a) < 1e-12


def test_coef_table_shape_and_first_step():
    table = diffusion.coef_table(20)
    assert table.shape == (20, 5)
    assert table[0, 2] == 0.0           # first step is Euler
    assert np.all(table[1:, 2] != 0.0)  # all others use 2M history


@settings(max_examples=8, deadline=None)
@given(steps=st.integers(5, 40))
def test_coef_table_any_step_count(steps):
    table = diffusion.coef_table(steps)
    assert table.shape == (steps, 5)
    assert np.all(np.isfinite(table))


# ---------------------------------------------------------------------------
# Solver accuracy on an analytic model.
#
# For x0 ~ N(0, I) the exact posterior score gives eps(x, t) = sigma_t * x
# (VP, alpha^2 + sigma^2 = 1). The probability-flow ODE then has a closed
# form: along the trajectory, x(t) = alpha(t) * z for the data sample z it
# converges to — i.e. the x0-prediction is constant. DPM++(2M) must track
# a high-resolution Euler solution of the same ODE.
# ---------------------------------------------------------------------------

def _analytic_eps(x, t, tokens):
    _, s = diffusion.alpha_sigma(t)
    return s[:, None, None, None] * x


def _run_solver(x_init, num_steps):
    b = x_init.shape[0]
    ts = diffusion.timesteps(num_steps)
    x = x_init.reshape(b, -1)
    x0_prev = jnp.zeros_like(x)
    for i in range(num_steps):
        tv = jnp.full((b,), float(ts[i]))
        eps = _analytic_eps(x.reshape(x_init.shape), tv, None).reshape(b, -1)
        coefs = jnp.tile(jnp.asarray(
            diffusion.fold_coefs(ts[i], ts[i + 1], ts[i - 1] if i else None),
            jnp.float32)[None], (b, 1))
        x, x0_prev = ref.dpmpp_step(x, eps, x0_prev, coefs)
    return np.asarray(x), np.asarray(x0_prev)


def test_dpmpp_matches_fine_euler_on_analytic_model():
    key = jax.random.PRNGKey(0)
    x_init = jax.random.normal(key, (4, 4, 4, 3))
    x20, _ = _run_solver(x_init, 20)
    x400, _ = _run_solver(x_init, 400)
    # 2nd-order 20-step must land close to the near-exact 400-step solution.
    err = np.abs(x20 - x400).max() / np.abs(x400).max()
    assert err < 1e-2, err


def test_dpmpp_convergence_order():
    key = jax.random.PRNGKey(1)
    x_init = jax.random.normal(key, (2, 4, 4, 3))
    ref_x, _ = _run_solver(x_init, 800)
    e10 = np.abs(_run_solver(x_init, 10)[0] - ref_x).max()
    e20 = np.abs(_run_solver(x_init, 20)[0] - ref_x).max()
    # second-order: halving h should cut error by ~4 (allow slack ≥ 2.5)
    assert e10 / max(e20, 1e-12) > 2.5, (e10, e20)


# ---------------------------------------------------------------------------
# Reference sampler semantics (the oracle the Rust engine is tested against)
# ---------------------------------------------------------------------------

def _toy_eps(x, t, tokens):
    """Conditional toy model: condition shifts the score by a fixed direction."""
    _, s = diffusion.alpha_sigma(t)
    shift = jnp.where(tokens[:, 0] > 0, 0.3, 0.0)  # cond vs null
    return s[:, None, None, None] * x + shift[:, None, None, None]


def _sample(gamma_bar, **kw):
    key = jax.random.PRNGKey(2)
    x_t = jax.random.normal(key, (3, 4, 4, 3))
    toks = jnp.ones((3, 4), jnp.int32)
    un = jnp.zeros((3, 4), jnp.int32)
    return diffusion.sample(_toy_eps, x_t, toks, un, num_steps=10,
                            guidance=4.0, gamma_bar=gamma_bar, **kw)


def test_sampler_cfg_nfe_accounting():
    res = _sample(gamma_bar=1.1)  # never truncates
    assert res.nfes == 3 * 10 * 2
    assert res.cfg_steps == 10


def test_sampler_cond_only_nfe_accounting():
    res = _sample(gamma_bar=1.1, cond_only=True)
    assert res.nfes == 3 * 10


def test_sampler_ag_truncation_saves_nfes_and_preserves_prefix():
    full = _sample(gamma_bar=1.1)
    ag = _sample(gamma_bar=0.0)  # truncates after the very first CFG step
    assert ag.nfes < full.nfes
    # AG trajectory must equal CFG's up to (and including) the first step.
    assert np.allclose(ag.gammas[0], full.gammas[0])


def test_sampler_ag_equals_cfg_when_threshold_unreachable():
    a = _sample(gamma_bar=1.1)
    b = _sample(gamma_bar=2.0)
    np.testing.assert_allclose(a.image, b.image, rtol=1e-6)
