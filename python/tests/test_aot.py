"""AOT path: HLO-text lowering sanity (the interchange contract with Rust)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, diffusion, model


def test_to_hlo_text_basic():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_lower_guide_contains_both_outputs():
    text = aot.to_hlo_text(aot.lower_guide(2))
    assert "ENTRY" in text
    # tuple of (eps_cfg (2,768), gamma (2,))
    assert "f32[2,768]" in text and "f32[2]" in text


def test_lower_solver_shapes():
    text = aot.to_hlo_text(aot.lower_solver(4))
    assert "f32[4,768]" in text and "f32[4,5]" in text


def test_lower_denoiser_tiny():
    cfg = model.DIT_S
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    text = aot.to_hlo_text(aot.lower_denoiser(params, cfg, 1))
    assert "ENTRY" in text
    assert "f32[1,16,16,3]" in text
    assert "s32[1,4]" in text


def test_manifest_schedule_parity_table():
    m = aot.build_manifest({}, {})
    ts = m["schedule"]["timesteps_20"]
    assert len(ts) == 21
    table = m["schedule"]["coefs_20"]
    assert len(table) == 20 and len(table[0]) == 5
    want = diffusion.coef_table(20)
    np.testing.assert_allclose(np.asarray(table), want, rtol=1e-12)
    # manifest must be JSON-serializable as-is
    json.dumps(m)


def test_manifest_vocab_matches_data():
    m = aot.build_manifest({}, {})
    assert m["vocab"]["shapes"] == data.SHAPES
    assert m["vocab"]["colors"] == data.COLORS
    assert m["flat_dim"] == 768


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_built_artifacts_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        m = json.load(f)
    for name, buckets in m["artifacts"]["denoisers"].items():
        for b, fname in buckets.items():
            path = os.path.join(root, fname)
            assert os.path.exists(path), path
            head = open(path).read(4096)
            assert "HloModule" in head
