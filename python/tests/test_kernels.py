"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and guidance strengths / coefficient magnitudes)
so the kernels are pinned to the refs across the whole envelope the
coordinator can request, not just the default model shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (attention as attn_k, cfg_combine as cfg_k,
                             dpmpp as dpmpp_k, modulate as mod_k, ref)

TOL = dict(rtol=2e-4, atol=2e-5)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 4), h=st.integers(1, 4),
       n=st.sampled_from([32, 64, 128]), d=st.sampled_from([8, 12, 16]),
       seed=st.integers(0, 2 ** 16))
def test_attention_matches_ref(b, h, n, d, seed):
    q = _rand(seed, (b, h, n, d))
    k = _rand(seed + 1, (b, h, n, d))
    v = _rand(seed + 2, (b, h, n, d))
    np.testing.assert_allclose(attn_k.attention(q, k, v),
                               ref.attention(q, k, v), **TOL)


def test_attention_block_tiling_exercised():
    # n=64 with BLOCK_Q=32 → 2 query tiles per (b, h); result must still match.
    assert attn_k.BLOCK_Q < 64
    q, k, v = (_rand(i, (2, 4, 64, 16)) for i in range(3))
    np.testing.assert_allclose(attn_k.attention(q, k, v),
                               ref.attention(q, k, v), **TOL)


def test_attention_softmax_rows_convex():
    # identity value → output rows must be convex combinations of v rows.
    q = _rand(0, (1, 1, 32, 8), scale=3.0)
    k = _rand(1, (1, 1, 32, 8), scale=3.0)
    v = jnp.eye(32, 8)[None, None]
    out = np.asarray(attn_k.attention(q, k, v))
    assert out.min() >= -1e-6 and out.max() <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# modulate
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 8), n=st.sampled_from([16, 64]),
       d=st.sampled_from([32, 48, 64]), seed=st.integers(0, 2 ** 16))
def test_modulate_matches_ref(b, n, d, seed):
    x = _rand(seed, (b, n, d))
    sh = _rand(seed + 1, (b, d))
    sc = _rand(seed + 2, (b, d))
    np.testing.assert_allclose(mod_k.modulate(x, sh, sc),
                               ref.modulate(x, sh, sc), **TOL)


def test_modulate_zero_cond_is_identity():
    x = _rand(0, (2, 64, 48))
    z = jnp.zeros((2, 48))
    np.testing.assert_allclose(mod_k.modulate(x, z, z), x, rtol=1e-6)


# ---------------------------------------------------------------------------
# cfg_combine
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 8), m=st.sampled_from([64, 768, 1024]),
       s=st.floats(0.0, 16.0), seed=st.integers(0, 2 ** 16))
def test_cfg_combine_matches_ref(b, m, s, seed):
    ec = _rand(seed, (b, m))
    eu = _rand(seed + 1, (b, m))
    sv = jnp.full((b,), jnp.float32(s))
    got_e, got_g = cfg_k.cfg_combine(ec, eu, sv)
    want_e, want_g = ref.cfg_combine(ec, eu, sv)
    np.testing.assert_allclose(got_e, want_e, **TOL)
    np.testing.assert_allclose(got_g, want_g, **TOL)


def test_cfg_combine_s1_is_conditional():
    ec, eu = _rand(0, (3, 768)), _rand(1, (3, 768))
    out, _ = cfg_k.cfg_combine(ec, eu, jnp.ones((3,)))
    np.testing.assert_allclose(out, ec, rtol=1e-5, atol=1e-6)


def test_cfg_combine_gamma_bounds_and_self_similarity():
    ec = _rand(0, (4, 768))
    out, gamma = cfg_k.cfg_combine(ec, ec, jnp.full((4,), 7.5))
    np.testing.assert_allclose(gamma, 1.0, atol=1e-5)
    np.testing.assert_allclose(out, ec, rtol=1e-4, atol=1e-5)
    _, g2 = cfg_k.cfg_combine(ec, -ec, jnp.full((4,), 7.5))
    np.testing.assert_allclose(g2, -1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# dpmpp solver step
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 8), m=st.sampled_from([64, 768]),
       seed=st.integers(0, 2 ** 16))
def test_dpmpp_matches_ref(b, m, seed):
    x = _rand(seed, (b, m))
    e = _rand(seed + 1, (b, m))
    p = _rand(seed + 2, (b, m))
    c = _rand(seed + 3, (b, 5), scale=2.0)
    got_x, got_0 = dpmpp_k.dpmpp_step(x, e, p, c)
    want_x, want_0 = ref.dpmpp_step(x, e, p, c)
    np.testing.assert_allclose(got_x, want_x, **TOL)
    np.testing.assert_allclose(got_0, want_0, **TOL)


def test_dpmpp_euler_ignores_prev():
    # k_prev = 0 → x0_prev must not affect the update.
    x, e = _rand(0, (2, 768)), _rand(1, (2, 768))
    c = jnp.tile(jnp.asarray([0.9, -0.1, 0.0, 1.1, -0.4])[None], (2, 1))
    a1, _ = dpmpp_k.dpmpp_step(x, e, jnp.zeros_like(x), c)
    a2, _ = dpmpp_k.dpmpp_step(x, e, _rand(2, (2, 768)) * 100, c)
    np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# linear (LINEARAG) estimator ref
# ---------------------------------------------------------------------------

def test_linear_uncond_estimate_exact_recovery():
    # If eps_u is exactly a known affine combination, the estimator recovers it.
    hist_c = _rand(0, (3, 64))
    hist_u = _rand(1, (2, 64))
    bc = jnp.asarray([0.2, -0.5, 1.1])
    bu = jnp.asarray([0.7, 0.3])
    target = bc @ hist_c + bu @ hist_u
    got = ref.linear_uncond_estimate(hist_c, hist_u, bc, bu)
    np.testing.assert_allclose(got, target, rtol=1e-5)
