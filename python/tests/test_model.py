"""DiT denoiser: shapes, conditioning semantics, Pallas/ref path parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model


@pytest.fixture(scope="module")
def setup():
    cfg = model.DIT_S
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    rng = np.random.default_rng(0)
    imgs, toks = data.make_batch(rng, 4)
    return cfg, params, jnp.asarray(imgs), jnp.asarray(toks)


def _perturb(params, key, scale=0.05):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    leaves = [l + scale * jax.random.normal(k, l.shape)
              for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def test_forward_shape(setup):
    cfg, params, x, toks = setup
    out = model.forward(params, cfg, x, jnp.full((4,), 0.5), toks)
    assert out.shape == (4, 16, 16, 3)


def test_param_counts():
    ps = model.init_params(jax.random.PRNGKey(0), model.DIT_S)
    pb = model.init_params(jax.random.PRNGKey(0), model.DIT_B)
    assert model.param_count(ps) < model.param_count(pb)
    assert 5e4 < model.param_count(ps) < 2e5
    assert 1e5 < model.param_count(pb) < 5e5


def test_pallas_and_ref_paths_match(setup):
    cfg, params, x, toks = setup
    # zero-init heads make the raw output 0; perturb weights to get signal.
    params = _perturb(params, jax.random.PRNGKey(7))
    t = jnp.full((4,), 0.37)
    a = model.forward(params, cfg, x, t, toks, use_pallas=True)
    b = model.forward(params, cfg, x, t, toks, use_pallas=False)
    assert float(jnp.abs(a).max()) > 1e-3
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_conditioning_changes_output(setup):
    cfg, params, x, toks = setup
    params = _perturb(params, jax.random.PRNGKey(8))
    t = jnp.full((4,), 0.5)
    cond = model.forward(params, cfg, x, t, toks, use_pallas=False)
    uncond = model.forward(params, cfg, x, t, jnp.zeros_like(toks),
                           use_pallas=False)
    assert float(jnp.abs(cond - uncond).max()) > 1e-5


def test_time_changes_output(setup):
    cfg, params, x, toks = setup
    params = _perturb(params, jax.random.PRNGKey(9))
    a = model.forward(params, cfg, x, jnp.full((4,), 0.1), toks,
                      use_pallas=False)
    b = model.forward(params, cfg, x, jnp.full((4,), 0.9), toks,
                      use_pallas=False)
    assert float(jnp.abs(a - b).max()) > 1e-5


def test_batch_independence(setup):
    # sample i's output must not depend on sample j's input.
    cfg, params, x, toks = setup
    params = _perturb(params, jax.random.PRNGKey(10))
    t = jnp.full((4,), 0.5)
    full = model.forward(params, cfg, x, t, toks, use_pallas=False)
    solo = model.forward(params, cfg, x[:1], t[:1], toks[:1],
                         use_pallas=False)
    np.testing.assert_allclose(full[:1], solo, rtol=1e-4, atol=1e-5)


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, params, x, toks = setup
    path = str(tmp_path / "ck.npz")
    model.save_params(path, params)
    loaded = model.load_params(path)
    a = model.forward(params, cfg, x, jnp.full((4,), 0.5), toks,
                      use_pallas=False)
    b = model.forward(loaded, cfg, x, jnp.full((4,), 0.5), toks,
                      use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_edit_model_shapes():
    cfg = model.DIT_EDIT
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    src, instr, tgt = data.make_edit_batch(rng, 2)
    x = jnp.concatenate([jnp.asarray(tgt), jnp.asarray(src)], axis=-1)
    out = model.forward(params, cfg, x, jnp.full((2,), 0.5),
                        jnp.asarray(instr), use_pallas=False)
    assert out.shape == (2, 16, 16, 3)


def test_timestep_embedding_distinguishes_times():
    e1 = model.timestep_embedding(jnp.asarray([0.1]), 64)
    e2 = model.timestep_embedding(jnp.asarray([0.11]), 64)
    assert float(jnp.abs(e1 - e2).max()) > 1e-3
