"""Procedural corpus: rendering + token encoding invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data


def test_prompt_space_size():
    assert len(data.ALL_PROMPTS) == 4 * 5 * 5 * 2


def test_tokens_one_based_with_null_reserved():
    for p in data.ALL_PROMPTS[:20]:
        t = p.tokens()
        assert t.shape == (4,)
        assert np.all(t >= 1)
        for slot, v in enumerate(t):
            assert v < data.VOCAB_SIZES[slot]
    assert np.all(data.NULL_TOKENS == 0)


def test_render_deterministic_without_rng():
    p = data.ALL_PROMPTS[17]
    a = data.render(p)
    b = data.render(p)
    np.testing.assert_array_equal(a, b)


def test_render_range_and_shape():
    for p in data.ALL_PROMPTS[::37]:
        img = data.render(p)
        assert img.shape == (16, 16, 3)
        assert img.min() >= -1.0 and img.max() <= 1.0


def test_render_color_dominates_shape_region():
    # a large red circle at the center: red channel must dominate mid-pixels.
    p = data.Prompt(shape=0, color=0, position=0, size=1)
    img = data.render(p)
    center = img[7:9, 7:9]
    assert center[..., 0].mean() > 0.5          # red high
    assert center[..., 1].mean() < 0.0          # green low (in [-1,1])


def test_render_positions_distinct():
    imgs = [data.render(data.Prompt(0, 0, pos, 1)) for pos in range(5)]
    for i in range(5):
        for j in range(i + 1, 5):
            assert np.abs(imgs[i] - imgs[j]).max() > 0.5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 20), batch=st.integers(1, 16))
def test_make_batch_shapes(seed, batch):
    rng = np.random.default_rng(seed)
    imgs, toks = data.make_batch(rng, batch)
    assert imgs.shape == (batch, 16, 16, 3)
    assert toks.shape == (batch, 4)
    assert imgs.dtype == np.float32 and toks.dtype == np.int32


def test_edit_example_changes_exactly_one_slot():
    rng = np.random.default_rng(3)
    for _ in range(50):
        src, instr, tgt = data.make_edit_example(rng)
        assert src.shape == tgt.shape == (16, 16, 3)
        changed = instr != 0
        assert changed.sum() == 1
        # the instruction token must be a valid (non-null) attribute value
        slot = int(np.argmax(changed))
        assert 1 <= instr[slot] < data.VOCAB_SIZES[slot]


def test_edit_batch_shapes():
    rng = np.random.default_rng(4)
    src, instr, tgt = data.make_edit_batch(rng, 8)
    assert src.shape == tgt.shape == (8, 16, 16, 3)
    assert instr.shape == (8, 4)
