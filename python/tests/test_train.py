"""Training loop: optimizer correctness + short-run convergence smoke."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model, train


def test_adam_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = train.adam_init(params)
    for _ in range(300):
        grads = {"w": 2.0 * params["w"]}
        params, opt = train.adam_update(params, grads, opt, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adam_bias_correction_first_step():
    # first Adam step with unit gradient moves by ~lr regardless of betas.
    params = {"w": jnp.asarray([0.0])}
    opt = train.adam_init(params)
    params, _ = train.adam_update(params, {"w": jnp.asarray([1.0])}, opt, lr=0.1)
    assert abs(float(params["w"][0]) + 0.1) < 1e-6


def test_ddpm_loss_positive_and_finite():
    cfg = model.DIT_S
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    imgs, toks = data.make_batch(rng, 8)
    loss = train.ddpm_loss(params, cfg, jax.random.PRNGKey(1),
                           jnp.asarray(imgs), jnp.asarray(toks))
    assert np.isfinite(float(loss)) and float(loss) > 0.0


def test_edit_loss_positive_and_finite():
    cfg = model.DIT_EDIT
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    src, instr, tgt = data.make_edit_batch(rng, 4)
    loss = train.edit_loss(params, cfg, jax.random.PRNGKey(1),
                           jnp.asarray(src), jnp.asarray(instr),
                           jnp.asarray(tgt))
    assert np.isfinite(float(loss)) and float(loss) > 0.0


@pytest.mark.slow
def test_short_training_reduces_loss():
    params, hist = train.train(model.DIT_S, steps=60, batch=32, log_every=20)
    assert hist[-1][1] < hist[0][1] * 0.5, hist


def test_ckpt_path_layout(tmp_path):
    p = train.ckpt_path(str(tmp_path), "dit_b")
    assert p.endswith("ckpt_dit_b.npz")
