"""Procedural "shapes" corpus: the CC3M/OUI substitute (see DESIGN.md §3).

Renders 16x16 RGB images of colored shapes with compositional text prompts
("a large red circle at the top-left"). The same prompt vocabulary and token
encoding are mirrored in ``rust/src/prompts.rs``; the vocabularies are
exported through ``manifest.json`` so the two sides cannot drift.
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

IMG = 16          # image side
CHANNELS = 3

SHAPES = ["circle", "square", "triangle", "cross"]
COLORS = ["red", "green", "blue", "yellow", "white"]
POSITIONS = ["center", "top-left", "top-right", "bottom-left", "bottom-right"]
SIZES = ["small", "large"]

# token slot layout: [shape, color, position, size]; index 0 in every slot is
# the null (unconditional) token, so real attributes are 1-based.
VOCAB_SIZES = [len(SHAPES) + 1, len(COLORS) + 1, len(POSITIONS) + 1,
               len(SIZES) + 1]
NUM_SLOTS = 4
NULL_TOKENS = np.zeros(NUM_SLOTS, dtype=np.int32)

_RGB = {
    "red": (0.9, 0.15, 0.15),
    "green": (0.15, 0.85, 0.2),
    "blue": (0.2, 0.3, 0.95),
    "yellow": (0.9, 0.85, 0.2),
    "white": (0.95, 0.95, 0.95),
}

_POS_CENTER = {
    "center": (8.0, 8.0),
    "top-left": (4.5, 4.5),
    "top-right": (4.5, 11.5),
    "bottom-left": (11.5, 4.5),
    "bottom-right": (11.5, 11.5),
}


@dataclasses.dataclass(frozen=True)
class Prompt:
    shape: int      # 0-based attribute indices
    color: int
    position: int
    size: int

    def tokens(self) -> np.ndarray:
        """1-based token encoding with 0 reserved for null."""
        return np.array([self.shape + 1, self.color + 1, self.position + 1,
                         self.size + 1], dtype=np.int32)

    def text(self) -> str:
        return (f"a {SIZES[self.size]} {COLORS[self.color]} "
                f"{SHAPES[self.shape]} at the {POSITIONS[self.position]}")


ALL_PROMPTS = [Prompt(s, c, p, z) for s, c, p, z in
               itertools.product(range(len(SHAPES)), range(len(COLORS)),
                                 range(len(POSITIONS)), range(len(SIZES)))]


def _mask(shape: str, cy: float, cx: float, radius: float) -> np.ndarray:
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float64)
    dy, dx = yy - cy, xx - cx
    if shape == "circle":
        d = np.sqrt(dy ** 2 + dx ** 2) - radius
    elif shape == "square":
        d = np.maximum(np.abs(dy), np.abs(dx)) - radius
    elif shape == "triangle":
        # upward triangle: inside if below the two slanted edges and above base
        d = np.maximum.reduce([
            dy - radius,                       # base
            (-dy) * 0.5 + np.abs(dx) - radius  # slanted sides
        ])
    elif shape == "cross":
        bar = radius * 0.45
        d = np.minimum(np.maximum(np.abs(dy) - bar, np.abs(dx) - radius),
                       np.maximum(np.abs(dx) - bar, np.abs(dy) - radius))
    else:
        raise ValueError(shape)
    # soft 1px anti-aliased edge — keeps the data distribution smooth.
    return np.clip(0.5 - d, 0.0, 1.0)


def render(prompt: Prompt, rng: np.random.Generator | None = None) -> np.ndarray:
    """Render one prompt to a ``(16, 16, 3)`` float32 image in [-1, 1].

    With ``rng``, applies the augmentations the corpus is trained with
    (sub-pixel jitter, brightness, background noise) so the model learns a
    distribution rather than a lookup table.
    """
    cy, cx = _POS_CENTER[POSITIONS[prompt.position]]
    radius = 2.4 if SIZES[prompt.size] == "small" else 4.2
    jitter_y = jitter_x = 0.0
    brightness = 1.0
    bg_noise = 0.0
    if rng is not None:
        jitter_y, jitter_x = rng.uniform(-0.75, 0.75, size=2)
        brightness = rng.uniform(0.85, 1.0)
        bg_noise = 1.0
    m = _mask(SHAPES[prompt.shape], cy + jitter_y, cx + jitter_x, radius)
    rgb = np.asarray(_RGB[COLORS[prompt.color]]) * brightness
    img = np.full((IMG, IMG, CHANNELS), 0.08, dtype=np.float64)
    if rng is not None:
        img += rng.normal(0.0, 0.015, size=img.shape) * bg_noise
    img = img * (1.0 - m[..., None]) + rgb[None, None, :] * m[..., None]
    return (img * 2.0 - 1.0).astype(np.float32)


def make_batch(rng: np.random.Generator, batch: int):
    """Sample a training batch: images (B,16,16,3) and tokens (B,4)."""
    idx = rng.integers(0, len(ALL_PROMPTS), size=batch)
    imgs = np.stack([render(ALL_PROMPTS[i], rng) for i in idx])
    toks = np.stack([ALL_PROMPTS[i].tokens() for i in idx])
    return imgs, toks


# --------------------------------------------------------------------------
# Editing task (Appendix B substitute): source image + instruction -> target.
# --------------------------------------------------------------------------

def make_edit_example(rng: np.random.Generator):
    """One editing triple: (source image, instruction tokens, target image).

    The instruction changes exactly one attribute; its token encoding sets
    only the changed slot (other slots null), e.g. "make it blue" ->
    [0, blue, 0, 0].
    """
    src = ALL_PROMPTS[rng.integers(0, len(ALL_PROMPTS))]
    slot = int(rng.integers(0, NUM_SLOTS))
    nvals = [len(SHAPES), len(COLORS), len(POSITIONS), len(SIZES)][slot]
    cur = [src.shape, src.color, src.position, src.size]
    new_val = int(rng.integers(0, nvals - 1))
    if new_val >= cur[slot]:
        new_val += 1  # ensure a real change
    tgt_attrs = list(cur)
    tgt_attrs[slot] = new_val
    tgt = Prompt(*tgt_attrs)
    instr = np.zeros(NUM_SLOTS, dtype=np.int32)
    instr[slot] = new_val + 1
    return render(src, rng), instr, render(tgt, rng)


def make_edit_batch(rng: np.random.Generator, batch: int):
    srcs, instrs, tgts = zip(*(make_edit_example(rng) for _ in range(batch)))
    return np.stack(srcs), np.stack(instrs), np.stack(tgts)
