"""L2: DiT-style conditional denoiser (build-time JAX, lowered to HLO).

A small Diffusion Transformer with adaLN-Zero conditioning on (time,
compositional text tokens). The forward pass routes its hot-spots through the
L1 Pallas kernels (``kernels.attention``, ``kernels.modulate``); everything
lowers into one HLO module per (model, batch-bucket) via ``aot.py``.

Three configs (DESIGN.md §3):
  * ``dit_s``   — the LDM-512 analogue used for the NAS search,
  * ``dit_b``   — the EMU-768 analogue used to show policy generalization,
  * ``dit_edit``— the InstructPix2Pix analogue (image + instruction cond).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .kernels import attention as attn_kernel
from .kernels import modulate as mod_kernel
from .kernels import ref as ref_kernels

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str
    dim: int = 64
    depth: int = 3
    heads: int = 4
    patch: int = 2
    img: int = data.IMG
    in_channels: int = data.CHANNELS    # 6 for the editing model (x || src)
    out_channels: int = data.CHANNELS
    mlp_ratio: int = 4
    vocab_sizes: tuple = tuple(data.VOCAB_SIZES)

    @property
    def tokens(self) -> int:
        return (self.img // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.in_channels * self.patch ** 2

    @property
    def out_patch_dim(self) -> int:
        return self.out_channels * self.patch ** 2

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


DIT_S = DiTConfig(name="dit_s", dim=48, depth=2, heads=4)
DIT_B = DiTConfig(name="dit_b", dim=64, depth=3, heads=4)
DIT_EDIT = DiTConfig(name="dit_edit", dim=64, depth=3, heads=4,
                     in_channels=2 * data.CHANNELS)

CONFIGS = {c.name: c for c in (DIT_S, DIT_B, DIT_EDIT)}


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _linear_init(key, fan_in: int, fan_out: int, zero: bool = False) -> Params:
    if zero:
        w = jnp.zeros((fan_in, fan_out), jnp.float32)
    else:
        lim = 1.0 / math.sqrt(fan_in)
        w = jax.random.uniform(key, (fan_in, fan_out), jnp.float32, -lim, lim)
    return {"w": w, "b": jnp.zeros((fan_out,), jnp.float32)}


def init_params(key: jax.Array, cfg: DiTConfig) -> Params:
    """Initialize all weights (adaLN projections zero-init per DiT)."""
    keys = iter(jax.random.split(key, 64))
    d = cfg.dim
    p: Params = {
        "patch_embed": _linear_init(next(keys), cfg.patch_dim, d),
        "pos_embed": jax.random.normal(next(keys), (cfg.tokens, d)) * 0.02,
        "t_mlp1": _linear_init(next(keys), d, d),
        "t_mlp2": _linear_init(next(keys), d, d),
        "slot_embeds": [
            jax.random.normal(next(keys), (v, d)) * 0.02
            for v in cfg.vocab_sizes
        ],
        "final_adaln": _linear_init(next(keys), d, 2 * d, zero=True),
        "final_out": _linear_init(next(keys), d, cfg.out_patch_dim, zero=True),
        "blocks": [],
    }
    for _ in range(cfg.depth):
        p["blocks"].append({
            "adaln": _linear_init(next(keys), d, 6 * d, zero=True),
            "qkv": _linear_init(next(keys), d, 3 * d),
            "proj": _linear_init(next(keys), d, d),
            "mlp1": _linear_init(next(keys), d, cfg.mlp_ratio * d),
            "mlp2": _linear_init(next(keys), cfg.mlp_ratio * d, d),
        })
    return p


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _linear(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]


def _layernorm(x: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6)


def timestep_embedding(t: jax.Array, dim: int) -> jax.Array:
    """Sinusoidal embedding of continuous t in [0, 1] (scaled by 1000)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    args = t[:, None] * 1000.0 * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def cond_embedding(p: Params, tokens: jax.Array) -> jax.Array:
    """Sum of per-slot embeddings; all-null tokens = the unconditional input."""
    embs = [p["slot_embeds"][i][tokens[:, i]] for i in range(tokens.shape[1])]
    return sum(embs)


def patchify(x: jax.Array, patch: int) -> jax.Array:
    b, h, w, c = x.shape
    gh, gw = h // patch, w // patch
    x = x.reshape(b, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gh * gw, patch * patch * c)


def unpatchify(x: jax.Array, patch: int, img: int, channels: int) -> jax.Array:
    b, n, _ = x.shape
    g = img // patch
    x = x.reshape(b, g, g, patch, patch, channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, img, img, channels)


def _block(bp: Params, x: jax.Array, c: jax.Array, cfg: DiTConfig,
           use_pallas: bool) -> jax.Array:
    attn = attn_kernel.attention if use_pallas else ref_kernels.attention
    mod = mod_kernel.modulate if use_pallas else ref_kernels.modulate
    b, n, d = x.shape
    mods = _linear(bp["adaln"], c)  # (B, 6d)
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mods, 6, axis=-1)
    h = mod(_layernorm(x), sh1, sc1)
    qkv = _linear(bp["qkv"], h).reshape(b, n, 3, cfg.heads, cfg.head_dim)
    qkv = qkv.transpose(2, 0, 3, 1, 4)  # (3, B, H, N, Dh)
    a = attn(qkv[0], qkv[1], qkv[2])
    a = a.transpose(0, 2, 1, 3).reshape(b, n, d)
    x = x + g1[:, None, :] * _linear(bp["proj"], a)
    h = mod(_layernorm(x), sh2, sc2)
    h = _linear(bp["mlp2"], jax.nn.gelu(_linear(bp["mlp1"], h)))
    return x + g2[:, None, :] * h


def forward(p: Params, cfg: DiTConfig, x: jax.Array, t: jax.Array,
            tokens: jax.Array, use_pallas: bool = True) -> jax.Array:
    """Denoiser forward: eps prediction.

    Args:
      x: ``(B, 16, 16, in_channels)`` noisy latent (editing model: ``x || src``).
      t: ``(B,)`` continuous time in [0, 1].
      tokens: ``(B, 4)`` slot tokens (0 = null → unconditional).
      use_pallas: route hot-spots through the L1 Pallas kernels (the AOT /
        inference path). Training passes ``False`` to use the pure-jnp
        oracles instead — Pallas interpret-mode has no reverse-mode autodiff
        — and ``python/tests/test_model.py`` pins the two paths together.

    Returns:
      ``(B, 16, 16, out_channels)`` predicted noise.
    """
    c = _linear(p["t_mlp2"], jax.nn.silu(
        _linear(p["t_mlp1"], timestep_embedding(t, cfg.dim))))
    c = jax.nn.silu(c + cond_embedding(p, tokens))
    h = _linear(p["patch_embed"], patchify(x, cfg.patch)) + p["pos_embed"]
    for bp in p["blocks"]:
        h = _block(bp, h, c, cfg, use_pallas)
    sh, sc = jnp.split(_linear(p["final_adaln"], c), 2, axis=-1)
    mod = mod_kernel.modulate if use_pallas else ref_kernels.modulate
    h = mod(_layernorm(h), sh, sc)
    out = _linear(p["final_out"], h)
    return unpatchify(out, cfg.patch, cfg.img, cfg.out_channels)


def eps_fn(p: Params, cfg: DiTConfig, use_pallas: bool = True):
    """Bind params → the ``EpsFn`` signature used by diffusion.sample."""
    def fn(x, t, tokens):
        return forward(p, cfg, x, t, tokens, use_pallas=use_pallas)
    return fn


def edit_eps_fn(p: Params, cfg: DiTConfig, src: jax.Array):
    """Editing denoiser with a fixed source-image conditioning channel.

    ``src`` of zeros is the image-unconditional input (Eq. 9's ∅ image).
    """
    def fn(x, t, tokens):
        return forward(p, cfg, jnp.concatenate([x, src], axis=-1), t, tokens)
    return fn


def param_count(p: Params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(p))


# ---------------------------------------------------------------------------
# Checkpoint (de)serialization — flat npz with path-encoded keys.
# ---------------------------------------------------------------------------

def save_params(path: str, p: Params) -> None:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}", v)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}", v)
        else:
            flat[prefix] = np.asarray(node)

    rec("p", p)
    np.savez(path, **flat)


def load_params(path: str) -> Params:
    flat = dict(np.load(path))
    root: Params = {}
    for key in sorted(flat):
        parts = key.split("/")[1:]
        node = root
        for i, part in enumerate(parts[:-1]):
            nxt = parts[i + 1]
            default: Any = [] if nxt.isdigit() else {}
            if part.isdigit():
                idx = int(part)
                while len(node) <= idx:
                    node.append(None)
                if node[idx] is None:
                    node[idx] = default
                node = node[idx]
            else:
                node = node.setdefault(part, default)
        last = parts[-1]
        arr = jnp.asarray(flat[key])
        if last.isdigit():
            idx = int(last)
            while len(node) <= idx:
                node.append(None)
            node[idx] = arr
        else:
            node[last] = arr
    return root
