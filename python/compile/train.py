"""Build-time training of the denoiser checkpoints (DDPM eps-objective).

Trains the three DiT configs on the procedural shapes corpus with
classifier-free-guidance dropout (10% of conditionings nulled, per Ho &
Salimans), using a hand-rolled Adam (the image has no optax). Checkpoints are
cached under ``artifacts/``; ``make artifacts`` skips training when they
exist.

Usage::

    python -m compile.train --model dit_s --steps 3000 --out ../artifacts
    python -m compile.train --all --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, diffusion, model

COND_DROPOUT = 0.15


# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": zeros, "step": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "step": step}


# ---------------------------------------------------------------------------
# DDPM loss + train step
# ---------------------------------------------------------------------------

def ddpm_loss(params, cfg, key, x0, tokens):
    """eps-prediction MSE at uniformly sampled t, with CFG dropout."""
    b = x0.shape[0]
    k_t, k_eps, k_drop = jax.random.split(key, 3)
    # mild low-t oversampling (u^1.3): the unconditional head must be
    # accurate late in the trajectory for the paper's gamma_t -> 1
    # convergence to emerge (see DESIGN.md §3).
    t = jax.random.uniform(k_t, (b,), minval=1e-3, maxval=1.0) ** 1.3
    alpha, sigma = diffusion.alpha_sigma(t)
    eps = jax.random.normal(k_eps, x0.shape)
    x_t = alpha[:, None, None, None] * x0 + sigma[:, None, None, None] * eps
    drop = jax.random.bernoulli(k_drop, COND_DROPOUT, (b,))
    toks = jnp.where(drop[:, None], jnp.zeros_like(tokens), tokens)
    pred = model.forward(params, cfg, x_t, t, toks, use_pallas=False)
    return jnp.mean((pred - eps) ** 2)


def edit_loss(params, cfg, key, src, instr, tgt):
    """Editing objective: denoise the target conditioned on (src, instr).

    Independent dropout of the instruction tokens and the source image
    reproduces the InstructPix2Pix conditioning structure that Eq. 9 needs
    (evals at (c, I), (∅, I), (∅, ∅))."""
    b = tgt.shape[0]
    k_t, k_eps, k_di, k_ds = jax.random.split(key, 4)
    t = jax.random.uniform(k_t, (b,), minval=1e-3, maxval=1.0)
    alpha, sigma = diffusion.alpha_sigma(t)
    eps = jax.random.normal(k_eps, tgt.shape)
    x_t = alpha[:, None, None, None] * tgt + sigma[:, None, None, None] * eps
    drop_i = jax.random.bernoulli(k_di, COND_DROPOUT, (b,))
    drop_s = jax.random.bernoulli(k_ds, COND_DROPOUT, (b,))
    toks = jnp.where(drop_i[:, None], jnp.zeros_like(instr), instr)
    src_in = jnp.where(drop_s[:, None, None, None], jnp.zeros_like(src), src)
    pred = model.forward(params, cfg, jnp.concatenate([x_t, src_in], axis=-1),
                         t, toks, use_pallas=False)
    return jnp.mean((pred - eps) ** 2)


def train(cfg: model.DiTConfig, steps: int, batch: int = 64,
          lr: float = 2e-3, seed: int = 0, log_every: int = 200):
    """Train one config with cosine LR decay; returns (params, history)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key, cfg)
    opt = adam_init(params)
    is_edit = cfg.in_channels != data.CHANNELS

    if is_edit:
        @jax.jit
        def step_fn(params, opt, key, lr_t, src, instr, tgt):
            loss, grads = jax.value_and_grad(edit_loss)(
                params, cfg, key, src, instr, tgt)
            params, opt = adam_update(params, grads, opt, lr_t)
            return params, opt, loss
    else:
        @jax.jit
        def step_fn(params, opt, key, lr_t, x0, tokens):
            loss, grads = jax.value_and_grad(ddpm_loss)(
                params, cfg, key, x0, tokens)
            params, opt = adam_update(params, grads, opt, lr_t)
            return params, opt, loss

    history = []
    t0 = time.time()
    import math as _math
    for i in range(steps):
        key, sub = jax.random.split(key)
        # cosine decay to 5% of the base LR
        lr_t = lr * (0.05 + 0.95 * 0.5 *
                     (1.0 + _math.cos(_math.pi * i / max(steps - 1, 1))))
        if is_edit:
            src, instr, tgt = data.make_edit_batch(rng, batch)
            params, opt, loss = step_fn(params, opt, sub, lr_t,
                                        jnp.asarray(src), jnp.asarray(instr),
                                        jnp.asarray(tgt))
        else:
            imgs, toks = data.make_batch(rng, batch)
            params, opt, loss = step_fn(params, opt, sub, lr_t,
                                        jnp.asarray(imgs), jnp.asarray(toks))
        if i % log_every == 0 or i == steps - 1:
            history.append((i, float(loss)))
            print(f"[{cfg.name}] step {i:5d} loss {float(loss):.5f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params, history


def ckpt_path(out_dir: str, name: str) -> str:
    return os.path.join(out_dir, f"ckpt_{name}.npz")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=list(model.CONFIGS), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--steps", type=int, default=3000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    names = list(model.CONFIGS) if args.all else [args.model or "dit_b"]
    os.makedirs(args.out, exist_ok=True)
    for name in names:
        path = ckpt_path(args.out, name)
        if os.path.exists(path) and not args.force:
            print(f"[{name}] checkpoint exists at {path}, skipping")
            continue
        cfg = model.CONFIGS[name]
        params, hist = train(cfg, args.steps, args.batch)
        model.save_params(path, params)
        n = model.param_count(params)
        print(f"[{name}] saved {n} params -> {path}; "
              f"final loss {hist[-1][1]:.5f}")


if __name__ == "__main__":
    main()
