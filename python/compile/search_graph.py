"""L2: the differentiable NAS search graph (paper §4, Eqs. 5-6).

Unrolls the student diffusion process with per-step *soft* guidance choices
and produces ``(loss, grad_alpha, mse, soft_nfe)`` in a single lowered HLO
module, so the Rust coordinator can drive the DARTS-style search with its own
Lion optimizer (``rust/src/search/``) — python stays off the optimization
loop.

Per-step options (paper §4.1, k = 3 guidance strengths):

    index  option                    cost (NFEs)
    0      unconditional eps(x, ∅)   1
    1      conditional   eps(x, c)   1
    2      cfg, s = 0.5 * s_base     2
    3      cfg, s = 1.0 * s_base     2
    4      cfg, s = 2.0 * s_base     2

All five options are affine in the two network evaluations (eps_c, eps_u), so
each unrolled step costs 2 NFEs at *search* time regardless of the soft
weighting — the same trick the paper exploits.

The teacher trajectory (plain CFG at s_base, Eq. 4) is computed inside the
same module under ``stop_gradient``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import diffusion, model

NUM_OPTIONS = 5
OPTION_NAMES = ["uncond", "cond", "cfg_half", "cfg_base", "cfg_double"]
OPTION_COSTS = np.array([1.0, 1.0, 2.0, 2.0, 2.0], dtype=np.float32)
SCALE_FACTORS = [0.5, 1.0, 2.0]


def _flat(x):
    return x.reshape(x.shape[0], -1)


def _options(eps_c, eps_u, s_base):
    """Stack the 5 option scores: (5, B, M); all affine in (eps_c, eps_u)."""
    opts = [eps_u, eps_c]
    for a in SCALE_FACTORS:
        opts.append(eps_u + a * s_base * (eps_c - eps_u))
    return jnp.stack(opts)


def unroll(eps_fn, x_t, tokens, uncond_tokens, num_steps, mix_fn):
    """Unrolled DPM++(2M) trajectory; ``mix_fn(i, eps_c, eps_u) -> eps``.

    Returns the final data prediction x0.
    """
    b = x_t.shape[0]
    shape = x_t.shape
    ts = diffusion.timesteps(num_steps)
    x = _flat(x_t)
    x0_prev = jnp.zeros_like(x)
    for i in range(num_steps):
        tv = jnp.full((b,), float(ts[i]), x.dtype)
        eps_c = _flat(eps_fn(x.reshape(shape), tv, tokens))
        eps_u = _flat(eps_fn(x.reshape(shape), tv, uncond_tokens))
        e = mix_fn(i, eps_c, eps_u)
        c = jnp.asarray(diffusion.fold_coefs(ts[i], ts[i + 1],
                                             ts[i - 1] if i else None),
                        x.dtype)
        x, x0_prev = (c[0] * x + c[1] * e + c[2] * x0_prev,
                      c[3] * x + c[4] * e)
    return x0_prev


def search_loss(alpha, gumbel, x_t, tokens, params, cfg, *, num_steps,
                s_base, lam_cost, cost_target, tau=1.0):
    """Eq. 6: replication distance + Gumbel-softmax NFE-cost penalty.

    Args:
      alpha: ``(num_steps, 5)`` architecture scores.
      gumbel: ``(num_steps, 5)`` pre-sampled Gumbel(0,1) noise (passed in so
        the lowered module is deterministic; Rust supplies it per iteration).
      x_t: ``(B, H, W, C)`` starting noise.
      tokens: ``(B, 4)`` condition tokens.

    Returns:
      ``(loss, (replication_mse, soft_nfe))``.
    """
    uncond = jnp.zeros_like(tokens)
    eps = model.eps_fn(params, cfg, use_pallas=False)

    def student_mix(i, eps_c, eps_u):
        w = jax.nn.softmax(alpha[i])                       # Eq. 5
        return jnp.einsum("o,obm->bm", w, _options(eps_c, eps_u, s_base))

    def teacher_mix(i, eps_c, eps_u):
        return eps_u + s_base * (eps_c - eps_u)            # Eq. 3, f_t = cfg

    x0_student = unroll(eps, x_t, tokens, uncond, num_steps, student_mix)
    x0_teacher = jax.lax.stop_gradient(
        unroll(eps, x_t, tokens, uncond, num_steps, teacher_mix))
    mse = jnp.mean((x0_student - x0_teacher) ** 2)

    # Differentiable NFE proxy: Gumbel-softmax sample of the per-step choice,
    # weighted by per-option cost, ReLU-offset to the target budget.
    gs = jax.nn.softmax((alpha + gumbel) / tau, axis=-1)   # (T, 5)
    soft_nfe = jnp.sum(gs @ jnp.asarray(OPTION_COSTS))
    cost_pen = jax.nn.relu(soft_nfe - cost_target)
    return mse + lam_cost * cost_pen, (mse, soft_nfe)


def build_search_fn(params, cfg, *, num_steps=20, s_base=7.5,
                    lam_cost=0.02, cost_target=30.0):
    """Returns a jittable fn: ``(alpha, gumbel, x_t, tokens) →
    (loss, grad_alpha, mse, soft_nfe)`` — the module AOT'd for Rust."""

    def fn(alpha, gumbel, x_t, tokens):
        (loss, (mse, nfe)), grad = jax.value_and_grad(
            functools.partial(search_loss, num_steps=num_steps,
                              s_base=s_base, lam_cost=lam_cost,
                              cost_target=cost_target),
            has_aux=True)(alpha, gumbel, x_t, tokens, params, cfg)
        return loss, grad, mse, nfe

    return fn
