"""L1 Pallas kernel: fused DPM-Solver++(2M) update.

The solver update is a pure affine combination once the per-step schedule
scalars are folded into five coefficients (see ``ref.dpmpp_step`` for the
algebra and ``python/compile/diffusion.py`` / ``rust/src/coordinator/solver.rs``
for the folding). Fusing it keeps the latent in VMEM for one pass instead of
five elementwise HLO ops, and emits both the next latent and the
data-prediction ``x0`` needed by the 2M history.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def dpmpp_step(x: jax.Array, eps: jax.Array, x0_prev: jax.Array,
               coefs: jax.Array):
    """Fused solver update.

    Args:
      x, eps, x0_prev: ``(B, M)``.
      coefs: ``(B, 5)`` = ``[k_x, k_eps, k_prev, j_x, j_eps]``.

    Returns:
      ``(x_next (B, M), x0 (B, M))``; matches ``ref.dpmpp_step``.
    """
    b, m = x.shape
    # single full block (batched) — see modulate.py for the §Perf rationale.
    grid = (1,)
    vec_spec = pl.BlockSpec((b, m), lambda i: (0, 0))
    coef_spec = pl.BlockSpec((b, 5), lambda i: (0, 0))

    def kernel(x_ref, eps_ref, prev_ref, coef_ref, next_ref, x0_ref):
        xv = x_ref[...]
        ev = eps_ref[...]
        pv = prev_ref[...]
        c = coef_ref[...]
        next_ref[...] = (c[:, 0][:, None] * xv + c[:, 1][:, None] * ev
                         + c[:, 2][:, None] * pv)
        x0_ref[...] = c[:, 3][:, None] * xv + c[:, 4][:, None] * ev

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, vec_spec, coef_spec],
        out_specs=[vec_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), x.dtype),
            jax.ShapeDtypeStruct((b, m), x.dtype),
        ],
        interpret=True,
    )(x, eps, x0_prev, coefs)
