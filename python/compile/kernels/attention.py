"""L1 Pallas kernel: fused multi-head attention.

The denoiser's hot-spot. The kernel is written TPU-idiomatically: the grid
iterates over ``(batch, head, query-block)``; each program instance streams a
``(BLOCK_Q, D)`` query tile plus the full ``(N, D)`` key/value panels through
VMEM and produces its output tile in a single pass (softmax statistics kept in
VMEM, no HBM round-trip for the logits).

On this testbed the kernel is lowered with ``interpret=True`` so it executes
as plain HLO on the CPU PJRT client; on a real TPU the same BlockSpecs map the
HBM→VMEM schedule that a CUDA implementation would express with threadblocks
(see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Query tile. N (token count) is 64 for the 16x16/patch-2 models, so tiles of
# 32 give a 2-deep grid per head: big enough to exercise real tiling, small
# enough that (BLOCK_Q, D) + (N, D)*2 panels stay far below VMEM limits.
BLOCK_Q = 32


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Fused MHA forward. ``q, k, v: (B, H, N, D)`` → ``(B, H, N, D)``.

    Matches ``ref.attention`` to float32 precision.

    §Perf iteration (EXPERIMENTS.md): the grid tiles *queries only*; the
    batch and head dimensions ride inside the block as batched-matmul dims.
    Interpret-mode Pallas lowers grid cells to a sequential loop, so a
    ``(B, H, qb)`` grid serialized every batch element on the CPU backend
    (per-NFE cost *rose* with bucket size). Folding (B, H) into the block
    keeps one grid axis for VMEM tiling while letting XLA vectorize across
    the batch; the VMEM bound stays modest (q tile + K/V panels + logits
    ≈ 1.3 MiB at bucket 16).
    """
    b, h, n, d = q.shape
    block_q = min(BLOCK_Q, n)
    assert n % block_q == 0, f"token count {n} not divisible by {block_q}"
    grid = (n // block_q,)
    q_spec = pl.BlockSpec((b, h, block_q, d), lambda qb: (0, 0, qb, 0))
    kv_spec = pl.BlockSpec((b, h, n, d), lambda qb: (0, 0, 0, 0))

    def kernel(q_ref, k_ref, v_ref, o_ref):
        qv = q_ref[...]  # (b, h, block_q, d)
        kv = k_ref[...]  # (b, h, n, d)
        vv = v_ref[...]
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, qv.dtype))
        logits = jax.lax.dot_general(
            qv, kv,
            dimension_numbers=(((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        ) * scale  # (b, h, block_q, n)
        m = jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp(logits - m)
        denom = jnp.sum(p, axis=-1, keepdims=True)
        out = jax.lax.dot_general(
            p, vv,
            dimension_numbers=(((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        )
        o_ref[...] = (out / denom).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, n, d), q.dtype),
        interpret=True,
    )(q, k, v)
