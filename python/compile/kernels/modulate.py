"""L1 Pallas kernel: fused adaLN modulation.

DiT applies ``x * (1 + scale) + shift`` after every LayerNorm, with the
``(B, D)`` shift/scale vectors produced from the (time, text) conditioning.
Fusing the broadcast + multiply-add into one VMEM pass removes two
materializations of the ``(B, N, D)`` activation per block per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def modulate(x: jax.Array, shift: jax.Array, scale: jax.Array) -> jax.Array:
    """``x * (1 + scale) + shift``; ``x: (B, N, D)``, ``shift/scale: (B, D)``.

    Matches ``ref.modulate`` exactly (same op order).
    """
    b, n, d = x.shape
    # single full-array block: elementwise math vectorizes across the batch
    # (a (b,) grid would serialize under the interpreter — see EXPERIMENTS
    # §Perf); VMEM footprint is b*n*d*4 ≈ 768 KiB at bucket 16 for dit_b.
    grid = (1,)
    x_spec = pl.BlockSpec((b, n, d), lambda i: (0, 0, 0))
    c_spec = pl.BlockSpec((b, d), lambda i: (0, 0))

    def kernel(x_ref, shift_ref, scale_ref, o_ref):
        xv = x_ref[...]          # (b, n, d)
        sh = shift_ref[...]      # (b, d)
        sc = scale_ref[...]
        o_ref[...] = xv * (1.0 + sc[:, None, :]) + sh[:, None, :]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, c_spec, c_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        interpret=True,
    )(x, shift, scale)
