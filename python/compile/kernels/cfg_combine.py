"""L1 Pallas kernel: fused CFG combine + Adaptive-Guidance cosine signal.

This is the paper's own hot-spot: every guided denoising step combines the
conditional and unconditional scores (Eq. 3) *and* — for Adaptive Guidance —
evaluates the convergence signal gamma_t (Eq. 7) that decides whether the
next step still needs the unconditional evaluation. A naive implementation
reads eps_c / eps_u three times (combine, dot product, norms); the fused
kernel does a single HBM→VMEM pass per sample and emits both the guided score
and the scalar gamma.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def cfg_combine(eps_c: jax.Array, eps_u: jax.Array, s: jax.Array):
    """Fused Eq. (3) + Eq. (7).

    Args:
      eps_c, eps_u: ``(B, M)`` flattened score predictions.
      s: ``(B,)`` guidance strengths.

    Returns:
      ``(eps_cfg (B, M), gamma (B,))``; matches ``ref.cfg_combine``.
    """
    b, m = eps_c.shape
    # single full block (batched): one pass over eps_c/eps_u yields both the
    # combined score and the per-sample reduction, vectorized across b.
    grid = (1,)
    vec_spec = pl.BlockSpec((b, m), lambda i: (0, 0))
    sca_spec = pl.BlockSpec((b,), lambda i: (0,))

    def kernel(c_ref, u_ref, s_ref, out_ref, gamma_ref):
        c = c_ref[...]
        u = u_ref[...]
        sv = s_ref[...]
        out_ref[...] = u + sv[:, None] * (c - u)
        num = jnp.sum(c * u, axis=-1)
        den = jnp.sqrt(jnp.sum(c * c, axis=-1)) * jnp.sqrt(jnp.sum(u * u, axis=-1))
        gamma_ref[...] = num / jnp.maximum(den, 1e-12)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, sca_spec],
        out_specs=[vec_spec, sca_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), eps_c.dtype),
            jax.ShapeDtypeStruct((b,), eps_c.dtype),
        ],
        interpret=True,
    )(eps_c, eps_u, s)
