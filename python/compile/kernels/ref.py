"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness references: each kernel in
``attention.py`` / ``modulate.py`` / ``cfg_combine.py`` / ``dpmpp.py`` must
match its oracle here to tight tolerances (see ``python/tests/``), and the
same math is re-implemented on the Rust side where the coordinator needs it
(e.g. LINEARAG's affine combine).
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Multi-head scaled dot-product attention.

    Args:
      q, k, v: ``(B, H, N, D)``.

    Returns:
      ``(B, H, N, D)`` attention output.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("bhnd,bhmd->bhnm", q, k) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhnm,bhmd->bhnd", probs, v)


def modulate(x: jax.Array, shift: jax.Array, scale: jax.Array) -> jax.Array:
    """adaLN modulation: ``x * (1 + scale) + shift`` with per-sample vectors.

    Args:
      x: ``(B, N, D)`` token activations.
      shift, scale: ``(B, D)`` conditioning vectors, broadcast over tokens.
    """
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def cfg_combine(eps_c: jax.Array, eps_u: jax.Array, s: jax.Array):
    """Classifier-free-guidance combine fused with the AG decision signal.

    Implements Eq. (3) and Eq. (7) of the paper in a single pass:

      eps_cfg = eps_u + s * (eps_c - eps_u)
      gamma   = <eps_c, eps_u> / (|eps_c| |eps_u|)

    Args:
      eps_c, eps_u: ``(B, M)`` flattened conditional/unconditional scores.
      s: ``(B,)`` per-request guidance strength.

    Returns:
      ``(eps_cfg (B, M), gamma (B,))``.
    """
    eps_cfg = eps_u + s[:, None] * (eps_c - eps_u)
    num = jnp.sum(eps_c * eps_u, axis=-1)
    den = jnp.linalg.norm(eps_c, axis=-1) * jnp.linalg.norm(eps_u, axis=-1)
    gamma = num / jnp.maximum(den, 1e-12)
    return eps_cfg, gamma


def dpmpp_step(x: jax.Array, eps: jax.Array, x0_prev: jax.Array,
               coefs: jax.Array):
    """DPM-Solver++(2M) update expressed as an affine combination.

    The per-step schedule scalars are folded (by the caller — python
    reference sampler or the Rust coordinator) into five coefficients

      ``coefs = [k_x, k_eps, k_prev, j_x, j_eps]``

    such that

      x_next = k_x * x + k_eps * eps + k_prev * x0_prev
      x0     = j_x * x + j_eps * eps

    The Euler (first) step is the special case ``k_prev == 0``.

    Args:
      x, eps, x0_prev: ``(B, M)``.
      coefs: ``(B, 5)``.

    Returns:
      ``(x_next (B, M), x0 (B, M))``.
    """
    k_x, k_eps, k_prev, j_x, j_eps = (coefs[:, i][:, None] for i in range(5))
    x_next = k_x * x + k_eps * eps + k_prev * x0_prev
    x0 = j_x * x + j_eps * eps
    return x_next, x0


def linear_uncond_estimate(eps_c_hist: jax.Array, eps_u_hist: jax.Array,
                           beta_c: jax.Array, beta_u: jax.Array) -> jax.Array:
    """LINEARAG unconditional-score estimator (Eq. 8).

    Args:
      eps_c_hist: ``(Kc, M)`` conditional scores at steps T..t (most recent last).
      eps_u_hist: ``(Ku, M)`` unconditional scores (true or estimated) at T..t+1.
      beta_c: ``(Kc,)`` scalar regression coefficients.
      beta_u: ``(Ku,)``.

    Returns:
      ``(M,)`` estimate of eps(x_t, null).
    """
    return beta_c @ eps_c_hist + beta_u @ eps_u_hist
