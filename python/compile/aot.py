"""AOT lowering: JAX (L2 + L1) → HLO **text** artifacts + manifest.json.

Emits one self-contained HLO module per (function, batch-bucket) with the
trained weights baked in as constants, so the Rust coordinator is fully
standalone at request time. HLO *text* — not ``.serialize()`` — is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` crate binds) rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts (all under ``artifacts/``):

  denoiser_<model>_b<B>.hlo.txt   (x[B,16,16,C], t[B], tokens[B,4]) -> eps
  guide_b<B>.hlo.txt              (eps_c[B,M], eps_u[B,M], s[B]) -> (eps_cfg, gamma)
  solver_b<B>.hlo.txt             (x, eps, x0_prev[B,M], coefs[B,5]) -> (x_next, x0)
  search_grad.hlo.txt             (alpha, gumbel, x_T, tokens) -> (loss, grad, mse, nfe)
  manifest.json                   everything Rust needs to stay in sync

Run via ``make artifacts`` (trains checkpoints first if missing).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, diffusion, model, search_graph, train
from .kernels import cfg_combine as cfg_kernel
from .kernels import dpmpp as dpmpp_kernel

BUCKETS = [1, 2, 4, 8, 16]
EDIT_BUCKETS = [1, 2, 4]
FLAT_DIM = data.IMG * data.IMG * data.CHANNELS  # 768
SEARCH_STEPS = 20
SEARCH_BATCH = 4
DEFAULT_GUIDANCE = 7.5
DEFAULT_STEPS = 20


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning round trip).

    `as_hlo_text(True)` = print_large_constants: without it the baked model
    weights are elided as ``constant({...})`` and the Rust-side parse fails.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(True)


def _write(out_dir: str, name: str, text: str) -> str:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text) / 1e6:.2f} MB)")
    return name


def lower_denoiser(params, cfg: model.DiTConfig, batch: int):
    """One denoiser executable; weights are closed-over constants."""
    def fn(x, t, tokens):
        return (model.forward(params, cfg, x, t, tokens, use_pallas=True),)

    spec_x = jax.ShapeDtypeStruct((batch, cfg.img, cfg.img, cfg.in_channels),
                                  jnp.float32)
    spec_t = jax.ShapeDtypeStruct((batch,), jnp.float32)
    spec_tok = jax.ShapeDtypeStruct((batch, len(cfg.vocab_sizes)), jnp.int32)
    return jax.jit(fn).lower(spec_x, spec_t, spec_tok)


def lower_guide(batch: int):
    def fn(eps_c, eps_u, s):
        return cfg_kernel.cfg_combine(eps_c, eps_u, s)

    v = jax.ShapeDtypeStruct((batch, FLAT_DIM), jnp.float32)
    s = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return jax.jit(fn).lower(v, v, s)


def lower_solver(batch: int):
    def fn(x, eps, x0_prev, coefs):
        return dpmpp_kernel.dpmpp_step(x, eps, x0_prev, coefs)

    v = jax.ShapeDtypeStruct((batch, FLAT_DIM), jnp.float32)
    c = jax.ShapeDtypeStruct((batch, 5), jnp.float32)
    return jax.jit(fn).lower(v, v, v, c)


def lower_search(params, cfg: model.DiTConfig):
    fn = search_graph.build_search_fn(
        params, cfg, num_steps=SEARCH_STEPS, s_base=DEFAULT_GUIDANCE,
        lam_cost=0.02, cost_target=30.0)
    a = jax.ShapeDtypeStruct((SEARCH_STEPS, search_graph.NUM_OPTIONS),
                             jnp.float32)
    x = jax.ShapeDtypeStruct((SEARCH_BATCH, cfg.img, cfg.img,
                              cfg.in_channels), jnp.float32)
    tok = jax.ShapeDtypeStruct((SEARCH_BATCH, len(cfg.vocab_sizes)),
                               jnp.int32)
    return jax.jit(fn).lower(a, a, x, tok)


def build_parity_fixture(params, cfg: model.DiTConfig) -> dict:
    """Reference values for the Rust integration tests (L2↔L3 parity).

    A deterministic x_T and prompt, the single-eval denoiser output, and two
    full reference trajectories (CFG and AG) from `diffusion.sample` — the
    Rust engine must reproduce the images and gammas within f32 tolerance.
    """
    rng = np.random.default_rng(1234)
    x_init = rng.standard_normal((1, cfg.img, cfg.img, 3)).astype(np.float32)
    tokens = np.array([[1, 2, 3, 1]], dtype=np.int32)
    uncond = np.zeros_like(tokens)
    eps_fn = model.eps_fn(params, cfg, use_pallas=True)

    t_probe = 0.5
    eps_probe = np.asarray(
        eps_fn(jnp.asarray(x_init), jnp.full((1,), t_probe), jnp.asarray(tokens)))

    def run(gamma_bar):
        res = diffusion.sample(eps_fn, jnp.asarray(x_init), jnp.asarray(tokens),
                               jnp.asarray(uncond), num_steps=DEFAULT_STEPS,
                               guidance=DEFAULT_GUIDANCE, gamma_bar=gamma_bar)
        return {
            "image": [float(v) for v in res.image.ravel()],
            "nfes": int(res.nfes),
            "gammas": [float(g) for g in res.gammas[:, 0]],
        }

    return {
        "model": cfg.name,
        "x_init": [float(v) for v in x_init.ravel()],
        "tokens": [int(v) for v in tokens.ravel()],
        "denoiser_t": t_probe,
        "denoiser_eps": [float(v) for v in eps_probe.ravel()],
        "sample_cfg": run(gamma_bar=1.1),
        "sample_ag": {**run(gamma_bar=0.991), "gamma_bar": 0.991},
    }


def build_manifest(models: dict, artifacts: dict) -> dict:
    table = diffusion.coef_table(DEFAULT_STEPS)
    return {
        "version": 1,
        "flat_dim": FLAT_DIM,
        "img": data.IMG,
        "channels": data.CHANNELS,
        "buckets": BUCKETS,
        "edit_buckets": EDIT_BUCKETS,
        "defaults": {"guidance": DEFAULT_GUIDANCE, "steps": DEFAULT_STEPS},
        "schedule": {
            "kind": "cosine-vp",
            "cosine_s": diffusion.COSINE_S,
            "t_max": diffusion.T_MAX,
            "t_min": diffusion.T_MIN,
            # parity table for rust tests: timesteps + folded coefficients
            "timesteps_20": [float(t) for t in
                             diffusion.timesteps(DEFAULT_STEPS)],
            "coefs_20": [[float(v) for v in row] for row in table],
        },
        "vocab": {
            "shapes": data.SHAPES,
            "colors": data.COLORS,
            "positions": data.POSITIONS,
            "sizes": data.SIZES,
        },
        "models": models,
        "artifacts": artifacts,
        "search": {
            "steps": SEARCH_STEPS,
            "batch": SEARCH_BATCH,
            "options": search_graph.OPTION_NAMES,
            "costs": [float(c) for c in search_graph.OPTION_COSTS],
            "s_base": DEFAULT_GUIDANCE,
            "lam_cost": 0.02,
            "cost_target": 30.0,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-search", action="store_true")
    ap.add_argument("--skip-missing", action="store_true",
                    help="skip models whose checkpoint is absent instead of failing")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    models_meta: dict = {}
    artifacts: dict = {"denoisers": {}, "guide": {}, "solver": {}}

    for name in ("dit_s", "dit_b", "dit_edit"):
        cfg = model.CONFIGS[name]
        ckpt = train.ckpt_path(out, name)
        if not os.path.exists(ckpt):
            if args.skip_missing:
                print(f"[{name}] checkpoint missing, skipping")
                continue
            raise SystemExit(
                f"missing checkpoint {ckpt}; run `make train` first")
        params = model.load_params(ckpt)
        buckets = EDIT_BUCKETS if name == "dit_edit" else BUCKETS
        per_bucket = {}
        print(f"[{name}] lowering denoiser ({model.param_count(params)} params)")
        for b in buckets:
            text = to_hlo_text(lower_denoiser(params, cfg, b))
            per_bucket[str(b)] = _write(out, f"denoiser_{name}_b{b}.hlo.txt",
                                        text)
        artifacts["denoisers"][name] = per_bucket
        models_meta[name] = {
            "params": model.param_count(params),
            "in_channels": cfg.in_channels,
            "buckets": buckets,
            "checkpoint": os.path.basename(ckpt),
        }
        if name == "dit_s":
            print(f"[{name}] building parity fixture (python reference run)")
            fixture = build_parity_fixture(params, cfg)
            with open(os.path.join(out, "parity.json"), "w") as f:
                json.dump(fixture, f)
        if name == "dit_s" and not args.skip_search:
            print(f"[{name}] lowering search graph "
                  f"(T={SEARCH_STEPS}, unrolled x2 trajectories)")
            artifacts["search_grad"] = _write(
                out, "search_grad.hlo.txt", to_hlo_text(lower_search(params,
                                                                     cfg)))

    print("[shared] lowering guide + solver kernels")
    for b in BUCKETS:
        artifacts["guide"][str(b)] = _write(
            out, f"guide_b{b}.hlo.txt", to_hlo_text(lower_guide(b)))
        artifacts["solver"][str(b)] = _write(
            out, f"solver_b{b}.hlo.txt", to_hlo_text(lower_solver(b)))

    manifest = build_manifest(models_meta, artifacts)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest.json written; {len(os.listdir(out))} files in {out}")


if __name__ == "__main__":
    main()
