"""Diffusion schedule math + reference samplers (build-time only).

Defines the VP cosine schedule, the DPM-Solver++(2M) coefficient folding used
by both the AOT'd Pallas solver kernel and the Rust coordinator
(``rust/src/coordinator/solver.rs`` re-implements ``fold_coefs`` and is tested
against the sample table exported in ``manifest.json``), and pure-python
reference samplers (CFG / AG / naive step reduction) used as oracles for the
Rust engine's integration tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Schedule constants — shared with rust/src/coordinator/solver.rs.
COSINE_S = 0.008
T_MAX = 0.98   # start of sampling (sigma ~ 0.9995)
T_MIN = 0.02   # end of sampling   (sigma ~ 0.044)


def alpha_bar(t):
    """Cosine cumulative signal level, normalized so alpha_bar(0) = 1."""
    f = lambda u: math.cos((u + COSINE_S) / (1.0 + COSINE_S) * math.pi / 2.0) ** 2
    if isinstance(t, (float, int)):
        return f(t) / f(0.0)
    g = lambda u: jnp.cos((u + COSINE_S) / (1.0 + COSINE_S) * jnp.pi / 2.0) ** 2
    return g(t) / g(0.0)


def alpha_sigma(t):
    """VP (alpha_t, sigma_t) with alpha^2 + sigma^2 = 1."""
    ab = alpha_bar(t)
    if isinstance(ab, float):
        return math.sqrt(ab), math.sqrt(1.0 - ab)
    return jnp.sqrt(ab), jnp.sqrt(1.0 - ab)


def lam(t: float) -> float:
    """Half log-SNR lambda_t = log(alpha_t / sigma_t)."""
    a, s = alpha_sigma(float(t))
    return math.log(a / s)


def timesteps(num_steps: int) -> np.ndarray:
    """Uniform time grid from T_MAX down to T_MIN, ``num_steps + 1`` points."""
    return np.linspace(T_MAX, T_MIN, num_steps + 1)


def fold_coefs(t_s: float, t_t: float, t_r: float | None) -> np.ndarray:
    """Fold the DPM-Solver++(2M) update into 5 affine coefficients.

    Step from time ``t_s`` to ``t_t`` with the previous solver point at
    ``t_r`` (``None`` → first step → Euler / DPM++(1S)).

    Returns ``[k_x, k_eps, k_prev, j_x, j_eps]`` such that

      x_next = k_x * x + k_eps * eps + k_prev * x0_prev
      x0     = j_x * x + j_eps * eps

    This is the exact algebra the fused Pallas kernel (``kernels/dpmpp.py``)
    and the Rust coordinator consume.
    """
    a_s, s_s = alpha_sigma(float(t_s))
    a_t, s_t = alpha_sigma(float(t_t))
    l_s, l_t = lam(t_s), lam(t_t)
    h = l_t - l_s
    e = a_t * (1.0 - math.exp(-h))  # = -alpha_t (exp(-h) - 1)
    if t_r is None:
        big_a, big_b = 1.0, 0.0
    else:
        l_r = lam(t_r)
        r0 = (l_s - l_r) / h
        big_a = 1.0 + 1.0 / (2.0 * r0)
        big_b = -1.0 / (2.0 * r0)
    j_x = 1.0 / a_s
    j_eps = -s_s / a_s
    k_x = s_t / s_s + e * big_a * j_x
    k_eps = e * big_a * j_eps
    k_prev = e * big_b
    return np.array([k_x, k_eps, k_prev, j_x, j_eps], dtype=np.float64)


def coef_table(num_steps: int) -> np.ndarray:
    """``(num_steps, 5)`` coefficient table for a full trajectory."""
    ts = timesteps(num_steps)
    rows = []
    for i in range(num_steps):
        t_r = ts[i - 1] if i > 0 else None
        rows.append(fold_coefs(ts[i], ts[i + 1], t_r))
    return np.stack(rows)


# ---------------------------------------------------------------------------
# Reference samplers (oracles for the Rust engine).
# ---------------------------------------------------------------------------

EpsFn = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
# eps_fn(x (B,H,W,C), t (B,), tokens (B,K)) -> eps (B,H,W,C)


@dataclasses.dataclass
class SampleResult:
    image: np.ndarray          # final x0 prediction, (B, H, W, C)
    nfes: int                  # total network function evaluations
    gammas: np.ndarray         # per-step x0-space cosine (the AG signal)
    gammas_eps: np.ndarray     # per-step raw-eps cosine (Eq. 7 as printed)
    cfg_steps: int             # steps that used guidance


def _flat(x):
    return x.reshape(x.shape[0], -1)


def sample(eps_fn: EpsFn, x_t: jax.Array, tokens: jax.Array,
           uncond_tokens: jax.Array, num_steps: int, guidance: float,
           gamma_bar: float = 1.1, cond_only: bool = False) -> SampleResult:
    """Reference CFG / AG / conditional-only sampler.

    ``gamma_bar > 1`` never truncates → plain CFG. ``gamma_bar <= 1`` →
    Adaptive Guidance: once the sample's convergence signal gamma_t exceeds
    gamma_bar, subsequent steps use the conditional score only.
    ``cond_only=True`` is the guidance-distillation cost proxy.

    The AG signal is Eq. 7's cosine evaluated on the *data predictions*
    ``x0 = j_x x + j_eps eps`` rather than on raw eps: the two are affine
    re-parameterizations of the same network output, but in x0 space the
    cond/uncond difference is scaled by sigma/alpha → 0, which makes the
    convergence robust to the eps-error floor of small models (DESIGN.md
    §Hardware-Adaptation). The raw-eps cosine (the paper's printed form)
    is recorded alongside.
    """
    from .kernels import ref

    b = x_t.shape[0]
    shape = x_t.shape
    ts = timesteps(num_steps)
    x = _flat(x_t)
    x0_prev = jnp.zeros_like(x)
    truncated = np.zeros(b, dtype=bool)
    gammas, gammas_eps, nfes, cfg_steps = [], [], 0, 0

    def _cos(a, bb):
        num = jnp.sum(a * bb, -1)
        den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(bb, axis=-1)
        return num / jnp.maximum(den, 1e-12)

    for i in range(num_steps):
        coefs_row = jnp.asarray(
            fold_coefs(ts[i], ts[i + 1], ts[i - 1] if i else None), x.dtype)
        tv = jnp.full((b,), float(ts[i]), x.dtype)
        eps_c = _flat(eps_fn(x.reshape(shape), tv, tokens))
        nfes += b
        if cond_only or bool(np.all(truncated)):
            eps = eps_c
            gamma = jnp.ones((b,))
            g_eps = jnp.ones((b,))
        else:
            eps_u = _flat(eps_fn(x.reshape(shape), tv, uncond_tokens))
            nfes += int(np.sum(~truncated))
            s = jnp.full((b,), guidance, x.dtype)
            eps_cfg, g_eps = ref.cfg_combine(eps_c, eps_u, s)
            x0_c = coefs_row[3] * x + coefs_row[4] * eps_c
            x0_u = coefs_row[3] * x + coefs_row[4] * eps_u
            gamma = _cos(x0_c, x0_u)
            # Per-sample AG switch: truncated samples keep the cheap score.
            mask = jnp.asarray(truncated)[:, None]
            eps = jnp.where(mask, eps_c, eps_cfg)
            cfg_steps += 1
            truncated = truncated | (np.asarray(gamma) >= gamma_bar)
        gammas.append(np.asarray(gamma))
        gammas_eps.append(np.asarray(g_eps))
        coefs = jnp.tile(coefs_row[None, :], (b, 1))
        x, x0 = ref.dpmpp_step(x, eps, x0_prev, coefs)
        x0_prev = x0
    return SampleResult(np.asarray(x0_prev).reshape(shape), nfes,
                        np.stack(gammas), np.stack(gammas_eps), cfg_steps)
