//! Coordinator integration + property tests on the analytic GMM backend —
//! no artifacts required. These pin the *semantics* of the serving engine:
//! policy NFE accounting, AG replication guarantees, batching invariants,
//! LINEARAG end-to-end, and scheduler behaviour under mixed traffic.

use std::sync::Arc;

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::ext::{adaptive_scale, compressed_cfg};
use adaptive_guidance::coordinator::policy::{
    ag, ag_prefix, alternating, cfg, cond_only, linear_ag, pix2pix, searched, PolicyRef,
    StepChoice,
};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::ols;
use adaptive_guidance::quality::ssim::ssim_rgb;
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::testing::{forall, gen};

fn engine(dim: usize) -> Engine<GmmBackend> {
    Engine::new(GmmBackend::new(Gmm::axes(dim, 6, 3.0, 0.05))).unwrap()
}

fn req(id: u64, seed: u64, steps: usize, policy: PolicyRef) -> Request {
    Request::new(id, "gmm", vec![1 + (id % 6) as i32, 0, 0, 0], seed, steps, policy)
}

// ---------------------------------------------------------------------------
// AG semantics
// ---------------------------------------------------------------------------

/// Property: for any seed/steps, AG's trajectory equals CFG's exactly up to
/// the truncation point, and saves NFEs when it truncates.
#[test]
fn prop_ag_prefix_replication() {
    forall(0xA6, 15, |rng| {
        let seed = rng.next_u64();
        let steps = gen::usize_in(rng, 6, 24);
        let mut e = engine(12);
        let mut cfg_r = req(0, seed, steps, cfg(2.0));
        let mut ag_r = req(1, seed, steps, ag(2.0, 0.999));
        cfg_r.tokens = vec![2, 0, 0, 0];
        ag_r.tokens = vec![2, 0, 0, 0];
        let out = e.run(vec![cfg_r, ag_r]).unwrap();
        let (cfg, ag) = (&out[0], &out[1]);
        assert!(ag.nfes <= cfg.nfes);
        if let Some(k) = ag.truncated_at {
            assert_eq!(ag.nfes, cfg.nfes - (steps - 1 - k), "NFE accounting");
            for i in 0..=k {
                assert!(
                    (ag.gammas[i] - cfg.gammas[i]).abs() < 1e-12,
                    "gamma prefix diverged at {i}"
                );
            }
        } else {
            assert_eq!(ag.image, cfg.image, "no truncation → exact replication");
        }
    });
}

/// Monotonicity: a lower gamma-bar can only truncate earlier (or equally),
/// and therefore costs at most as many NFEs.
#[test]
fn prop_ag_threshold_monotonicity() {
    forall(0xB7, 10, |rng| {
        let seed = rng.next_u64();
        let mut e = engine(12);
        let mk = |id, g| {
            let mut r = req(id, seed, 16, ag(2.0, g));
            r.tokens = vec![3, 0, 0, 0];
            r
        };
        let out = e.run(vec![mk(0, 0.9), mk(1, 0.99), mk(2, 0.9999)]).unwrap();
        assert!(out[0].nfes <= out[1].nfes);
        assert!(out[1].nfes <= out[2].nfes);
        let t = |c: &adaptive_guidance::Completion| c.truncated_at.unwrap_or(usize::MAX);
        assert!(t(&out[0]) <= t(&out[1]));
        assert!(t(&out[1]) <= t(&out[2]));
    });
}

/// AG must still transport to the conditioned mode (quality preserved).
#[test]
fn ag_lands_on_the_conditioned_mode() {
    let mut e = engine(8);
    let gmm = e.backend.gmm.clone();
    let out = e
        .run(vec![req(2, 41, 20, ag(2.0, 0.995))])
        .unwrap();
    let img = &out[0].image;
    let target = &gmm.means[2];
    let dist: f64 = img
        .iter()
        .zip(target)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    assert!(dist < 1.5, "AG sample {dist} from conditioned mode");
    assert!(out[0].truncated_at.is_some(), "expected truncation");
}

// ---------------------------------------------------------------------------
// Batching invariants
// ---------------------------------------------------------------------------

/// Property: results are independent of co-scheduled traffic — a request
/// produces bit-identical output alone or in a full batch.
#[test]
fn prop_batching_does_not_change_results() {
    forall(0xC1, 8, |rng| {
        let seed = rng.next_u64();
        let steps = gen::usize_in(rng, 4, 12);
        let solo = {
            let mut e = engine(12);
            e.run(vec![req(0, seed, steps, cfg(2.0))])
                .unwrap()
        };
        let crowded = {
            let mut e = engine(12);
            let mut reqs = vec![req(0, seed, steps, cfg(2.0))];
            for i in 1..9 {
                reqs.push(req(i, rng.next_u64(), steps, ag(2.0, 0.99)));
            }
            e.run(reqs).unwrap()
        };
        assert_eq!(solo[0].image, crowded[0].image);
        assert_eq!(solo[0].nfes, crowded[0].nfes);
    });
}

/// Items executed must exactly equal the sum of per-request NFEs — the
/// batcher neither drops nor duplicates work.
#[test]
fn prop_work_conservation() {
    forall(0xD2, 8, |rng| {
        let n = gen::usize_in(rng, 1, 12);
        let mut e = engine(12);
        let reqs: Vec<_> = (0..n)
            .map(|i| {
                let policy = match i % 3 {
                    0 => cfg(2.0),
                    1 => ag(2.0, 0.995),
                    _ => cond_only(),
                };
                req(i as u64, rng.next_u64(), 10, policy)
            })
            .collect();
        let out = e.run(reqs).unwrap();
        let total: usize = out.iter().map(|c| c.nfes).sum();
        assert_eq!(e.backend.items_executed, total);
        assert_eq!(e.items(), total);
    });
}

// ---------------------------------------------------------------------------
// Searched policies + LINEARAG end-to-end
// ---------------------------------------------------------------------------

#[test]
fn searched_policy_runs_with_expected_cost() {
    let choices = vec![
        StepChoice::Cfg { s: 2.0 },
        StepChoice::Cfg { s: 2.0 },
        StepChoice::Cond,
        StepChoice::Uncond,
        StepChoice::Cond,
    ];
    let mut e = engine(8);
    let out = e
        .run(vec![req(0, 5, 5, searched(choices))])
        .unwrap();
    assert_eq!(out[0].nfes, 2 + 2 + 1 + 1 + 1);
}

/// Full LINEARAG loop: record CFG trajectories, fit OLS, run the LinearAg
/// policy, and check it (a) costs the Eq. 11 budget and (b) lands near the
/// CFG result.
#[test]
fn linear_ag_end_to_end_on_gmm() {
    let steps = 10;
    // collect training trajectories
    let mut e = engine(8);
    let reqs: Vec<_> = (0..40)
        .map(|i| {
            let mut r = req(i, 1000 + i, steps, cfg(2.0));
            r.record_trajectory = true;
            r
        })
        .collect();
    let trajs: Vec<_> = e
        .run(reqs)
        .unwrap()
        .into_iter()
        .map(|c| c.trajectory.unwrap())
        .collect();
    let coeffs = Arc::new(ols::fit(&trajs, 1e-6));

    // run LINEARAG vs CFG on fresh seeds
    let mut e2 = engine(8);
    let out = e2
        .run(vec![
            req(0, 7777, steps, cfg(2.0)),
            {
                let mut r = req(1, 7777, steps, linear_ag(2.0, coeffs.clone()));
                r.tokens = vec![1, 0, 0, 0];
                r
            },
        ])
        .unwrap();
    let (cfg, lin) = (&out[0], &out[1]);
    // Eq. 11 budget at T=10: 3 guided steps (0,2,4) ·2 + 7 LR steps ·1 = 13
    assert_eq!(lin.nfes, 13);
    assert!(lin.nfes < cfg.nfes);
    // quality: close to the CFG endpoint in L2 (the paper accepts deviation)
    let dist: f64 = cfg
        .image
        .iter()
        .zip(&lin.image)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let norm: f64 = cfg.image.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
    assert!(dist / norm < 0.35, "LINEARAG drifted {:.3} rel", dist / norm);
}

// ---------------------------------------------------------------------------
// Negative prompts + SSIM sanity on GMM "images"
// ---------------------------------------------------------------------------

#[test]
fn negative_prompt_changes_the_uncond_stream_only() {
    // with a negative prompt the guided result differs from plain CFG,
    // but conditional-only generations are unaffected.
    let mut e = engine(8);
    let mk = |id, policy| {
        let mut r = req(id, 9, 10, policy);
        r.tokens = vec![2, 0, 0, 0]; // identical condition for all four
        r
    };
    let mut with_neg = mk(0, cfg(2.0));
    with_neg.neg_tokens = Some(vec![4, 0, 0, 0]);
    let plain = mk(1, cfg(2.0));
    let mut cond_a = mk(2, cond_only());
    cond_a.neg_tokens = Some(vec![4, 0, 0, 0]);
    let cond_b = mk(3, cond_only());
    let out = e.run(vec![with_neg, plain, cond_a, cond_b]).unwrap();
    assert_ne!(out[0].image, out[1].image, "negative prompt must matter");
    assert_eq!(out[2].image, out[3].image, "cond-only ignores negatives");
}

#[test]
fn ssim_of_replicated_trajectories_is_one() {
    // engine determinism feeds the quality metric: same request twice → SSIM 1.
    let run = || {
        let mut e = Engine::new(GmmBackend::new(Gmm::axes(768, 4, 3.0, 0.05))).unwrap();
        e.run(vec![req(0, 3, 8, cfg(2.0))]).unwrap()
    };
    let a = run();
    let b = run();
    let s = ssim_rgb(&a[0].image, &b[0].image, 16, 16);
    assert!((s - 1.0).abs() < 1e-12);
}

// ---------------------------------------------------------------------------
// Open-policy API: mixed fleets, plugins, and the shared half-split rule
// ---------------------------------------------------------------------------

/// Every policy — the eight built-ins plus the two ext.rs plugins — batched
/// through the *same* pump loop, with per-request NFE accounting checked
/// against each policy's own worst-case bound and exact counts for the
/// deterministic ones. The engine never learns which policy is which.
#[test]
fn mixed_policy_fleet_accounts_nfes_per_request() {
    let steps = 12;
    let coeffs = Arc::new(ols::OlsCoeffs::identity(steps));
    let policies: Vec<(PolicyRef, Option<usize>)> = vec![
        (cfg(2.0), Some(24)),
        (cond_only(), Some(12)),
        (ag(2.0, 0.995), None), // adaptive: bound-checked only
        (ag_prefix(2.0, 4), Some(16)),
        (alternating(2.0), Some(15)), // guided half = 6 → CFG at 0, 2, 4
        (linear_ag(2.0, coeffs), Some(15)),
        (
            searched(vec![
                StepChoice::Cfg { s: 2.0 },
                StepChoice::Cond,
                StepChoice::Uncond,
            ]),
            Some(13), // 2 + 1 + 1, then 9 default-cond steps
        ),
        (pix2pix(2.0, 1.5, None, Some(6)), Some(24)), // 6·3 + 6·1
        (compressed_cfg(2.0, 4), Some(15)),           // guided at 0, 4, 8
        (adaptive_scale(3.0, 1.0, 0.9, 2.0), Some(24)), // γ̄_hi unreachable
    ];
    let mut e = engine(8);
    let reqs: Vec<Request> = policies
        .iter()
        .enumerate()
        .map(|(i, (p, _))| req(i as u64, 4000 + i as u64, steps, p.clone()))
        .collect();
    let out = e.run(reqs).unwrap();
    assert_eq!(out.len(), policies.len());

    let total: usize = out.iter().map(|c| c.nfes).sum();
    assert_eq!(e.items(), total, "batcher dropped or duplicated work");
    assert_eq!(e.backend.items_executed, total);
    // the fleet actually batched across policies (occupancy ≫ 1)
    assert!(e.mean_occupancy() > 4.0, "{}", e.mean_occupancy());

    for (c, (p, expect)) in out.iter().zip(&policies) {
        assert!(
            c.nfes <= p.max_nfes(steps),
            "{}: {} NFEs exceeds its own bound {}",
            p.name(),
            c.nfes,
            p.max_nfes(steps)
        );
        if let Some(n) = expect {
            assert_eq!(c.nfes, *n, "{}", p.name());
        }
    }
}

/// The AdaptiveScale plugin truncates through its own observe() rule — no
/// engine involvement. A threshold below any possible cosine fires after
/// the first guided step: 2 + (T-1) NFEs, deterministically.
#[test]
fn adaptive_scale_truncates_via_policy_state() {
    let mut e = engine(8);
    let out = e
        .run(vec![req(0, 11, 10, adaptive_scale(2.0, 0.5, -2.0, -1.5))])
        .unwrap();
    assert_eq!(out[0].truncated_at, Some(0));
    assert_eq!(out[0].nfes, 11);
}

/// With unreachable gamma thresholds the AdaptiveScale ramp never leaves
/// s_max, so it replicates plain CFG at the same scale bit-for-bit.
#[test]
fn adaptive_scale_with_unreachable_ramp_replicates_cfg() {
    let mut e = engine(8);
    let mk = |id, p| {
        let mut r = req(id, 777, 10, p);
        r.tokens = vec![2, 0, 0, 0];
        r
    };
    let out = e
        .run(vec![
            mk(0, cfg(2.0)),
            mk(1, adaptive_scale(2.0, 0.5, 2.0, 3.0)),
        ])
        .unwrap();
    assert_eq!(out[0].image, out[1].image);
    assert_eq!(out[0].nfes, out[1].nfes);
}

/// CompressedCfg with period 1 is plain CFG; larger periods guide every
/// k-th step only.
#[test]
fn compressed_cfg_period_one_replicates_cfg() {
    let mut e = engine(8);
    let mk = |id, p| {
        let mut r = req(id, 31, 10, p);
        r.tokens = vec![3, 0, 0, 0];
        r
    };
    let out = e
        .run(vec![mk(0, cfg(2.0)), mk(1, compressed_cfg(2.0, 1)), mk(2, compressed_cfg(2.0, 5))])
        .unwrap();
    assert_eq!(out[0].image, out[1].image);
    assert_eq!(out[0].nfes, out[1].nfes);
    assert_eq!(out[2].nfes, 2 * 2 + 8); // guided at steps 0 and 5
}

/// Odd totals: the shared ⌈T/2⌉ rule gives the guided half the extra step
/// for both half-split policies (exact NFE counts, end-to-end).
#[test]
fn odd_total_half_split_is_guided_biased() {
    let steps = 5; // guided half = 3 → CFG at steps 0 and 2
    let coeffs = Arc::new(ols::OlsCoeffs::identity(steps));
    let mut e = engine(8);
    let out = e
        .run(vec![
            req(0, 9, steps, alternating(2.0)),
            req(1, 9, steps, linear_ag(2.0, coeffs)),
        ])
        .unwrap();
    assert_eq!(out[0].nfes, 2 * 2 + 3, "alternating: 2 guided + 3 cond");
    assert_eq!(out[1].nfes, 2 * 2 + 3, "linear-ag: 2 guided + 3 LR");
    assert_eq!(out[0].cfg_steps, 2);
    assert_eq!(out[1].cfg_steps, 2);
}
