//! Runtime integration tests: the PJRT path against the real artifacts.
//!
//! These tests need `make artifacts` to have run; they skip (with a note)
//! when `artifacts/manifest.json` is absent so `cargo test` stays green on a
//! fresh checkout.
//!
//! The key assertions are *parity* with the python reference (parity.json,
//! produced by aot.py from the same checkpoint): the Rust engine must
//! reproduce the L2 sampler's images and gamma signals through the AOT'd
//! denoiser + host combine/solver within f32 tolerance.

use std::path::PathBuf;

use adaptive_guidance::backend::{Backend, EvalInput};
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg, pix2pix};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::quality::ssim::ssim_rgb;
use adaptive_guidance::runtime::PjrtBackend;
use adaptive_guidance::util::json::{self, Value};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn load_parity(dir: &PathBuf) -> Option<Value> {
    let path = dir.join("parity.json");
    if !path.exists() {
        eprintln!("skipping: parity.json missing");
        return None;
    }
    Some(json::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

fn f32s(v: &Value) -> Vec<f32> {
    v.as_f64_vec().unwrap().into_iter().map(|x| x as f32).collect()
}

#[test]
fn manifest_loads_and_validates() {
    let Some(dir) = artifacts_dir() else { return };
    let be = PjrtBackend::load(&dir).unwrap();
    assert!(be.manifest.models.contains_key("dit_s"));
    assert!(be.manifest.models.contains_key("dit_b"));
    assert_eq!(be.manifest.flat_dim, 768);
    assert_eq!(be.buckets(), &[1, 2, 4, 8, 16]);
}

#[test]
fn denoiser_matches_python_reference_eval() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(par) = load_parity(&dir) else { return };
    let mut be = PjrtBackend::load(&dir).unwrap();
    let model = par.req("model").as_str().unwrap().to_owned();
    let x = f32s(par.req("x_init"));
    let t = par.req("denoiser_t").as_f64().unwrap() as f32;
    let tokens: Vec<i32> = par
        .req("tokens")
        .as_f64_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as i32)
        .collect();
    let want = f32s(par.req("denoiser_eps"));
    let got = be
        .denoise(&model, &[EvalInput { x, t, tokens }])
        .unwrap()
        .remove(0);
    assert_eq!(got.len(), want.len());
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-4, "denoiser parity max err {max_err}");
}

#[test]
fn engine_cfg_run_matches_python_sampler() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(par) = load_parity(&dir) else { return };
    let mut engine = Engine::new(PjrtBackend::load(&dir).unwrap()).unwrap();
    let model = par.req("model").as_str().unwrap().to_owned();
    let tokens: Vec<i32> = par
        .req("tokens")
        .as_f64_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as i32)
        .collect();
    let refrun = par.req("sample_cfg");
    let mut req = Request::new(0, &model, tokens, 0, 20, cfg(7.5));
    req.init_noise = Some(f32s(par.req("x_init")));
    let out = engine.run(vec![req]).unwrap().remove(0);
    assert_eq!(out.nfes as f64, refrun.req("nfes").as_f64().unwrap());

    let want_img = f32s(refrun.req("image"));
    let max_err = out
        .image
        .iter()
        .zip(&want_img)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 5e-3, "image parity max err {max_err}");

    let want_gammas = refrun.req("gammas").as_f64_vec().unwrap();
    for (i, (a, b)) in out.gammas.iter().zip(&want_gammas).enumerate() {
        assert!((a - b).abs() < 1e-4, "gamma[{i}] {a} vs {b}");
    }
}

#[test]
fn engine_ag_run_matches_python_sampler() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(par) = load_parity(&dir) else { return };
    let mut engine = Engine::new(PjrtBackend::load(&dir).unwrap()).unwrap();
    let model = par.req("model").as_str().unwrap().to_owned();
    let tokens: Vec<i32> = par
        .req("tokens")
        .as_f64_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as i32)
        .collect();
    let refrun = par.req("sample_ag");
    let gamma_bar = refrun.req("gamma_bar").as_f64().unwrap();
    let mut req = Request::new(0, &model, tokens, 0, 20, ag(7.5, gamma_bar));
    req.init_noise = Some(f32s(par.req("x_init")));
    let out = engine.run(vec![req]).unwrap().remove(0);
    assert_eq!(
        out.nfes as f64,
        refrun.req("nfes").as_f64().unwrap(),
        "AG NFE accounting must match python (truncated_at {:?})",
        out.truncated_at
    );
    let want_img = f32s(refrun.req("image"));
    let max_err = out
        .image
        .iter()
        .zip(&want_img)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 5e-3, "AG image parity max err {max_err}");
}

#[test]
fn buckets_give_identical_results() {
    // the same item executed via the b1 and (padded) b4 executables must
    // produce the same scores — padding lanes cannot leak.
    let Some(dir) = artifacts_dir() else { return };
    let mut be = PjrtBackend::load(&dir).unwrap();
    let Some(par) = load_parity(&dir) else { return };
    let item = EvalInput {
        x: f32s(par.req("x_init")),
        t: 0.37,
        tokens: vec![2, 1, 4, 2],
    };
    let solo = be.denoise("dit_s", &[item.clone()]).unwrap().remove(0);
    let many: Vec<EvalInput> = vec![item.clone(), item.clone(), item.clone()];
    let batched = be.denoise("dit_s", &many).unwrap();
    for out in &batched {
        let max_err = out
            .iter()
            .zip(&solo)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-5, "bucket mismatch {max_err}");
    }
}

#[test]
fn device_guide_and_solver_match_host_math() {
    let Some(dir) = artifacts_dir() else { return };
    let mut be = PjrtBackend::load(&dir).unwrap();
    let m = be.manifest.flat_dim;
    let mut rng = adaptive_guidance::util::rng::Rng::new(3);
    let eps_c = rng.normal_vec(m);
    let eps_u = rng.normal_vec(m);

    // guide: device vs host (tensor::cfg_combine + cosine)
    let (dev_eps, dev_gamma) = be.run_guide(&eps_c, &eps_u, &[7.5]).unwrap();
    let tc = adaptive_guidance::tensor::Tensor::new(vec![m], eps_c.clone());
    let tu = adaptive_guidance::tensor::Tensor::new(vec![m], eps_u.clone());
    let host_eps = adaptive_guidance::tensor::Tensor::cfg_combine(&tc, &tu, 7.5);
    let host_gamma = tc.cosine(&tu);
    let max_err = dev_eps
        .iter()
        .zip(&host_eps.data)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "guide parity {max_err}");
    assert!((dev_gamma[0] as f64 - host_gamma).abs() < 1e-4);

    // solver: device vs host apply_step
    let coefs = adaptive_guidance::coordinator::solver::fold_coefs(0.6, 0.55, Some(0.65));
    let x = rng.normal_vec(m);
    let x0_prev = rng.normal_vec(m);
    let carr = coefs.as_array().map(|v| v as f32);
    let (dev_x, dev_x0) = be.run_solver(&x, &eps_c, &x0_prev, &carr).unwrap();
    let (host_x, host_x0) =
        adaptive_guidance::coordinator::solver::apply_step(&x, &eps_c, &x0_prev, &coefs);
    for (d, h) in dev_x.iter().zip(&host_x).chain(dev_x0.iter().zip(&host_x0)) {
        assert!((d - h).abs() < 1e-4, "solver parity {d} vs {h}");
    }
}

#[test]
fn ag_saves_nfes_and_preserves_ssim_on_trained_model() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(PjrtBackend::load(&dir).unwrap()).unwrap();
    let tokens = vec![1, 3, 1, 2];
    let mk = |id, policy| {
        let mut r = Request::new(id, "dit_s", tokens.clone(), 99, 20, policy);
        r.record_trajectory = false;
        r
    };
    let out = engine
        .run(vec![
            mk(0, cfg(7.5)),
            mk(1, ag(7.5, 0.9988)),
        ])
        .unwrap();
    let (cfg, ag) = (&out[0], &out[1]);
    assert!(ag.nfes < cfg.nfes, "AG saved nothing: {} vs {}", ag.nfes, cfg.nfes);
    let s = ssim_rgb(&ag.image, &cfg.image, 16, 16);
    assert!(s > 0.8, "AG-vs-CFG SSIM {s}");
}

#[test]
fn edit_model_triple_eval_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let be = PjrtBackend::load(&dir).unwrap();
    if !be.manifest.models.contains_key("dit_edit") {
        eprintln!("skipping: dit_edit not in manifest");
        return;
    }
    let mut engine = Engine::new(be).unwrap();
    let mut req = Request::new(
        0,
        "dit_edit",
        vec![0, 2, 0, 0], // "make it green"
        5,
        10,
        pix2pix(7.5, 1.5, None, None),
    );
    req.src_image = Some(vec![0.1; 768]);
    let out = engine.run(vec![req]).unwrap().remove(0);
    assert_eq!(out.nfes, 30, "Eq. 9 costs 3 NFEs/step");
    assert_eq!(out.image.len(), 768);
}
