//! Engine-fleet integration tests on the analytic GMM backend — no
//! artifacts required. These pin the fleet's contract:
//!
//! * **placement never changes results** — per-request completions are
//!   byte-identical across `--shards 1/2/4` × every placement × the
//!   fifo and cost-aware schedulers, and all of them match the golden
//!   *unfused reference sampler* (the same seed-era primitives
//!   `sched_integration.rs` pins), so the whole fleet is anchored to
//!   first-principles math, not just to itself;
//! * **two-level admission** — the router's global budget trips before
//!   any shard budget does, and the shed line says which scope refused;
//! * **deadline-aware shedding** — an infeasible `deadline_ms` is refused
//!   with `deadline_infeasible` once a service rate has been observed,
//!   and counted in `deadline_shed_total{policy=}`;
//! * **drain** — in-flight work completes, threads join, later submits
//!   get a `draining` error.

use std::sync::mpsc::Receiver;

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::coordinator::policy::{ag, cfg, PolicyRef};
use adaptive_guidance::coordinator::request::{Completion, Request};
use adaptive_guidance::coordinator::solver;
use adaptive_guidance::fleet::{Fleet, FleetConfig, JobReply, Placement, ScopedShed};
use adaptive_guidance::sched::{Admission, AdmitError, SchedulerKind};
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::tensor::Tensor;
use adaptive_guidance::util::json;
use adaptive_guidance::util::rng::Rng;

fn gmm() -> Gmm {
    Gmm::axes(12, 6, 3.0, 0.05)
}

fn launch(shards: usize, placement: Placement, kind: SchedulerKind) -> Fleet {
    Fleet::launch(
        move |_shard| Ok(GmmBackend::new(gmm())),
        FleetConfig {
            shards,
            placement,
            scheduler: kind,
            ..FleetConfig::default()
        },
    )
}

/// The shared workload: 8 mixed cfg/ag requests with per-request seeds.
/// Ids are fleet-assigned in submission order, so request `i` always has
/// id `i` on a fresh fleet.
fn workload(steps: usize) -> Vec<Request> {
    (0..8u64)
        .map(|i| {
            let policy: PolicyRef = if i % 2 == 0 { cfg(2.0) } else { ag(2.0, 0.99) };
            let mut r = Request::new(
                0,
                "gmm",
                vec![1 + (i % 6) as i32, 0, 0, 0],
                7000 + i,
                steps,
                policy,
            );
            // distinct clients so client-hash placement actually spreads
            r.client_id = Some(std::sync::Arc::from(format!("client-{}", i % 4).as_str()));
            r
        })
        .collect()
}

/// Submit a workload and collect its completions in id order.
fn run_fleet(fleet: &Fleet, reqs: Vec<Request>) -> Vec<Completion> {
    let rxs: Vec<Receiver<JobReply>> = reqs
        .into_iter()
        .map(|r| fleet.submit(r).expect("admitted"))
        .collect();
    let mut out: Vec<Completion> = rxs
        .into_iter()
        .map(|rx| match rx.recv().expect("shard replied") {
            JobReply::Done(c, _ms) => *c,
            JobReply::Error(line) => panic!("unexpected error reply: {line}"),
            JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
        })
        .collect();
    out.sort_by_key(|c| c.id);
    out
}

/// Golden reference: one request's trajectory with the seed-era unfused
/// primitives — per-item `Gmm::eps` (allocating), separate
/// `Tensor::cfg_combine` + `Tensor::cosine` passes, out-of-place
/// `solver::apply_step` — replicating the engine's exact arithmetic
/// (including the f64→f32→f64 round-trip of the eval time). Identical to
/// the pin in `sched_integration.rs`; duplicated here on purpose so the
/// fleet anchors to first principles even if that file changes.
fn reference_sample(
    gmm: &Gmm,
    comp: usize,
    seed: u64,
    steps: usize,
    s: f32,
    gamma_bar: Option<f64>,
) -> (Vec<f32>, Vec<f64>) {
    let dim = gmm.dim;
    let mut x = Rng::new(seed).normal_vec(dim);
    let mut x0_prev = vec![0.0f32; dim];
    let ts = solver::timesteps(steps);
    let mut truncated = false;
    let mut gammas = Vec::new();
    for i in 0..steps {
        let t_r = if i > 0 { Some(ts[i - 1]) } else { None };
        let c = solver::fold_coefs(ts[i], ts[i + 1], t_r);
        let t_eval = ts[i] as f32 as f64;
        let eps = if truncated {
            gammas.push(f64::NAN);
            gmm.eps(&x, t_eval, Some(comp))
        } else {
            let ec = Tensor::new(vec![dim], gmm.eps(&x, t_eval, Some(comp)));
            let eu = Tensor::new(vec![dim], gmm.eps(&x, t_eval, None));
            let (jx, je) = (c.j_x as f32, c.j_eps as f32);
            let xa: Vec<f32> = (0..dim).map(|k| jx * x[k] + je * ec.data[k]).collect();
            let xb: Vec<f32> = (0..dim).map(|k| jx * x[k] + je * eu.data[k]).collect();
            let gamma = Tensor::new(vec![dim], xa).cosine(&Tensor::new(vec![dim], xb));
            gammas.push(gamma);
            if let Some(bar) = gamma_bar {
                if gamma >= bar {
                    truncated = true;
                }
            }
            Tensor::cfg_combine(&ec, &eu, s).data
        };
        let (xn, x0) = solver::apply_step(&x, &eps, &x0_prev, &c);
        x = xn;
        x0_prev = x0;
    }
    (x0_prev, gammas)
}

/// The tentpole pin: per-request completions are byte-identical across
/// shards 1/2/4 × all three placements × fifo and cost-aware, all
/// anchored to the golden unfused sampler, with the same total work
/// executed by every topology.
#[test]
fn shard_counts_and_placements_are_byte_identical() {
    let steps = 9;
    let g = gmm();
    for kind in [SchedulerKind::Fifo, SchedulerKind::CostAware] {
        for placement in Placement::ALL {
            let mut base_items: Option<f64> = None;
            for shards in [1usize, 2, 4] {
                let ctx = format!(
                    "{} / {} / shards={shards}",
                    kind.name(),
                    placement.name()
                );
                let fleet = launch(shards, placement, kind);
                let out = run_fleet(&fleet, workload(steps));
                assert_eq!(out.len(), 8, "{ctx}");
                for c in &out {
                    let comp = (c.id % 6) as usize;
                    let gamma_bar = if c.id % 2 == 1 { Some(0.99) } else { None };
                    let (image, gammas) =
                        reference_sample(&g, comp, 7000 + c.id, steps, 2.0, gamma_bar);
                    assert_eq!(
                        c.image, image,
                        "{ctx}: request {} diverged from the reference sampler",
                        c.id
                    );
                    assert_eq!(c.gammas.len(), gammas.len(), "{ctx}");
                    for (i, (a, b)) in c.gammas.iter().zip(&gammas).enumerate() {
                        assert!(
                            (a.is_nan() && b.is_nan()) || a == b,
                            "{ctx}: request {} gamma[{i}]: fleet {a} vs reference {b}",
                            c.id
                        );
                    }
                }
                // AG requests must actually exercise truncation, or the
                // test lost its teeth
                assert!(
                    out.iter().any(|c| c.truncated_at.is_some()),
                    "{ctx}: no AG truncation"
                );
                // work conservation: the same total items regardless of
                // how placement spread them
                let stats = fleet.stats_json().unwrap();
                let items = stats.req("items").as_f64().unwrap();
                match base_items {
                    None => base_items = Some(items),
                    Some(b) => assert_eq!(items, b, "{ctx}: total work changed"),
                }
                // every live shard's breakdown sums to the fleet total
                let per: f64 = stats
                    .req("per_shard")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|s| s.req("items").as_f64().unwrap())
                    .sum();
                assert_eq!(per, items, "{ctx}: per-shard items disagree with total");
                fleet.shutdown();
            }
        }
    }
}

/// Two-level admission: the router's global NFE budget trips before any
/// per-shard budget would, and the error names the global scope with the
/// budget numbers. The shard budgets would have admitted the request —
/// shard 1 is empty — which is exactly what makes the scope meaningful.
#[test]
fn global_budget_trips_before_shard_budgets() {
    // one 50k-step CFG request costs 100_000 NFEs — big enough that it
    // cannot complete between two back-to-back submits
    let fleet = Fleet::launch(
        |_shard| Ok(GmmBackend::new(gmm())),
        FleetConfig {
            shards: 2,
            placement: Placement::LeastLoaded,
            scheduler: SchedulerKind::Fifo,
            global_admission: Admission {
                max_queued_nfes: Some(150_000),
                ..Admission::unlimited()
            },
            shard_admission: Admission {
                max_queued_nfes: Some(120_000),
                ..Admission::unlimited()
            },
            ..FleetConfig::default()
        },
    );
    let big = |seed: u64| Request::new(0, "gmm", vec![1, 0, 0, 0], seed, 50_000, cfg(2.0));
    let rx = fleet.submit(big(1)).expect("first big request admits");
    let err = fleet.submit(big(2)).expect_err("second must trip the global budget");
    let shed = err
        .downcast_ref::<ScopedShed>()
        .unwrap_or_else(|| panic!("expected a scoped shed, got: {err}"));
    assert_eq!(shed.scope, "global");
    match &shed.inner {
        AdmitError::NfeBudgetFull {
            queued_nfes,
            request_nfes,
            max,
        } => {
            assert_eq!(*max, 150_000);
            assert_eq!(*request_nfes, 100_000);
            assert!(*queued_nfes > 50_000, "{queued_nfes}");
        }
        other => panic!("expected NfeBudgetFull, got {other}"),
    }
    // the in-flight request is unaffected and completes
    match rx.recv().unwrap() {
        JobReply::Done(c, _) => assert_eq!(c.nfes, 100_000),
        JobReply::Error(line) => panic!("{line}"),
        JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
    }
    fleet.shutdown();
}

/// A per-shard budget shed comes back over the job's reply channel as a
/// structured line with `"scope": "shard"`.
#[test]
fn shard_budget_sheds_with_shard_scope() {
    let fleet = Fleet::launch(
        |_shard| Ok(GmmBackend::new(gmm())),
        FleetConfig {
            shards: 1,
            shard_admission: Admission {
                max_queued_nfes: Some(10),
                ..Admission::unlimited()
            },
            ..FleetConfig::default()
        },
    );
    // cost 16 > 10: placed by the router (global is unlimited), refused
    // by the shard engine
    let rx = fleet
        .submit(Request::new(0, "gmm", vec![1, 0, 0, 0], 5, 8, cfg(2.0)))
        .expect("router places it");
    let line = match rx.recv().unwrap() {
        JobReply::Error(line) => line,
        JobReply::Done(..) => panic!("must be shed by the shard budget"),
        JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
    };
    let v = json::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
    assert_eq!(v.req("code").as_str(), Some("queue_full"));
    assert_eq!(v.req("scope").as_str(), Some("shard"));
    assert_eq!(v.req("max_queued_nfes").as_f64(), Some(10.0));
    // an in-budget request still completes on the same fleet
    let rx = fleet
        .submit(Request::new(0, "gmm", vec![2, 0, 0, 0], 6, 4, cfg(2.0)))
        .unwrap();
    match rx.recv().unwrap() {
        JobReply::Done(c, _) => assert_eq!(c.nfes, 8),
        JobReply::Error(line) => panic!("{line}"),
        JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
    }
    fleet.shutdown();
}

/// Deadline-aware shedding: once a shard has observed a service rate, a
/// request whose deadline cannot cover the backlog is refused with
/// `deadline_infeasible` and counted per policy; feasible deadlines and
/// deadline-free requests still pass.
#[test]
fn infeasible_deadlines_are_shed_at_admission() {
    let fleet = Fleet::launch(
        |_shard| Ok(GmmBackend::new(gmm())),
        FleetConfig {
            shards: 1,
            shed_infeasible: true,
            ..FleetConfig::default()
        },
    );
    // cold start: even a zero deadline is admitted (no observed rate yet)
    let mut cold = Request::new(0, "gmm", vec![1, 0, 0, 0], 11, 2000, cfg(2.0));
    cold.deadline_ms = Some(0);
    let rx = fleet.submit(cold).unwrap();
    match rx.recv().unwrap() {
        JobReply::Done(c, _) => assert_eq!(c.nfes, 4000),
        JobReply::Error(line) => panic!("cold start must admit: {line}"),
        JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
    }
    // the warmup measured a per-NFE rate; a 4000-NFE request due "now"
    // is infeasible by construction
    let mut doomed = Request::new(0, "gmm", vec![2, 0, 0, 0], 12, 2000, cfg(2.0));
    doomed.deadline_ms = Some(0);
    let rx = fleet.submit(doomed).unwrap();
    let line = match rx.recv().unwrap() {
        JobReply::Error(line) => line,
        JobReply::Done(..) => panic!("infeasible deadline must be shed"),
        JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
    };
    let v = json::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
    assert_eq!(v.req("code").as_str(), Some("deadline_infeasible"));
    assert_eq!(v.req("deadline_ms").as_f64(), Some(0.0));
    assert!(v.req("estimated_ms").as_f64().unwrap() >= 1.0, "{line}");
    assert_eq!(v.req("queued_nfes").as_f64(), Some(4000.0));
    // a generous deadline passes, as does no deadline at all
    let mut fine = Request::new(0, "gmm", vec![3, 0, 0, 0], 13, 8, cfg(2.0));
    fine.deadline_ms = Some(3_600_000);
    let rx = fleet.submit(fine).unwrap();
    assert!(matches!(rx.recv().unwrap(), JobReply::Done(..)));
    let rx = fleet
        .submit(Request::new(0, "gmm", vec![4, 0, 0, 0], 14, 8, cfg(2.0)))
        .unwrap();
    assert!(matches!(rx.recv().unwrap(), JobReply::Done(..)));
    // the shed is visible in the merged telemetry
    let stats = fleet.stats_json().unwrap();
    let counters = stats.req("telemetry").req("counters");
    assert_eq!(
        counters.req("deadline_shed_total{policy=cfg}").as_f64(),
        Some(1.0)
    );
    fleet.shutdown();
}

/// Drain during in-flight work: the ack arrives only after the work
/// completed, nothing is dropped, and the fleet refuses new requests with
/// a `draining` route error afterwards.
#[test]
fn drain_completes_in_flight_work_and_refuses_new() {
    let fleet = launch(2, Placement::RoundRobin, SchedulerKind::Fifo);
    let rxs: Vec<_> = workload(12)
        .into_iter()
        .map(|r| fleet.submit(r).unwrap())
        .collect();
    assert_eq!(fleet.shutdown(), 2);
    for rx in rxs {
        match rx.recv().expect("drained fleets answer every in-flight job") {
            JobReply::Done(c, _) => assert!(c.nfes > 0),
            JobReply::Error(line) => panic!("{line}"),
            JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
        }
    }
    let err = fleet
        .submit(Request::new(0, "gmm", vec![1, 0, 0, 0], 1, 4, cfg(2.0)))
        .unwrap_err();
    assert!(err.to_string().contains("draining"), "{err}");
}
