//! Scheduler/admission integration tests on the analytic GMM backend — no
//! artifacts required. These pin the scheduling subsystem's contract:
//! disciplines reorder *work*, never *results*; `fifo` reproduces the
//! engine's historical completions exactly; `fair-share` bounds a bulk
//! client's share; `cost-aware` drains cheap requests first; admission
//! sheds load without touching in-flight requests.

use std::sync::Arc;

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg, cond_only, linear_ag, PolicyRef};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::coordinator::solver;
use adaptive_guidance::ols::OlsCoeffs;
use adaptive_guidance::sched::{Admission, SchedulerKind};
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::tensor::Tensor;
use adaptive_guidance::util::rng::Rng;

fn backend(dim: usize) -> GmmBackend {
    GmmBackend::new(Gmm::axes(dim, 6, 3.0, 0.05))
}

fn engine_with(kind: SchedulerKind) -> Engine<GmmBackend> {
    Engine::with_scheduler(backend(12), kind.build(), Admission::unlimited()).unwrap()
}

fn req(id: u64, seed: u64, steps: usize, policy: PolicyRef) -> Request {
    Request::new(id, "gmm", vec![1 + (id % 6) as i32, 0, 0, 0], seed, steps, policy)
}

/// A mixed cfg/ag/linear-ag workload with dynamic per-request cost.
fn mixed_workload(n: usize, steps: usize) -> Vec<Request> {
    let coeffs = Arc::new(OlsCoeffs::identity(steps));
    (0..n)
        .map(|i| {
            let policy = match i % 3 {
                0 => cfg(2.0),
                1 => ag(2.0, 0.99),
                _ => linear_ag(2.0, coeffs.clone()),
            };
            req(i as u64, 5000 + i as u64, steps, policy)
        })
        .collect()
}

/// The acceptance pin: with the `fifo` scheduler the engine's completions
/// — images, NFEs, batch/item counts — are byte-identical run-to-run and
/// identical between `Engine::new` (the default) and an explicit `fifo`.
#[test]
fn fifo_reproduces_default_engine_completions_exactly() {
    let run = |mut e: Engine<GmmBackend>| {
        let out = e.run(mixed_workload(10, 12)).unwrap();
        (out, e.batches(), e.items())
    };
    let (a, a_batches, a_items) =
        run(Engine::new(backend(12)).unwrap());
    let (b, b_batches, b_items) =
        run(Engine::new(backend(12)).unwrap());
    let (c, c_batches, c_items) = run(engine_with(SchedulerKind::Fifo));
    assert_eq!(a_batches, b_batches);
    assert_eq!(a_items, b_items);
    assert_eq!(a_batches, c_batches);
    assert_eq!(a_items, c_items);
    assert_eq!(a.len(), b.len());
    for ((x, y), z) in a.iter().zip(&b).zip(&c) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.image, y.image, "request {}", x.id);
        assert_eq!(x.nfes, y.nfes);
        assert_eq!(x.truncated_at, y.truncated_at);
        assert_eq!(x.image, z.image, "explicit fifo diverged on {}", x.id);
        assert_eq!(x.nfes, z.nfes);
    }
}

/// Scheduling must reorder work, never change results: every discipline
/// produces bit-identical per-request completions on the same workload.
#[test]
fn every_scheduler_produces_identical_results() {
    let baseline = {
        let mut e = engine_with(SchedulerKind::Fifo);
        e.run(mixed_workload(12, 10)).unwrap()
    };
    let total: usize = baseline.iter().map(|c| c.nfes).sum();
    for kind in SchedulerKind::ALL {
        let mut e = engine_with(kind);
        let out = e.run(mixed_workload(12, 10)).unwrap();
        assert_eq!(out.len(), baseline.len(), "{}", kind.name());
        for (x, y) in out.iter().zip(&baseline) {
            assert_eq!(x.id, y.id, "{}", kind.name());
            assert_eq!(x.image, y.image, "{}: request {}", kind.name(), x.id);
            assert_eq!(x.nfes, y.nfes, "{}: request {}", kind.name(), x.id);
            assert_eq!(x.truncated_at, y.truncated_at, "{}", kind.name());
        }
        // work conservation: same items executed under every discipline
        assert_eq!(e.items(), total, "{}", kind.name());
        assert_eq!(e.backend.items_executed, total, "{}", kind.name());
    }
}

/// Starvation test: a bulk client floods 12 requests before an interactive
/// client's 2 arrive. Fair-share gives the interactive lane an equal slot
/// share per batch, so it finishes long before the bulk backlog — under
/// fifo both lanes advance in lockstep and interactive finishes last.
#[test]
fn fair_share_bounds_a_bulk_client() {
    let steps = 6;
    let run = |kind: SchedulerKind| {
        let mut e = engine_with(kind);
        for i in 0..12 {
            let mut r = req(i, 100 + i, steps, cfg(2.0));
            r.client_id = Some(Arc::from("bulk"));
            e.submit(r);
        }
        for i in 12..14 {
            let mut r = req(i, 100 + i, steps, cfg(2.0));
            r.client_id = Some(Arc::from("live"));
            e.submit(r);
        }
        // how many bulk requests completed before the live client was done?
        let (mut bulk_done, mut live_done) = (0usize, 0usize);
        let mut bulk_done_at_live_finish = None;
        while !e.idle() {
            for c in e.pump().unwrap() {
                if c.id < 12 {
                    bulk_done += 1;
                } else {
                    live_done += 1;
                    if live_done == 2 {
                        bulk_done_at_live_finish = Some(bulk_done);
                    }
                }
            }
        }
        assert_eq!(bulk_done + live_done, 14);
        bulk_done_at_live_finish.unwrap()
    };
    let fair = run(SchedulerKind::FairShare);
    assert!(
        fair <= 4,
        "fair-share let the bulk client starve the interactive one: \
         {fair}/12 bulk requests finished first"
    );
    let fifo = run(SchedulerKind::Fifo);
    assert!(
        fair < fifo,
        "fair-share ({fair} bulk first) must beat fifo ({fifo} bulk first)"
    );
}

/// Cost-aware scheduling drains cheap requests ahead of expensive ones
/// under contention (small batch bucket), without changing any output.
#[test]
fn cost_aware_finishes_cheap_requests_first() {
    let mk_engine = |kind: SchedulerKind| {
        let be = GmmBackend::new(Gmm::axes(12, 6, 3.0, 0.05)).with_buckets(vec![1, 2, 4]);
        Engine::with_scheduler(be, kind.build(), Admission::unlimited()).unwrap()
    };
    let workload = || {
        let mut reqs: Vec<Request> = (0..6).map(|i| req(i, 300 + i, 10, cfg(2.0))).collect();
        // the cheap requests arrive *last* — fifo would serve them last
        reqs.push(req(6, 306, 10, cond_only()));
        reqs.push(req(7, 307, 10, cond_only()));
        reqs
    };

    let mut e = mk_engine(SchedulerKind::CostAware);
    for r in workload() {
        e.submit(r);
    }
    let mut order = Vec::new();
    while !e.idle() {
        for c in e.pump().unwrap() {
            order.push(c.id);
        }
    }
    let cheap_pos: Vec<usize> = order
        .iter()
        .enumerate()
        .filter(|(_, &id)| id >= 6)
        .map(|(pos, _)| pos)
        .collect();
    assert!(
        cheap_pos.iter().all(|&p| p <= 1),
        "cheap requests must complete first under cost-aware: order {order:?}"
    );

    // and the outputs still match fifo bit-for-bit
    let mut fifo = mk_engine(SchedulerKind::Fifo);
    let fifo_out = fifo.run(workload()).unwrap();
    let mut ca = mk_engine(SchedulerKind::CostAware);
    let ca_out = ca.run(workload()).unwrap();
    for (x, y) in fifo_out.iter().zip(&ca_out) {
        assert_eq!(x.image, y.image, "request {}", x.id);
        assert_eq!(x.nfes, y.nfes);
    }
}

/// The cost signal updates mid-flight: once AG truncates, the request's
/// remaining estimate halves and cost-aware pulls it ahead of untruncated
/// CFG traffic — its completion must not trail the whole CFG pack.
#[test]
fn cost_aware_reacts_to_truncation() {
    let be = GmmBackend::new(Gmm::axes(12, 6, 3.0, 0.05)).with_buckets(vec![1, 2, 4]);
    let mut e = Engine::with_scheduler(
        be,
        SchedulerKind::CostAware.build(),
        Admission::unlimited(),
    )
    .unwrap();
    // 5 expensive CFG requests, then one AG request that truncates early
    for i in 0..5 {
        e.submit(req(i, 400 + i, 12, cfg(2.0)));
    }
    e.submit(req(5, 405, 12, ag(2.0, 0.99)));
    let mut order = Vec::new();
    while !e.idle() {
        for c in e.pump().unwrap() {
            order.push((c.id, c.truncated_at));
        }
    }
    let ag_pos = order.iter().position(|&(id, _)| id == 5).unwrap();
    assert!(order[ag_pos].1.is_some(), "AG must truncate on the oracle");
    assert!(
        ag_pos < order.len() - 1,
        "truncated AG request finished dead last under cost-aware: {order:?}"
    );
}

/// EDF: a late-arriving request with the earliest deadline overtakes the
/// queue; undeadlined traffic runs after every dated request.
#[test]
fn deadline_scheduler_prefers_urgent_requests() {
    let be = GmmBackend::new(Gmm::axes(12, 6, 3.0, 0.05)).with_buckets(vec![1, 2, 4]);
    let mut e = Engine::with_scheduler(
        be,
        SchedulerKind::Deadline.build(),
        Admission::unlimited(),
    )
    .unwrap();
    for i in 0..4 {
        let mut r = req(i, 500 + i, 8, cfg(2.0));
        r.deadline_ms = Some(10_000 + i * 1000);
        e.submit(r);
    }
    // last to arrive, first to be due
    let mut urgent = req(4, 504, 8, cfg(2.0));
    urgent.deadline_ms = Some(100);
    e.submit(urgent);
    let mut order = Vec::new();
    while !e.idle() {
        for c in e.pump().unwrap() {
            order.push(c.id);
        }
    }
    assert_eq!(order[0], 4, "urgent request must finish first: {order:?}");
}

/// Golden reference for the packed-buffer refactor: re-run one request's
/// trajectory with the *seed-era unfused primitives* — per-item `Gmm::eps`
/// (allocating), `Tensor::cfg_combine` + `Tensor::cosine` as separate
/// passes, out-of-place `solver::apply_step` — replicating the engine's
/// exact arithmetic (including the f64→f32→f64 round-trip of the eval
/// time). Completions must match bit-for-bit.
fn reference_sample(
    gmm: &Gmm,
    comp: usize,
    seed: u64,
    steps: usize,
    s: f32,
    gamma_bar: Option<f64>,
) -> (Vec<f32>, Vec<f64>) {
    let dim = gmm.dim;
    let mut x = Rng::new(seed).normal_vec(dim);
    let mut x0_prev = vec![0.0f32; dim];
    let ts = solver::timesteps(steps);
    let mut truncated = false;
    let mut gammas = Vec::new();
    for i in 0..steps {
        let t_r = if i > 0 { Some(ts[i - 1]) } else { None };
        let c = solver::fold_coefs(ts[i], ts[i + 1], t_r);
        // the engine hands the backend an f32 time; mirror the rounding
        let t_eval = ts[i] as f32 as f64;
        let eps = if truncated {
            gammas.push(f64::NAN);
            gmm.eps(&x, t_eval, Some(comp))
        } else {
            let ec = Tensor::new(vec![dim], gmm.eps(&x, t_eval, Some(comp)));
            let eu = Tensor::new(vec![dim], gmm.eps(&x, t_eval, None));
            // the AG signal: Eq. 7's cosine on the x0 re-parameterization
            let (jx, je) = (c.j_x as f32, c.j_eps as f32);
            let xa: Vec<f32> = (0..dim).map(|k| jx * x[k] + je * ec.data[k]).collect();
            let xb: Vec<f32> = (0..dim).map(|k| jx * x[k] + je * eu.data[k]).collect();
            let gamma = Tensor::new(vec![dim], xa).cosine(&Tensor::new(vec![dim], xb));
            gammas.push(gamma);
            if let Some(bar) = gamma_bar {
                if gamma >= bar {
                    truncated = true; // effective from the next step
                }
            }
            Tensor::cfg_combine(&ec, &eu, s).data
        };
        let (xn, x0) = solver::apply_step(&x, &eps, &x0_prev, &c);
        x = xn;
        x0_prev = x0;
    }
    (x0_prev, gammas)
}

/// The packed/pooled/fused execution path must be bit-identical to the
/// unfused reference sampler, for plain CFG and for truncating AG, and the
/// agreement must hold under every scheduler. (The linear-ag leg of the
/// invariance story rides on `every_scheduler_produces_identical_results`.)
#[test]
fn packed_execution_matches_unfused_reference_sampler() {
    let gmm = Gmm::axes(12, 6, 3.0, 0.05);
    let steps = 9;
    let expect = |id: u64, gamma_bar: Option<f64>| {
        let comp = (id % 6) as usize; // req() conditions on token 1 + id%6
        reference_sample(&gmm, comp, 7000 + id, steps, 2.0, gamma_bar)
    };
    for kind in SchedulerKind::ALL {
        let be = GmmBackend::new(gmm.clone());
        let mut e =
            Engine::with_scheduler(be, kind.build(), Admission::unlimited()).unwrap();
        let out = e
            .run(vec![
                req(0, 7000, steps, cfg(2.0)),
                req(1, 7001, steps, ag(2.0, 0.99)),
            ])
            .unwrap();
        assert_eq!(out.len(), 2, "{}", kind.name());
        for c in &out {
            let gamma_bar = if c.id == 1 { Some(0.99) } else { None };
            let (image, gammas) = expect(c.id, gamma_bar);
            assert_eq!(
                c.image,
                image,
                "{}: request {} image diverged from the unfused reference",
                kind.name(),
                c.id
            );
            assert_eq!(c.gammas.len(), gammas.len(), "{}", kind.name());
            for (i, (a, b)) in c.gammas.iter().zip(&gammas).enumerate() {
                assert!(
                    (a.is_nan() && b.is_nan()) || a == b,
                    "{}: request {} gamma[{i}]: engine {a} vs reference {b}",
                    kind.name(),
                    c.id
                );
            }
        }
        // the AG request must actually have exercised the truncated path
        assert!(out[1].truncated_at.is_some(), "{}", kind.name());
    }
}

/// The multi-core execution layer must never touch the numerics: under
/// every scheduler, `--workers 4` (and 2) produces byte-identical
/// completions to `--workers 1`, and both match the *unfused reference
/// sampler* — the same seed-era golden the packed-path test pins — so the
/// whole chain (sharded GMM rows + parallel step completion) is anchored
/// to first-principles math, not just to itself.
#[test]
fn worker_counts_are_bit_identical_under_every_scheduler() {
    let gmm = Gmm::axes(12, 6, 3.0, 0.05);
    let steps = 9;
    // batch ≥ 8: 8 requests × ≤2 evals keeps the 16-bucket batches full
    let workload = || -> Vec<Request> {
        (0..8)
            .map(|id| {
                let policy = if id % 2 == 0 { cfg(2.0) } else { ag(2.0, 0.99) };
                req(id, 7000 + id, steps, policy)
            })
            .collect()
    };
    for kind in SchedulerKind::ALL {
        let run = |workers: usize| {
            let be = GmmBackend::new(gmm.clone());
            let mut e =
                Engine::with_scheduler(be, kind.build(), Admission::unlimited()).unwrap();
            e.set_workers(workers);
            let out = e.run(workload()).unwrap();
            (out, e.batches(), e.items())
        };
        let (base, base_batches, base_items) = run(1);
        for workers in [2usize, 4] {
            let (out, batches, items) = run(workers);
            assert_eq!(batches, base_batches, "{} workers={workers}", kind.name());
            assert_eq!(items, base_items, "{} workers={workers}", kind.name());
            assert_eq!(out.len(), base.len(), "{}", kind.name());
            for (a, b) in out.iter().zip(&base) {
                assert_eq!(a.id, b.id, "{} workers={workers}", kind.name());
                assert_eq!(
                    a.image, b.image,
                    "{} workers={workers}: request {} image diverged",
                    kind.name(),
                    a.id
                );
                assert_eq!(a.nfes, b.nfes, "{} workers={workers}", kind.name());
                assert_eq!(a.truncated_at, b.truncated_at, "{}", kind.name());
                assert_eq!(a.gammas.len(), b.gammas.len(), "{}", kind.name());
                for (x, y) in a.gammas.iter().zip(&b.gammas) {
                    assert!(
                        (x.is_nan() && y.is_nan()) || x == y,
                        "{} workers={workers}: gamma diverged",
                        kind.name()
                    );
                }
            }
        }
        // anchor the parallel engine to the unfused golden sampler
        for c in &base {
            let comp = (c.id % 6) as usize;
            let gamma_bar = if c.id % 2 == 1 { Some(0.99) } else { None };
            let (image, gammas) =
                reference_sample(&gmm, comp, 7000 + c.id, steps, 2.0, gamma_bar);
            assert_eq!(
                c.image,
                image,
                "{}: request {} diverged from the reference sampler",
                kind.name(),
                c.id
            );
            for (i, (a, b)) in c.gammas.iter().zip(&gammas).enumerate() {
                assert!(
                    (a.is_nan() && b.is_nan()) || a == b,
                    "{}: request {} gamma[{i}]",
                    kind.name(),
                    c.id
                );
            }
        }
        // AG requests must actually exercise the truncated (mixed-plan)
        // path inside the parallel completion phase
        assert!(
            base.iter().any(|c| c.truncated_at.is_some()),
            "{}: no AG truncation, the test lost its teeth",
            kind.name()
        );
    }
}

/// Admission budgets shed load without touching in-flight work, and
/// capacity recovers as requests complete.
#[test]
fn admission_sheds_and_recovers_under_load() {
    let adm = Admission {
        max_in_flight: Some(4),
        max_queued_nfes: Some(200),
        ..Admission::unlimited()
    };
    let mut e =
        Engine::with_scheduler(backend(12), SchedulerKind::CostAware.build(), adm).unwrap();
    let mut admitted = 0;
    let mut shed = 0;
    for i in 0..8 {
        match e.try_submit(req(i, 600 + i, 10, cfg(2.0))) {
            Ok(()) => admitted += 1,
            Err(_) => shed += 1,
        }
    }
    assert_eq!(admitted, 4, "in-flight cap");
    assert_eq!(shed, 4);
    let done = e.drain().unwrap();
    assert_eq!(done.len(), 4, "admitted requests complete despite shedding");
    // queue drained → new work admits again
    e.try_submit(req(20, 620, 10, cfg(2.0))).unwrap();
    assert_eq!(e.drain().unwrap().len(), 1);
    assert_eq!(e.telemetry().counter("requests_rejected_total", &[]), 4);
}
