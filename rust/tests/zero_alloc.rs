//! The §Perf acceptance pin: at steady state (after warmup, mid-flight —
//! no admissions, no completions) `Engine::pump()` on the GMM backend
//! performs **zero heap allocations**, under every scheduling discipline.
//!
//! A counting global allocator wraps `System`; the file contains exactly
//! one `#[test]` so no concurrent test can allocate inside the measurement
//! window. Warmup pumps let every reusable buffer reach capacity — the
//! packed [`BatchBuf`]/[`BatchOut`] pair, the scheduler's pop buffer and
//! selection scratch, the engine's [`BufPool`], the GMM responsibility
//! scratch, and the per-request gamma reserves — after which the per-step
//! path must never touch the allocator again. AG truncation is allowed to
//! fire inside the window: plan changes reuse existing capacity.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::sched::{Admission, SchedulerKind};
use adaptive_guidance::sim::gmm::Gmm;

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// 8 mixed cfg/ag requests, long enough that warmup + the measurement
/// window finish well before the first completion.
const STEPS: usize = 48;
const WARMUP_PUMPS: usize = 16;
const MEASURED_PUMPS: usize = 16;

#[test]
fn pump_is_allocation_free_at_steady_state_under_every_scheduler() {
    for kind in SchedulerKind::ALL {
        let be = GmmBackend::new(Gmm::axes(16, 4, 3.0, 0.05));
        let mut e = Engine::with_scheduler(be, kind.build(), Admission::unlimited())
            .expect("engine over the GMM oracle");
        for i in 0..8u64 {
            let policy = if i % 2 == 0 { cfg(2.0) } else { ag(2.0, 0.99) };
            let mut r = Request::new(
                i,
                "gmm",
                vec![1 + (i % 4) as i32, 0, 0, 0],
                900 + i,
                STEPS,
                policy,
            );
            // exercise the fair-share lanes and the deadline keys too
            r.client_id = Some(Arc::from(if i % 2 == 0 { "bulk" } else { "live" }));
            r.deadline_ms = Some(60_000 + i);
            // §Observability: the invariant must hold with tracing ON —
            // lifecycle spans + per-step guidance events are slot writes
            // into storage preallocated at admission/construction
            r.trace = true;
            e.submit(r);
        }

        // warmup: pools, packed buffers and scheduler scratch reach capacity
        let mut done = 0usize;
        for _ in 0..WARMUP_PUMPS {
            done += e.pump().expect("warmup pump").len();
        }
        assert_eq!(done, 0, "warmup completed requests under {}", kind.name());

        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        let mut completed = 0usize;
        for _ in 0..MEASURED_PUMPS {
            completed += e.pump().expect("steady-state pump").len();
        }
        COUNTING.store(false, Ordering::SeqCst);
        let allocs = ALLOCS.load(Ordering::SeqCst);

        assert_eq!(
            completed,
            0,
            "measurement window must stay mid-flight under {}",
            kind.name()
        );
        assert_eq!(
            allocs,
            0,
            "pump() allocated {allocs} time(s) at steady state under `{}` — \
             a per-step allocation crept back into the hot path (see \
             engine.rs §Perf: buffer ownership)",
            kind.name()
        );

        // the workload still drains to correct completions afterwards —
        // and tracing actually recorded: every completion carries its
        // timeline and the ring holds span + guidance events to drain
        let out = e.drain().expect("drain");
        assert_eq!(out.len(), 8, "{}", kind.name());
        assert!(
            out.iter().filter(|c| c.truncated_at.is_some()).count() >= 1,
            "AG requests should truncate on the oracle ({})",
            kind.name()
        );
        assert!(
            out.iter().all(|c| c.timeline.is_some()),
            "traced requests must carry timelines ({})",
            kind.name()
        );
        let spans = e.drain_spans();
        assert!(
            !spans.events.is_empty(),
            "the span ring must hold events after a traced run ({})",
            kind.name()
        );
    }
}
