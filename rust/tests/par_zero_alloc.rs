//! §Perf acceptance pin for the multi-core execution layer: with a
//! 4-lane worker pool attached, steady-state `Engine::pump()` (after
//! warmup, mid-flight — no admissions, no completions) performs **zero
//! heap allocations across every thread**, under every scheduling
//! discipline.
//!
//! This is the parallel sibling of `zero_alloc.rs` (which pins the
//! serial engine and must keep exactly one `#[test]`; so must this file
//! — the counting global allocator sees every thread in the process, and
//! a concurrently-running test would pollute the window). It pins the
//! pool's dispatch contract: publishing a region, claiming rows,
//! lane-local GMM scratch, the pre-staged `StepBufs`, and the batched
//! pool returns all reuse warm capacity — nothing allocates per job.
//!
//! Worker threads park on a `Condvar` between regions and the per-item
//! path is lock-free atomics, so the only allocation candidates are the
//! ones this test exists to catch.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::sched::{Admission, SchedulerKind};
use adaptive_guidance::sim::gmm::Gmm;

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// 8 mixed cfg/ag requests, long enough that warmup + the measurement
/// window finish well before the first completion (mirrors zero_alloc.rs).
const STEPS: usize = 48;
const WARMUP_PUMPS: usize = 16;
const MEASURED_PUMPS: usize = 16;
const WORKERS: usize = 4;

#[test]
fn parallel_pump_is_allocation_free_at_steady_state() {
    for kind in SchedulerKind::ALL {
        let be = GmmBackend::new(Gmm::axes(16, 4, 3.0, 0.05));
        let mut e = Engine::with_scheduler(be, kind.build(), Admission::unlimited())
            .expect("engine over the GMM oracle");
        e.set_workers(WORKERS);
        for i in 0..8u64 {
            let policy = if i % 2 == 0 { cfg(2.0) } else { ag(2.0, 0.99) };
            let mut r = Request::new(
                i,
                "gmm",
                vec![1 + (i % 4) as i32, 0, 0, 0],
                900 + i,
                STEPS,
                policy,
            );
            // exercise the fair-share lanes and the deadline keys too
            r.client_id = Some(Arc::from(if i % 2 == 0 { "bulk" } else { "live" }));
            r.deadline_ms = Some(60_000 + i);
            // §Observability: the invariant must hold with tracing ON
            r.trace = true;
            e.submit(r);
        }

        // warmup: pools, packed buffers, lane scratches, StepBufs staging
        // and the workers' own lazy thread state all reach capacity
        let mut done = 0usize;
        for _ in 0..WARMUP_PUMPS {
            done += e.pump().expect("warmup pump").len();
        }
        assert_eq!(done, 0, "warmup completed requests under {}", kind.name());

        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        let mut completed = 0usize;
        for _ in 0..MEASURED_PUMPS {
            completed += e.pump().expect("steady-state pump").len();
        }
        COUNTING.store(false, Ordering::SeqCst);
        let allocs = ALLOCS.load(Ordering::SeqCst);

        assert_eq!(
            completed,
            0,
            "measurement window must stay mid-flight under {}",
            kind.name()
        );
        assert_eq!(
            allocs,
            0,
            "parallel pump() allocated {allocs} time(s) at steady state under \
             `{}` with {WORKERS} workers — the pool's dispatch or the sharded \
             row/slot path allocates per job (see exec/pool.rs and \
             engine.rs §Perf)",
            kind.name()
        );

        // the workload still drains to correct completions afterwards,
        // with every traced request's timeline recorded
        let out = e.drain().expect("drain");
        assert_eq!(out.len(), 8, "{}", kind.name());
        assert!(
            out.iter().filter(|c| c.truncated_at.is_some()).count() >= 1,
            "AG requests should truncate on the oracle ({})",
            kind.name()
        );
        assert!(
            out.iter().all(|c| c.timeline.is_some()),
            "traced requests must carry timelines ({})",
            kind.name()
        );
        assert!(!e.drain_spans().events.is_empty(), "{}", kind.name());
    }
}
