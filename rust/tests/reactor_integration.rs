//! Reactor front-end integration (§Scale): every test drives the *real*
//! serving loop — `serve_on` on an ephemeral port with `--net reactor` —
//! over real TCP, and checks the protocol invariants the reactor adds:
//!
//! * completions are byte-identical to the threaded front end (same
//!   renderers, same refusal lines — only wall-clock `ms` may differ);
//! * wire-id-tagged requests pipeline and replies match by echoed id,
//!   id-less requests keep the historical serialized order;
//! * `{"cmd": "cancel", "id": X}` revokes queued/in-flight work, refunds
//!   the admission budget, and answers `"code": "canceled"`;
//! * opted-in requests stream `{"event": "progress"}` lines;
//! * one event-loop thread serves ≥1024 concurrent connections.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::chaos::{self, completion_digest, read_trace, Director, ReplayConfig};
use adaptive_guidance::coordinator::spec::PolicyRegistry;
use adaptive_guidance::fleet::{Fleet, JobReply};
use adaptive_guidance::sched::Admission;
use adaptive_guidance::server::{parse_request_line, serve_on, NetMode, ServerConfig};
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::util::json::{self, Value};

/// Fast backend for throughput-shaped tests (a request is milliseconds).
fn fast_gmm() -> Gmm {
    Gmm::axes(8, 3, 3.0, 0.05)
}

/// Deliberately slow backend (the chaos suite's), so long-step requests
/// are still grinding when cancels and shard kills land.
fn slow_gmm() -> Gmm {
    Gmm::axes(64, 6, 3.0, 0.05)
}

fn base_cfg() -> ServerConfig {
    ServerConfig {
        model: "gmm".into(),
        shards: 2,
        workers: 2,
        net: NetMode::Reactor,
        ..Default::default()
    }
}

/// Bind an ephemeral port and run the production `serve_on` dispatch
/// (reactor or threads, per `scfg.net`) against a GMM fleet.
fn spawn_server(
    mut scfg: ServerConfig,
    gmm: fn() -> Gmm,
) -> (SocketAddr, Arc<Fleet>, ServerConfig) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    scfg.addr = addr.to_string();
    let fleet = Arc::new(Fleet::launch(
        move |_shard| Ok(GmmBackend::new(gmm())),
        scfg.fleet_config(),
    ));
    let registry = Arc::new(PolicyRegistry::builtin());
    {
        let fleet = fleet.clone();
        let scfg = scfg.clone();
        std::thread::spawn(move || {
            let _ = serve_on(listener, fleet, scfg, registry);
        });
    }
    (addr, fleet, scfg)
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn send(stream: &mut TcpStream, line: &str) {
    writeln!(stream, "{line}").unwrap();
}

fn read_line(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    let n = reader.read_line(&mut line).unwrap();
    assert!(n > 0, "server closed the connection mid-conversation");
    line.trim().to_owned()
}

/// Read the next non-progress reply and require it to echo `id`.
fn read_for_id(reader: &mut BufReader<TcpStream>, id: u64) -> Value {
    loop {
        let line = read_line(reader);
        let v = json::parse(&line).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"));
        if v.get("event").and_then(Value::as_str) == Some("progress") {
            continue;
        }
        assert_eq!(
            v.get("id").and_then(Value::as_f64),
            Some(id as f64),
            "expected id {id}, got {line}"
        );
        return v;
    }
}

/// Serve `request_line` on a fresh fault-free single-shard fleet and
/// return its completion digest (the golden value).
fn clean_digest(request_line: &str, scfg: &ServerConfig, gmm: fn() -> Gmm) -> String {
    let clean = ServerConfig {
        shards: 1,
        ..scfg.clone()
    };
    let fleet = Fleet::launch(move |_shard| Ok(GmmBackend::new(gmm())), clean.fleet_config());
    let (req, _) = parse_request_line(request_line, &clean, &PolicyRegistry::builtin())
        .unwrap_or_else(|e| panic!("golden parse of {request_line}: {e}"));
    let rx = fleet.submit(req).unwrap();
    match rx.recv().unwrap() {
        JobReply::Done(c, _) => completion_digest(&c),
        JobReply::Error(line) => panic!("clean run refused {request_line}: {line}"),
        JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
    }
}

/// Strip the wall-clock `ms` field — the only part of a reply that may
/// legitimately differ between two servings of the same request.
fn sans_ms(line: &str) -> String {
    let mut v = json::parse(line).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"));
    if let Value::Obj(m) = &mut v {
        m.remove("ms");
    }
    json::to_string(&v)
}

/// The largest value of a counter family in the fleet's merged
/// telemetry, matching `name` exactly or `name{...}` (max, not sum:
/// merged telemetry may carry both a fleet total and per-shard copies).
fn counter_max(fleet: &Fleet, name: &str) -> f64 {
    let stats = fleet.stats_json().unwrap();
    let counters = stats.req("telemetry").req("counters");
    let Value::Obj(m) = counters else {
        panic!("counters is not an object")
    };
    let prefix = format!("{name}{{");
    m.iter()
        .filter(|(k, _)| k.as_str() == name || k.starts_with(&prefix))
        .filter_map(|(_, v)| v.as_f64())
        .fold(0.0, f64::max)
}

/// Fleet-wide queued-NFE estimate from the stats the server publishes.
fn queued_nfes(fleet: &Fleet) -> f64 {
    fleet.stats_json().unwrap().req("queued_nfes").as_f64().unwrap()
}

/// The same conversation served by both front ends must render the same
/// bytes (modulo `ms`): completions, image payloads, wire-id echoes,
/// parse refusals, unknown-policy refusals.
#[test]
fn reactor_and_threads_render_identical_replies() {
    let conversation = [
        r#"{"prompt": "red circle", "policy": "cfg", "steps": 6, "guidance": 2.0, "seed": 1, "image": true}"#,
        "this is not json",
        r#"{"prompt": "x", "policy": "no-such-policy", "steps": 4}"#,
        r#"{"id": 9, "prompt": "green triangle", "policy": "ag", "steps": 8, "guidance": 2.0, "seed": 2, "image": true}"#,
        r#"{"id": "job-a", "prompt": "red circle", "policy": "cfg", "steps": 120000, "guidance": 2.0, "seed": 3}"#,
    ];
    let mut renderings: Vec<Vec<String>> = Vec::new();
    for net in [NetMode::Reactor, NetMode::Threads] {
        let (addr, _fleet, _) = spawn_server(
            ServerConfig {
                net,
                ..base_cfg()
            },
            fast_gmm,
        );
        let (mut w, mut r) = connect(addr);
        let mut replies = Vec::new();
        for line in conversation {
            send(&mut w, line);
            replies.push(sans_ms(&read_line(&mut r)));
        }
        renderings.push(replies);
    }
    assert_eq!(
        renderings[0], renderings[1],
        "reactor and threads diverged on the same conversation"
    );
    // spot-check the interesting shapes
    let replies = &renderings[0];
    assert!(replies[0].contains("\"image\""), "{}", replies[0]);
    let bad = json::parse(&replies[1]).unwrap();
    assert_eq!(bad.req("code").as_str(), Some("invalid_request"));
    let idle = json::parse(&replies[3]).unwrap();
    assert_eq!(idle.req("id").as_f64(), Some(9.0), "wire id not echoed");
    // a string wire id is echoed verbatim too (here: on a step-count
    // refusal, which exceeds MAX_STEPS)
    let refused = json::parse(&replies[4]).unwrap();
    assert_eq!(refused.req("id").as_str(), Some("job-a"));
    assert!(refused.get("error").is_some());
}

/// Four wire ids written back-to-back on one connection: the reactor
/// keeps them all in flight, every reply echoes its id, and each
/// completion digest-matches a clean single-shard run.
#[test]
fn pipelined_wire_ids_all_complete_and_match_clean() {
    let (addr, _fleet, scfg) = spawn_server(base_cfg(), fast_gmm);
    let lines: Vec<String> = (0..4)
        .map(|i| {
            format!(
                r#"{{"id": {i}, "prompt": "red circle", "policy": "{}", "steps": {}, "guidance": 2.0, "seed": {}, "image": true}}"#,
                if i % 2 == 0 { "cfg" } else { "ag" },
                5 + i,
                30 + i,
            )
        })
        .collect();
    let (mut w, mut r) = connect(addr);
    for line in &lines {
        send(&mut w, line);
    }
    let mut got: HashMap<u64, Value> = HashMap::new();
    while got.len() < lines.len() {
        let line = read_line(&mut r);
        let v = json::parse(&line).unwrap();
        if v.get("event").and_then(Value::as_str) == Some("progress") {
            continue;
        }
        let id = v.req("id").as_f64().unwrap() as u64;
        assert!(got.insert(id, v).is_none(), "id {id} replied twice");
    }
    for (i, line) in lines.iter().enumerate() {
        let v = &got[&(i as u64)];
        assert!(v.get("error").is_none(), "{line} refused: {v:?}");
        assert_eq!(
            chaos::reply_digest(v).unwrap(),
            clean_digest(line, &scfg, fast_gmm),
            "pipelined completion diverged from the clean run: {line}"
        );
    }
}

/// Id-less requests keep the historical contract: dispatch serializes,
/// so replies come back in exact arrival order even when the client
/// writes the whole burst up front.
#[test]
fn idless_requests_serialize_in_arrival_order() {
    let (addr, _fleet, _) = spawn_server(base_cfg(), fast_gmm);
    let (mut w, mut r) = connect(addr);
    // distinct step counts → distinct nfes in the replies
    for steps in [4usize, 6, 8] {
        send(
            &mut w,
            &format!(
                r#"{{"prompt": "red circle", "policy": "cfg", "steps": {steps}, "guidance": 2.0, "seed": 5}}"#
            ),
        );
    }
    for steps in [4usize, 6, 8] {
        let v = json::parse(&read_line(&mut r)).unwrap();
        assert_eq!(
            v.req("nfes").as_f64(),
            Some((steps * 2) as f64),
            "reply out of arrival order"
        );
    }
}

/// Two live requests under one wire id are unmatchable, so the second
/// is refused up front — and a mid-flight cancel resolves the first.
#[test]
fn duplicate_wire_id_is_refused_and_cancel_resolves_the_original() {
    let (addr, _fleet, _) = spawn_server(
        ServerConfig {
            shards: 1,
            ..base_cfg()
        },
        slow_gmm,
    );
    let (mut w, mut r) = connect(addr);
    send(
        &mut w,
        r#"{"id": 7, "prompt": "red circle", "policy": "cfg", "steps": 90000, "guidance": 2.0, "seed": 6}"#,
    );
    send(
        &mut w,
        r#"{"id": 7, "prompt": "red circle", "policy": "cfg", "steps": 4, "guidance": 2.0, "seed": 6}"#,
    );
    let dup = read_for_id(&mut r, 7);
    assert_eq!(dup.req("code").as_str(), Some("invalid_request"));
    assert!(
        dup.req("error").as_str().unwrap().contains("already in flight"),
        "{dup:?}"
    );
    send(&mut w, r#"{"cmd": "cancel", "id": 7}"#);
    let canceled = read_for_id(&mut r, 7);
    assert_eq!(canceled.req("code").as_str(), Some("canceled"));
}

/// The cancellation acceptance path: canceling a request drops the
/// queued-NFE gauge, refunds the fleet admission budget (a request the
/// budget refused before is admitted after), and increments
/// `requests_canceled_total`. Unknown ids get `"code": "unknown_id"`.
#[test]
fn cancel_refunds_admission_and_counts() {
    let (addr, fleet, _) = spawn_server(
        ServerConfig {
            shards: 1,
            admission: Admission {
                max_queued_nfes: Some(400_000),
                ..Admission::unlimited()
            },
            ..base_cfg()
        },
        slow_gmm,
    );
    let (mut w, mut r) = connect(addr);
    // cfg worst case is 2 NFEs/step: id 1 reserves 200k, id 2 180k
    send(
        &mut w,
        r#"{"id": 1, "prompt": "red circle", "policy": "cfg", "steps": 100000, "guidance": 2.0, "seed": 11}"#,
    );
    send(
        &mut w,
        r#"{"id": 2, "prompt": "green triangle", "policy": "cfg", "steps": 90000, "guidance": 2.0, "seed": 12}"#,
    );
    // id 3 (60k) would put the budget at 440k > 400k: refused, id echoed
    send(
        &mut w,
        r#"{"id": 3, "prompt": "blue square", "policy": "cfg", "steps": 30000, "guidance": 2.0, "seed": 13}"#,
    );
    let refused = read_for_id(&mut r, 3);
    assert_eq!(refused.req("code").as_str(), Some("queue_full"));
    // the admitted work is on the engine-published gauge (poll: the
    // router's reservation lands on the gauge once the shard admits)
    let deadline = Instant::now() + Duration::from_secs(5);
    while queued_nfes(&fleet) < 250_000.0 {
        assert!(Instant::now() < deadline, "queued-NFE gauge never rose");
        std::thread::sleep(Duration::from_millis(2));
    }
    // cancel something that was never admitted → unknown_id
    send(&mut w, r#"{"cmd": "cancel", "id": 3}"#);
    assert_eq!(read_for_id(&mut r, 3).req("code").as_str(), Some("unknown_id"));
    // cancel id 2: the canceled reply resolves the id, the gauge drops
    send(&mut w, r#"{"cmd": "cancel", "id": 2}"#);
    assert_eq!(read_for_id(&mut r, 2).req("code").as_str(), Some("canceled"));
    let deadline = Instant::now() + Duration::from_secs(5);
    while queued_nfes(&fleet) > 220_000.0 {
        assert!(Instant::now() < deadline, "queued-NFE gauge never dropped");
        std::thread::sleep(Duration::from_millis(5));
    }
    // the refund re-opens the budget: more work is admitted now (no
    // immediate reply), proven by its cancel answering `canceled`
    send(
        &mut w,
        r#"{"id": 4, "prompt": "blue square", "policy": "cfg", "steps": 10000, "guidance": 2.0, "seed": 13}"#,
    );
    send(&mut w, r#"{"cmd": "cancel", "id": 4}"#);
    assert_eq!(read_for_id(&mut r, 4).req("code").as_str(), Some("canceled"));
    send(&mut w, r#"{"cmd": "cancel", "id": 1}"#);
    assert_eq!(read_for_id(&mut r, 1).req("code").as_str(), Some("canceled"));
    assert_eq!(counter_max(&fleet, "requests_canceled_total"), 3.0);
    let deadline = Instant::now() + Duration::from_secs(5);
    while queued_nfes(&fleet) > 0.0 {
        assert!(Instant::now() < deadline, "gauge never returned to zero");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// `"progress": true` streams per-step `{"event": "progress"}` lines:
/// the wire id is echoed on every sample, step/of/gamma/nfes ride
/// along, and the completion still arrives at the end.
#[test]
fn progress_streams_per_step_events() {
    let (addr, _fleet, _) = spawn_server(
        ServerConfig {
            shards: 1,
            ..base_cfg()
        },
        fast_gmm,
    );
    let (mut w, mut r) = connect(addr);
    send(
        &mut w,
        r#"{"id": 5, "prompt": "red circle", "policy": "cfg", "steps": 64, "guidance": 2.0, "seed": 9, "progress": true}"#,
    );
    let mut samples = 0usize;
    let completion = loop {
        let v = json::parse(&read_line(&mut r)).unwrap();
        if v.get("event").and_then(Value::as_str) == Some("progress") {
            assert_eq!(v.req("id").as_f64(), Some(5.0), "progress id not echoed");
            let step = v.req("step").as_f64().unwrap();
            let of = v.req("of").as_f64().unwrap();
            assert!(step < of, "step {step} of {of} (0-based)");
            assert_eq!(of, 64.0);
            assert!(v.req("nfes").as_f64().unwrap() >= 1.0);
            assert!(v.get("gamma").is_some());
            samples += 1;
            continue;
        }
        break v;
    };
    assert!(samples >= 1, "no progress event survived to the wire");
    assert_eq!(completion.req("id").as_f64(), Some(5.0));
    assert!(completion.get("error").is_none(), "{completion:?}");
    // a request that does NOT opt in gets no progress lines at all
    send(
        &mut w,
        r#"{"prompt": "red circle", "policy": "cfg", "steps": 16, "guidance": 2.0, "seed": 10}"#,
    );
    let v = json::parse(&read_line(&mut r)).unwrap();
    assert!(v.get("event").is_none(), "unrequested progress: {v:?}");
    assert!(v.get("error").is_none());
}

/// §Scale acceptance: ≥1024 concurrent connections, all held open at
/// once with a request in flight on each, served closed-loop by the one
/// event-loop thread.
#[test]
fn a_thousand_connections_share_one_reactor() {
    const CONNS: usize = 1024;
    let (addr, fleet, _) = spawn_server(base_cfg(), fast_gmm);
    let mut socks = Vec::with_capacity(CONNS);
    for _ in 0..CONNS {
        socks.push(connect(addr));
    }
    for (i, (w, _)) in socks.iter_mut().enumerate() {
        send(
            w,
            &format!(
                r#"{{"id": {i}, "prompt": "red circle", "policy": "cfg", "steps": 2, "guidance": 2.0, "seed": {i}}}"#
            ),
        );
    }
    for (i, (_, r)) in socks.iter_mut().enumerate() {
        let v = read_for_id(r, i as u64);
        assert!(v.get("error").is_none(), "conn {i} refused: {v:?}");
        assert_eq!(v.req("nfes").as_f64(), Some(4.0));
    }
    // every connection is still open and serviceable after the burst
    let (w, r) = &mut socks[CONNS - 1];
    send(w, r#"{"cmd": "stats"}"#);
    let stats = json::parse(&read_line(r)).unwrap();
    assert_eq!(stats.req("shards").as_f64(), Some(2.0));
    drop(socks);
    // the reactor reaps them; the fleet survives
    assert!(fleet.stats_json().is_ok());
}

/// The pipelined chaos scenario: four wire ids on one connection racing
/// a mid-flight cancel and a shard kill. The canceled id answers
/// `"code": "canceled"`, the killed shard's id answers `shard_failed`,
/// and the surviving ids complete byte-identical to a clean run.
#[test]
fn scenario_pipelined_kill() {
    let (addr, fleet, scfg) = spawn_server(base_cfg(), slow_gmm);
    let script = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("scenarios")
            .join("pipelined_kill.txt"),
    )
    .unwrap();
    let mut d = Director::new(&fleet, addr);
    d.run(&script).unwrap();
    assert!(
        counter_max(&fleet, "requests_canceled_total") >= 1.0,
        "the cancel never reached an engine"
    );
    let m = fleet.metrics_prometheus().unwrap();
    assert!(m.contains(r#"shard_died_total{shard="0"} 1"#), "{m}");
    assert!(m.contains("fleet_shards_alive 1"), "{m}");
    // ids 3 and 4 carried images: digest-check both against clean runs
    let mut checked = 0;
    for reply in &d.replies {
        let Some(digest) = chaos::reply_digest(&reply.value) else {
            continue;
        };
        assert_eq!(
            digest,
            clean_digest(&reply.request_line, &scfg, slow_gmm),
            "survivor diverged: {}",
            reply.request_line
        );
        checked += 1;
    }
    assert_eq!(checked, 2, "both pipelined survivors must digest-check");
}

/// Capture → pipelined replay round trip against the reactor: serve the
/// sample trace with `--trace-out`, then replay the capture with
/// `--pipeline 4` against a fresh reactor server. Every reply matches
/// its captured digest — pipelining changes reply *order*, never bytes.
#[test]
fn pipelined_replay_round_trips_digests() {
    let capture = std::env::temp_dir().join(format!(
        "agd_reactor_capture_{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&capture);
    let (addr_a, _fleet_a, _) = spawn_server(
        ServerConfig {
            trace_out: Some(capture.to_str().unwrap().to_owned()),
            ..base_cfg()
        },
        fast_gmm,
    );
    let sample = read_trace(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("scenarios")
            .join("sample_trace.jsonl")
            .to_str()
            .unwrap(),
    )
    .unwrap();
    let outcome = chaos::replay(
        &sample,
        &ReplayConfig {
            addr: addr_a.to_string(),
            speed: 50.0,
            connections: 2,
            pipeline: 4,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(outcome.sent, sample.len());
    assert_eq!(outcome.completed, sample.len(), "shed: {:?}", outcome.shed);
    assert_eq!(outcome.transport_errors, 0);

    let captured = read_trace(capture.to_str().unwrap()).unwrap();
    assert_eq!(captured.len(), sample.len());
    assert!(captured.iter().all(|r| r.digest.is_some()));

    let (addr_b, _fleet_b, _) = spawn_server(base_cfg(), fast_gmm);
    let outcome = chaos::replay(
        &captured,
        &ReplayConfig {
            addr: addr_b.to_string(),
            speed: 50.0,
            connections: 2,
            pipeline: 4,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(outcome.completed, captured.len(), "shed: {:?}", outcome.shed);
    assert_eq!(outcome.digest_checked, captured.len());
    assert_eq!(outcome.digest_mismatches, 0);
    let _ = std::fs::remove_file(&capture);
}
