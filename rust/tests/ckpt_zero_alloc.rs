//! §Robustness acceptance pin: checkpointing stays off the allocation
//! hot path. With `--checkpoint-steps 1` — the most aggressive setting,
//! a snapshot after *every* completed denoising step — the steady-state
//! pump must make zero heap allocations: capture buffers are sized once
//! at admission ([`CheckpointStore::register`]) and every per-step
//! capture is `clear()` + `extend_from_slice` into retained capacity.
//!
//! Same shape as `zero_alloc.rs` / `fault_zero_alloc.rs`: a counting
//! global allocator over `System`, exactly one `#[test]` so nothing else
//! allocates inside the measurement window, warmup pumps to capacity,
//! then a measured window asserting zero allocations.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::sched::{Admission, SchedulerKind};
use adaptive_guidance::sim::gmm::Gmm;

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

const STEPS: usize = 48;
const WARMUP_PUMPS: usize = 16;
const MEASURED_PUMPS: usize = 16;

#[test]
fn checkpoint_armed_pump_is_allocation_free() {
    let be = GmmBackend::new(Gmm::axes(16, 4, 3.0, 0.05));
    let mut e = Engine::with_scheduler(
        be,
        SchedulerKind::Fifo.build(),
        Admission::unlimited(),
    )
    .expect("engine over the GMM oracle");
    // checkpoint after every completed step — the heaviest configuration
    e.set_checkpoints(1);
    for i in 0..8u64 {
        let policy = if i % 2 == 0 { cfg(2.0) } else { ag(2.0, 0.99) };
        let r = Request::new(
            i,
            "gmm",
            vec![1 + (i % 4) as i32, 0, 0, 0],
            900 + i,
            STEPS,
            policy,
        );
        e.submit(r);
    }

    // warmup: buffer pools, batch buffers, scheduler state, checkpoint
    // slots and the checkpoint_bytes histogram all reach capacity here
    let mut done = 0usize;
    for _ in 0..WARMUP_PUMPS {
        done += e.pump().expect("warmup pump").len();
    }
    assert_eq!(done, 0, "warmup must stay mid-flight");

    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    let mut completed = 0usize;
    for _ in 0..MEASURED_PUMPS {
        completed += e.pump().expect("steady-state pump").len();
    }
    COUNTING.store(false, Ordering::SeqCst);
    let allocs = ALLOCS.load(Ordering::SeqCst);

    assert_eq!(completed, 0, "measurement window must stay mid-flight");
    assert_eq!(
        allocs, 0,
        "checkpoint-armed pump() allocated {allocs} time(s) at steady state \
         — captures must be swap-don't-copy into buffers sized at admission"
    );

    // and the workload still drains to correct completions
    let out = e.drain().expect("drain");
    assert_eq!(out.len(), 8);
}
