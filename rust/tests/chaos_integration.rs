//! Chaos + replay integration (§Robustness): every test drives the *real*
//! serving loop — `serve_on` on an ephemeral port, real TCP connections,
//! a real [`Fleet`] handle for fault injection — from the scenario corpus
//! in `scenarios/*.txt`.
//!
//! The invariant under test is the one the whole stack is built on:
//! faults change *who* gets served (structured shed codes, closed
//! connections), never *what* a survivor is served. Survivor completions
//! are digest-compared against a fresh, fault-free single-shard run of
//! the same request line.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::chaos::{
    self, completion_digest, read_trace, reply_digest, Director, FaultPlan, FaultSpec,
    FaultyBackend, ReplayConfig,
};
use adaptive_guidance::coordinator::spec::PolicyRegistry;
use adaptive_guidance::fleet::{Fleet, JobReply, Placement};
use adaptive_guidance::sched::SchedulerKind;
use adaptive_guidance::server::{parse_request_line, serve_on, ServerConfig};
use adaptive_guidance::sim::gmm::Gmm;
use adaptive_guidance::util::json;

/// The chaos backend: deliberately *slow* (dim 64, 6 components) so the
/// long-step scenario requests are still in flight when faults land.
fn chaos_gmm() -> Gmm {
    Gmm::axes(64, 6, 3.0, 0.05)
}

/// Baseline harness config; scenarios override the knobs they exercise.
fn base_cfg() -> ServerConfig {
    ServerConfig {
        model: "gmm".into(),
        shards: 2,
        workers: 2,
        ..Default::default()
    }
}

/// Bind an ephemeral port and run the production accept loop against a
/// GMM fleet; returns the address plus the fleet handle faults go into.
fn spawn_chaos_server(mut scfg: ServerConfig) -> (std::net::SocketAddr, Arc<Fleet>, ServerConfig) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    scfg.addr = addr.to_string();
    // mirror serve_with_registry: every shard backend behind the fault
    // wrapper, plan installed (disarmed unless the scenario arms it)
    let plan = Arc::new(FaultPlan::default());
    if let Some(spec) = &scfg.fault_spec {
        plan.arm(FaultSpec::parse(spec).unwrap());
    }
    let shard_plan = plan.clone();
    let fleet = Arc::new(Fleet::launch(
        move |shard| {
            Ok(FaultyBackend::with_shard(
                GmmBackend::new(chaos_gmm()),
                shard_plan.clone(),
                shard as u64,
            ))
        },
        scfg.fleet_config(),
    ));
    fleet.set_fault_plan(plan);
    let registry = Arc::new(PolicyRegistry::builtin());
    {
        let fleet = fleet.clone();
        let scfg = scfg.clone();
        std::thread::spawn(move || {
            let _ = serve_on(listener, fleet, scfg, registry);
        });
    }
    (addr, fleet, scfg)
}

/// Load one scenario from the corpus the harness ships with.
fn scenario(name: &str) -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("scenarios")
        .join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

/// Serve `request_line` on a fresh fault-free single-shard fleet and
/// return its completion digest — the golden value a chaos survivor must
/// match byte for byte.
fn clean_digest(request_line: &str, scfg: &ServerConfig) -> String {
    let clean = ServerConfig {
        shards: 1,
        ..scfg.clone()
    };
    let fleet = Fleet::launch(
        |_shard| Ok(GmmBackend::new(chaos_gmm())),
        clean.fleet_config(),
    );
    let (req, _) = parse_request_line(request_line, &clean, &PolicyRegistry::builtin())
        .unwrap_or_else(|e| panic!("golden parse of {request_line}: {e}"));
    let rx = fleet.submit(req).unwrap();
    match rx.recv().unwrap() {
        JobReply::Done(c, _) => completion_digest(&c),
        JobReply::Error(line) => panic!("clean run refused {request_line}: {line}"),
        JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
    }
}

/// Every `expect-ok` reply that carried an image must digest-match a
/// clean run of its own request line.
fn assert_survivors_match_clean(replies: &[chaos::Reply], scfg: &ServerConfig) {
    let mut checked = 0;
    for r in replies {
        let Some(digest) = reply_digest(&r.value) else {
            continue;
        };
        assert_eq!(
            digest,
            clean_digest(&r.request_line, scfg),
            "survivor completion diverged from the clean run: {}",
            r.request_line
        );
        checked += 1;
    }
    assert!(checked > 0, "no survivor carried an image to digest-check");
}

#[test]
fn scenario_kill_shard_mid_flight() {
    let (addr, fleet, scfg) = spawn_chaos_server(base_cfg());
    let mut d = Director::new(&fleet, addr);
    d.run(&scenario("kill_shard_mid_flight.txt")).unwrap();
    // the fault is visible in telemetry: the injection, the death, and
    // the shrunken fleet
    let m = fleet.metrics_prometheus().unwrap();
    assert!(m.contains(r#"chaos_kill_shard_total{shard="0"} 1"#), "{m}");
    assert!(m.contains(r#"shard_died_total{shard="0"} 1"#), "{m}");
    assert!(m.contains("fleet_shards_alive 1"), "{m}");
    assert!(m.contains("fleet_shards 2"), "{m}");
    // a second injection into the same shard is a no-op, reported as such
    assert!(!fleet.kill_shard(0), "dead shard must not be killable twice");
    assert_survivors_match_clean(&d.replies, &scfg);
}

#[test]
fn scenario_disconnect_mid_request() {
    let (addr, fleet, scfg) = spawn_chaos_server(base_cfg());
    let mut d = Director::new(&fleet, addr);
    d.run(&scenario("disconnect_mid_request.txt")).unwrap();
    // the vanished client cost nothing: both shards alive, no deaths
    let m = fleet.metrics_prometheus().unwrap();
    assert!(m.contains("fleet_shards_alive 2"), "{m}");
    assert!(!m.contains("shard_died_total"), "{m}");
    assert_survivors_match_clean(&d.replies, &scfg);
}

#[test]
fn scenario_slowloris() {
    let (addr, fleet, scfg) = spawn_chaos_server(ServerConfig {
        read_timeout_ms: 300,
        ..base_cfg()
    });
    let mut d = Director::new(&fleet, addr);
    d.run(&scenario("slowloris.txt")).unwrap();
    let m = fleet.metrics_prometheus().unwrap();
    assert!(m.contains(r#"conn_timeout_total{kind="midline"} 1"#), "{m}");
    assert!(m.contains("fleet_shards_alive 2"), "{m}");
    assert_survivors_match_clean(&d.replies, &scfg);
}

#[test]
fn scenario_malformed_frames() {
    let (addr, fleet, scfg) = spawn_chaos_server(ServerConfig {
        max_line_bytes: 4096,
        ..base_cfg()
    });
    let mut d = Director::new(&fleet, addr);
    d.run(&scenario("malformed_frames.txt")).unwrap();
    let m = fleet.metrics_prometheus().unwrap();
    assert!(m.contains(r#"conn_bad_line_total{kind="utf8"} 1"#), "{m}");
    assert!(m.contains(r#"conn_bad_line_total{kind="oversized"} 1"#), "{m}");
    assert!(m.contains("fleet_shards_alive 2"), "{m}");
    assert_survivors_match_clean(&d.replies, &scfg);
}

#[test]
fn scenario_drain_under_load() {
    let (addr, fleet, scfg) = spawn_chaos_server(base_cfg());
    let mut d = Director::new(&fleet, addr);
    d.run(&scenario("drain_under_load.txt")).unwrap();
    assert!(fleet.is_draining());
    assert_survivors_match_clean(&d.replies, &scfg);
}

/// §Robustness: a transient-fault storm armed by the director is fully
/// absorbed — every request completes (byte-identical to a clean run),
/// no shard dies, and `fault clear` disarms the live plan.
#[test]
fn scenario_backend_fault_storm() {
    let (addr, fleet, scfg) = spawn_chaos_server(ServerConfig {
        max_batch_retries: 6,
        ..base_cfg()
    });
    let mut d = Director::new(&fleet, addr);
    d.run(&scenario("backend_fault_storm.txt")).unwrap();
    let plan = fleet.fault_plan().unwrap();
    assert!(plan.errors() > 0, "the storm never injected a fault");
    assert!(!plan.armed(), "`fault clear` must disarm the live plan");
    let m = fleet.metrics_prometheus().unwrap();
    assert!(m.contains("batch_retries_total"), "{m}");
    assert!(!m.contains("shard_died_total"), "{m}");
    assert!(m.contains("fleet_shards_alive 2"), "{m}");
    assert_survivors_match_clean(&d.replies, &scfg);
}

/// §Robustness: a killed shard comes back. Single-shard fleet with
/// `--shard-respawn`: the post-respawn request can only be served by the
/// rebuilt shard, and its completion matches a clean run byte for byte.
#[test]
fn scenario_shard_respawn() {
    let (addr, fleet, scfg) = spawn_chaos_server(ServerConfig {
        shards: 1,
        shard_respawn: true,
        ..base_cfg()
    });
    let mut d = Director::new(&fleet, addr);
    d.run(&scenario("shard_respawn.txt")).unwrap();
    let m = fleet.metrics_prometheus().unwrap();
    assert!(m.contains(r#"shard_died_total{shard="0"} 1"#), "{m}");
    assert!(m.contains(r#"shard_respawned_total{shard="0"} 1"#), "{m}");
    assert!(m.contains("fleet_shards_alive 1"), "{m}");
    assert_survivors_match_clean(&d.replies, &scfg);
}

/// §Robustness: the tentpole scenario — a shard dies mid-trajectory with
/// `--checkpoint-steps 1` armed, and the victim request *completes* on a
/// survivor (resumed from its checkpoint, digest-identical to a clean
/// run) instead of being refused with `shard_failed`.
#[test]
fn scenario_kill_shard_resume() {
    let (addr, fleet, scfg) = spawn_chaos_server(ServerConfig {
        checkpoint_steps: 1,
        ..base_cfg()
    });
    let mut d = Director::new(&fleet, addr);
    d.run(&scenario("kill_shard_resume.txt")).unwrap();
    // the death and the resume are both on the ledger (the resume
    // counter lands just after re-placement — poll briefly)
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let m = fleet.metrics_prometheus().unwrap();
        if m.contains(r#"jobs_resumed_total{shard="0"} 1"#) {
            assert!(m.contains(r#"shard_died_total{shard="0"} 1"#), "{m}");
            assert!(m.contains("resume_step"), "{m}");
            assert!(m.contains("checkpoint_bytes"), "{m}");
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "resume counter never appeared: {m}"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    // both the resumed victim and the bystander match fault-free runs
    assert_survivors_match_clean(&d.replies, &scfg);
}

/// §Robustness × §Sched × §Scale: the acceptance matrix — a request
/// killed mid-trajectory with `--checkpoint-steps 1` resumes on a
/// survivor and completes byte-identical to a fault-free run, under
/// every scheduler and both fleet widths. Deterministic by construction:
/// shard 0 dies itself after exactly 4 successful batches
/// (`shard=0:fail-after=4` — no timing, no sleeps), and round-robin
/// placement pins who lands there.
#[test]
fn resumed_completions_match_clean_under_every_scheduler() {
    let lines: Vec<String> = (0..4)
        .map(|i| {
            format!(
                r#"{{"prompt": "red circle", "policy": "{}", "steps": 8, "guidance": 2.0, "seed": {}, "image": true, "client_id": "c{}"}}"#,
                if i % 2 == 0 { "cfg" } else { "ag" },
                50 + i,
                i
            )
        })
        .collect();
    for kind in SchedulerKind::ALL {
        for shards in [2usize, 4] {
            let scfg = ServerConfig {
                scheduler: kind,
                shards,
                placement: Placement::RoundRobin,
                checkpoint_steps: 1,
                ..base_cfg()
            };
            let plan = Arc::new(FaultPlan::default());
            plan.arm(FaultSpec::parse("shard=0:fail-after=4").unwrap());
            let shard_plan = plan.clone();
            let fleet = Fleet::launch(
                move |shard| {
                    Ok(FaultyBackend::with_shard(
                        GmmBackend::new(chaos_gmm()),
                        shard_plan.clone(),
                        shard as u64,
                    ))
                },
                scfg.fleet_config(),
            );
            let registry = PolicyRegistry::builtin();
            // submit everything up front so shard 0's work is genuinely
            // mid-flight when its 5th batch turns fatal
            let rxs: Vec<_> = lines
                .iter()
                .map(|line| {
                    let (req, _) = parse_request_line(line, &scfg, &registry).unwrap();
                    fleet.submit(req).unwrap()
                })
                .collect();
            for (line, rx) in lines.iter().zip(rxs) {
                match rx.recv().unwrap() {
                    JobReply::Done(c, _) => assert_eq!(
                        completion_digest(&c),
                        clean_digest(line, &scfg),
                        "{line} under {} x{shards}",
                        kind.name()
                    ),
                    JobReply::Error(l) => {
                        panic!("refused under {} x{shards}: {l}", kind.name())
                    }
                    JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
                }
            }
            assert!(
                plan.fatals() > 0,
                "shard 0 never died under {} x{shards}",
                kind.name()
            );
            // at least one mid-flight job actually took the resume path
            let deadline =
                std::time::Instant::now() + std::time::Duration::from_secs(5);
            loop {
                let m = fleet.metrics_prometheus().unwrap();
                if m.contains("jobs_resumed_total") {
                    break;
                }
                assert!(
                    std::time::Instant::now() < deadline,
                    "no resume under {} x{shards}: {m}",
                    kind.name()
                );
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            fleet.shutdown();
        }
    }
}

/// §Robustness × §Sched: retried completions are byte-identical to a
/// fault-free run under *every* scheduling discipline — the retry path
/// (rollback, requeue, fresh take_batch) must not interact with any
/// scheduler's ordering state.
#[test]
fn retried_completions_match_clean_under_every_scheduler() {
    let lines: Vec<String> = (0..4)
        .map(|i| {
            format!(
                r#"{{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0, "seed": {}, "image": true, "client_id": "c{}"}}"#,
                30 + i,
                i % 2
            )
        })
        .collect();
    for kind in SchedulerKind::ALL {
        let scfg = ServerConfig {
            scheduler: kind,
            shards: 1,
            max_batch_retries: 8,
            ..base_cfg()
        };
        // armed from the start: every 3rd batch errors transiently
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("error-every=3").unwrap());
        let shard_plan = plan.clone();
        let fleet = Fleet::launch(
            move |_shard| {
                Ok(FaultyBackend::new(
                    GmmBackend::new(chaos_gmm()),
                    shard_plan.clone(),
                ))
            },
            scfg.fleet_config(),
        );
        let registry = PolicyRegistry::builtin();
        for line in &lines {
            let (req, _) = parse_request_line(line, &scfg, &registry).unwrap();
            let rx = fleet.submit(req).unwrap();
            match rx.recv().unwrap() {
                JobReply::Done(c, _) => assert_eq!(
                    completion_digest(&c),
                    clean_digest(line, &scfg),
                    "{line} under {}",
                    kind.name()
                ),
                JobReply::Error(l) => panic!("refused under {}: {l}", kind.name()),
                JobReply::Progress(n) => panic!("unexpected progress: {n:?}"),
            }
        }
        assert!(plan.errors() > 0, "no fault fired under {}", kind.name());
        fleet.shutdown();
    }
}

/// The corpus itself stays parseable — a scenario that rots into a
/// syntax error should fail here, not deep inside a director run.
#[test]
fn scenario_corpus_parses() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios");
    let mut scripts = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("txt") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let ops = chaos::parse_script(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(!ops.is_empty(), "{} is empty", path.display());
        scripts += 1;
    }
    assert!(scripts >= 7, "scenario corpus shrank to {scripts} scripts");
}

/// Capture → replay round trip over real TCP:
///
/// 1. replay the checked-in sample trace against server A, which records
///    every served request via `--trace-out`;
/// 2. replay A's capture against a *fresh* server B at a different speed
///    and connection count;
/// 3. every digest-checked completion must match the capture — the
///    replayed traffic is served byte-identically — and the perfstat
///    report must round-trip through JSON.
#[test]
fn capture_then_replay_round_trips_digests() {
    let capture = std::env::temp_dir().join(format!(
        "agd_chaos_capture_{}.jsonl",
        std::process::id()
    ));
    let report = std::env::temp_dir().join(format!(
        "agd_chaos_replay_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&capture);

    // server A records what it serves
    let (addr_a, _fleet_a, _) = spawn_chaos_server(ServerConfig {
        trace_out: Some(capture.to_str().unwrap().to_owned()),
        ..base_cfg()
    });
    let sample = read_trace(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("scenarios")
            .join("sample_trace.jsonl")
            .to_str()
            .unwrap(),
    )
    .unwrap();
    assert!(sample.len() >= 10, "sample trace shrank to {}", sample.len());
    let outcome = chaos::replay(
        &sample,
        &ReplayConfig {
            addr: addr_a.to_string(),
            speed: 50.0,
            connections: 8,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(outcome.sent, sample.len());
    assert_eq!(outcome.completed, sample.len(), "shed: {:?}", outcome.shed);
    assert_eq!(outcome.transport_errors, 0);
    // the sample trace carries no digests (it is hand-written, not
    // captured), so nothing was checkable on this leg
    assert_eq!(outcome.digest_checked, 0);

    // the capture now holds one digest-bearing record per served request
    let captured = read_trace(capture.to_str().unwrap()).unwrap();
    assert_eq!(captured.len(), sample.len());
    assert!(captured.iter().all(|r| r.digest.is_some()), "capture lacks digests");
    assert!(captured.iter().all(|r| r.client_id.is_some()));

    // replay the capture against a fresh server B: every completion is
    // digest-checked and must match
    let (addr_b, _fleet_b, _) = spawn_chaos_server(base_cfg());
    let cfg_b = ReplayConfig {
        addr: addr_b.to_string(),
        speed: 20.0,
        connections: 4,
        ..Default::default()
    };
    let outcome = chaos::replay(&captured, &cfg_b).unwrap();
    assert_eq!(outcome.completed, captured.len(), "shed: {:?}", outcome.shed);
    assert_eq!(outcome.digest_checked, captured.len());
    assert_eq!(outcome.digest_mismatches, 0);
    assert_eq!(outcome.latencies_ms.len(), outcome.completed);

    // the report is the BENCH_replay.json the CLI writes — including the
    // post-run survival scrape (all zero here: nothing was injected)
    let survival = chaos::fetch_survival(&cfg_b.addr, 5_000).unwrap();
    assert_eq!(survival, chaos::SurvivalCounters::default());
    chaos::replay::write_report(report.to_str().unwrap(), &outcome, &cfg_b, Some(&survival))
        .unwrap();
    let v = json::parse(&std::fs::read_to_string(&report).unwrap()).unwrap();
    let rows = v.req("benchmarks").as_arr().unwrap();
    assert_eq!(rows[0].req("name").as_str(), Some("replay_wire_latency"));
    assert!(rows[0].req("p99_ms").as_f64().unwrap() >= 0.0);
    let derived = v.req("derived");
    assert_eq!(derived.req("digest_mismatches").as_f64(), Some(0.0));
    assert_eq!(derived.req("completed").as_f64(), Some(captured.len() as f64));
    assert_eq!(derived.req("survived_batch_retries").as_f64(), Some(0.0));
    assert_eq!(derived.req("survived_shard_deaths").as_f64(), Some(0.0));
    let _ = std::fs::remove_file(&capture);
    let _ = std::fs::remove_file(&report);
}
