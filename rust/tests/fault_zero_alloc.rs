//! §Robustness acceptance pin: the fault-injection wrapper is free when
//! it is not firing. [`FaultyBackend`] sits on *every* serving shard's
//! denoise path unconditionally (that is what lets the chaos director
//! arm faults on a live fleet), so its steady-state cost must be zero
//! heap allocations — both disarmed and armed-but-not-yet-firing, the
//! wrapper is a handful of relaxed atomic ops per batch.
//!
//! Same shape as `zero_alloc.rs`: a counting global allocator over
//! `System`, exactly one `#[test]` so nothing else allocates inside the
//! measurement window, warmup pumps to capacity, then a measured window
//! asserting zero allocations.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use adaptive_guidance::backend::GmmBackend;
use adaptive_guidance::chaos::fault::{FaultPlan, FaultSpec, FaultyBackend};
use adaptive_guidance::coordinator::engine::Engine;
use adaptive_guidance::coordinator::policy::{ag, cfg};
use adaptive_guidance::coordinator::request::Request;
use adaptive_guidance::sched::{Admission, SchedulerKind};
use adaptive_guidance::sim::gmm::Gmm;

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

const STEPS: usize = 48;
const WARMUP_PUMPS: usize = 16;
const MEASURED_PUMPS: usize = 16;

#[test]
fn faulty_backend_pump_is_allocation_free_when_not_firing() {
    // disarmed, then armed with a schedule that cannot fire inside the
    // window — the armed check path (counter bump + comparisons) must be
    // as free as the disarmed one
    let plans = [
        Arc::new(FaultPlan::default()),
        {
            let p = Arc::new(FaultPlan::default());
            p.arm(FaultSpec::parse("error-every=1000000").expect("spec"));
            p
        },
    ];
    for plan in plans {
        let armed = plan.armed();
        let be = FaultyBackend::new(GmmBackend::new(Gmm::axes(16, 4, 3.0, 0.05)), plan.clone());
        let mut e = Engine::with_scheduler(
            be,
            SchedulerKind::Fifo.build(),
            Admission::unlimited(),
        )
        .expect("engine over the wrapped GMM oracle");
        for i in 0..8u64 {
            let policy = if i % 2 == 0 { cfg(2.0) } else { ag(2.0, 0.99) };
            let r = Request::new(
                i,
                "gmm",
                vec![1 + (i % 4) as i32, 0, 0, 0],
                900 + i,
                STEPS,
                policy,
            );
            e.submit(r);
        }

        let mut done = 0usize;
        for _ in 0..WARMUP_PUMPS {
            done += e.pump().expect("warmup pump").len();
        }
        assert_eq!(done, 0, "warmup completed requests (armed={armed})");

        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        let mut completed = 0usize;
        for _ in 0..MEASURED_PUMPS {
            completed += e.pump().expect("steady-state pump").len();
        }
        COUNTING.store(false, Ordering::SeqCst);
        let allocs = ALLOCS.load(Ordering::SeqCst);

        assert_eq!(
            completed, 0,
            "measurement window must stay mid-flight (armed={armed})"
        );
        assert_eq!(
            allocs, 0,
            "FaultyBackend pump() allocated {allocs} time(s) at steady state \
             (armed={armed}) — the wrapper must stay a few relaxed atomics \
             per batch when no fault fires"
        );

        // the wrapper saw every batch and injected nothing
        assert!(plan.errors() == 0 && plan.stalls() == 0 && plan.fatals() == 0);

        // and the workload still drains to correct completions
        let out = e.drain().expect("drain");
        assert_eq!(out.len(), 8, "armed={armed}");
    }
}
