//! §Scale: the poll-based connection reactor (`agd serve`, default
//! front end; `--net threads` keeps the historical loop as an A/B
//! baseline).
//!
//! The threaded front end burns one OS thread (and one blocked stack)
//! per connection and cannot act on a connection while a request is in
//! flight — which makes pipelining, per-step progress streaming, and
//! wire-level cancellation structurally impossible. The reactor
//! multiplexes every connection onto **one** event-loop thread over raw
//! `poll(2)` (bound directly in [`poll`]; the offline vendor set has no
//! mio/tokio), with non-blocking sockets and a self-pipe waker:
//!
//! * **Submit side** — parsed requests enter the fleet through
//!   [`crate::fleet::Fleet::submit_to`] with a push-and-wake
//!   [`crate::fleet::ReplyTarget`]; the reactor never blocks on a reply
//!   channel.
//! * **Reply side** — shard engine threads push
//!   [`crate::fleet::JobReply`]s onto a shared queue and poke the waker;
//!   the reactor renders them to protocol lines (ids echoed, traces
//!   recorded) on its own thread, so shard pumps never touch sockets.
//!
//! # Pipelining and ordering
//!
//! A client that tags requests with a wire `"id"` may keep any number in
//! flight per connection; every reply line echoes the id, so replies may
//! be matched out of order. Id-less requests keep the historical
//! contract instead: each one *serializes* the connection (nothing later
//! is dispatched until its reply is queued), so reply order equals
//! arrival order and an id-less conversation is byte-identical to the
//! threaded front end. Control lines (`{"cmd": ..}`) take their place in
//! the same arrival order.
//!
//! # Backpressure (bounded memory at 1k+ connections)
//!
//! Outbound queues are bounded per connection: past a soft budget, new
//! progress events are shed (`conn_progress_dropped_total`) — though a
//! request's already-queued progress line is still *coalesced* in place,
//! so the client always sees the freshest sample; completions and errors
//! are never shed. Past the hard budget the connection's read interest
//! is parked (so a peer that won't drain replies throttles itself), as
//! it also is when too many parsed lines await dispatch. Inbound lines
//! are capped by `--max-line-bytes` exactly like the threaded loop, with
//! the same counters and refusal lines.
//!
//! # Cancellation
//!
//! `{"cmd": "cancel", "id": X}` looks X up in the connection's in-flight
//! table and routes a cancel to the shard named by its
//! [`crate::fleet::Ticket`]. A still-queued request is revoked from the
//! scheduler (admission refunded, `requests_canceled_total`) and the id
//! gets `{"error": .., "code": "canceled", "id": X}`; a request already
//! denoising (or re-placed by salvage after a shard death) simply
//! completes — cancel is best-effort by design. Unknown ids get
//! `"code": "unknown_id"`. Closing a connection best-effort-cancels
//! everything it still has in flight, so queued work for a vanished
//! client is refunded instead of computed.
//!
//! Timeout and oversized-frame hardening mirror `crate::server` byte for
//! byte (same counters, same refusal lines): a mid-line stall or an
//! oversized frame queues its coded refusal *after* every already-owed
//! reply, then closes; an idle connection (nothing partial, nothing in
//! flight) closes silently.

pub mod conn;
pub mod poll;

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read};
use std::net::TcpListener;
use std::os::unix::io::AsRawFd;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::chaos::trace::{completion_digest, TraceSink};
use crate::coordinator::engine::ProgressNote;
use crate::coordinator::spec::PolicyRegistry;
use crate::fleet::{Fleet, JobReply, ReplyTo};
use crate::server::{self, ServerConfig};
use crate::util::json::{self, Value};

use conn::{Conn, ConnTarget, Delivery, InFlight, PendingLine, Shared, TraceCtx, SERIAL_KEY};
use poll::{PollFd, POLLIN, POLLOUT};

/// Immutable per-reactor context threaded through the event handlers.
struct Ctx {
    fleet: Arc<Fleet>,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
    trace: Option<Arc<TraceSink>>,
    shared: Arc<Shared>,
}

/// Serve an already-bound listener on the reactor. Blocks the calling
/// thread forever (the event loop); returns only on a permanent
/// listener/poll failure, mirroring the threaded loop's contract.
pub fn serve_reactor(
    listener: TcpListener,
    fleet: Arc<Fleet>,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
    trace: Option<Arc<TraceSink>>,
) -> Result<()> {
    listener
        .set_nonblocking(true)
        .map_err(|e| anyhow!("listener set_nonblocking: {e}"))?;
    let (waker, wake_rx) = poll::waker_pair().map_err(|e| anyhow!("reactor waker: {e}"))?;
    let shared = Arc::new(Shared::new(waker));
    let ctx = Ctx {
        fleet,
        cfg,
        registry,
        trace,
        shared,
    };
    let deadline =
        (ctx.cfg.read_timeout_ms > 0).then(|| Duration::from_millis(ctx.cfg.read_timeout_ms));
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token: u64 = 1;
    let mut deliveries: VecDeque<Delivery> = VecDeque::new();
    let mut fds: Vec<PollFd> = Vec::new();
    let mut tokens: Vec<u64> = Vec::new();

    loop {
        fds.clear();
        tokens.clear();
        fds.push(PollFd::new(listener.as_raw_fd(), POLLIN));
        fds.push(PollFd::new(wake_rx.fd(), POLLIN));
        for (&token, c) in &conns {
            let mut ev = 0i16;
            if c.wants_read() {
                ev |= POLLIN;
            }
            if !c.outq.is_empty() {
                ev |= POLLOUT;
            }
            fds.push(PollFd::new(c.stream.as_raw_fd(), ev));
            tokens.push(token);
        }
        poll::poll_fds(&mut fds, poll_timeout_ms(&conns, deadline))
            .map_err(|e| anyhow!("poll: {e}"))?;
        wake_rx.drain();

        // Shard replies first: a completion may free a serialized
        // connection's dispatch slot before its socket is even looked at.
        ctx.shared.drain(&mut deliveries);
        for d in deliveries.drain(..) {
            on_delivery(&mut conns, d, &ctx);
        }

        if fds[0].readable() {
            accept_ready(&listener, &mut conns, &mut next_token)?;
        }
        for (i, &token) in tokens.iter().enumerate() {
            let pfd = fds[i + 2];
            let Some(c) = conns.get_mut(&token) else {
                continue;
            };
            if pfd.invalid() {
                c.dead = true;
                continue;
            }
            if pfd.readable() {
                read_ready(c, token, &ctx);
            }
        }

        conns.retain(|_, c| {
            if !c.dead {
                sweep_timeouts(c, &ctx, deadline);
                settle(c);
                if !c.outq.is_empty() && c.outq.flush(&c.stream).is_err() {
                    c.dead = true;
                }
                settle(c); // an eof conn that just fully drained closes now
            }
            let reap = c.dead || (c.closing && c.outq.is_empty());
            if reap {
                // refund queued work the peer will never read for
                for inf in c.inflight.values() {
                    ctx.fleet.cancel(inf.ticket);
                }
                log::info!("connection {} closed", c.peer);
            }
            !reap
        });
    }
}

/// Next poll timeout: 1s housekeeping tick, shortened to the nearest
/// read-deadline so timeout refusals stay prompt at small
/// `--read-timeout-ms` without a busy tick at the 60s default.
fn poll_timeout_ms(conns: &HashMap<u64, Conn>, deadline: Option<Duration>) -> i32 {
    let mut t = Duration::from_millis(1000);
    if let Some(dl) = deadline {
        for c in conns.values() {
            if c.dead || c.closing || c.fatal.is_some() {
                continue;
            }
            let anchor = if c.line_start.is_some() {
                c.line_start
            } else if c.inflight.is_empty() && c.pending.is_empty() && c.outq.is_empty() && !c.eof
            {
                Some(c.last_activity)
            } else {
                None
            };
            if let Some(t0) = anchor {
                t = t.min(dl.saturating_sub(t0.elapsed()));
            }
        }
    }
    (t.as_millis() as i32).clamp(10, 1000)
}

/// Drain the accept backlog. Transient failures log and yield (same
/// classification as the threaded loop); permanent ones propagate.
fn accept_ready(
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
) -> Result<()> {
    loop {
        match listener.accept() {
            Ok((stream, addr)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue; // fd already torn down: drop this one
                }
                let token = *next_token;
                *next_token += 1;
                conns.insert(token, Conn::new(stream, addr.to_string()));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
            Err(e) if server::transient_accept_error(&e) => {
                log::warn!("accept failed (transient, continuing): {e}");
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Pull everything the socket has, split lines, dispatch what the
/// ordering rules allow.
fn read_ready(c: &mut Conn, token: u64, ctx: &Ctx) {
    let mut buf = [0u8; 8192];
    while c.wants_read() {
        let mut r = &c.stream;
        match r.read(&mut buf) {
            Ok(0) => {
                c.eof = true;
                break;
            }
            Ok(n) => {
                if c.rbuf.is_empty() {
                    c.line_start = Some(Instant::now());
                }
                ingest(c, &buf[..n], ctx);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    dispatch_pending(c, token, ctx);
}

/// Append a chunk to the line accumulator and move complete lines to the
/// pending queue, enforcing `--max-line-bytes` with the same refusal
/// lines and counters as the threaded `read_line_bounded`.
fn ingest(c: &mut Conn, chunk: &[u8], ctx: &Ctx) {
    c.rbuf.extend_from_slice(chunk);
    loop {
        let Some(pos) = c.rbuf.iter().position(|&b| b == b'\n') else {
            if c.rbuf.len() > ctx.cfg.max_line_bytes {
                oversized(c, ctx);
            }
            return;
        };
        let rest = c.rbuf.split_off(pos + 1);
        let mut line_bytes = std::mem::replace(&mut c.rbuf, rest);
        line_bytes.pop(); // the newline
        c.last_activity = Instant::now();
        c.line_start = (!c.rbuf.is_empty()).then(Instant::now);
        if line_bytes.len() > ctx.cfg.max_line_bytes {
            oversized(c, ctx);
            return;
        }
        match String::from_utf8(line_bytes) {
            Ok(s) => c.pending.push_back(PendingLine::Dispatch(s)),
            // refusable in-band without closing; the reply rides the
            // pending queue so it keeps its place in arrival order
            Err(_) => {
                ctx.fleet.count("conn_bad_line_total", &[("kind", "utf8")]);
                c.pending.push_back(PendingLine::Reply(server::static_error_line(
                    "request line is not valid UTF-8",
                    "invalid_request",
                )));
            }
        }
    }
}

/// Oversized frame: the rest of the stream is undelimited garbage.
/// Queue the refusal *after* every reply already owed, stop reading,
/// close once drained — so pipelined replies in flight are not jumped.
fn oversized(c: &mut Conn, ctx: &Ctx) {
    ctx.fleet.count("conn_bad_line_total", &[("kind", "oversized")]);
    if c.fatal.is_none() {
        c.fatal = Some(server::static_error_line(
            &format!(
                "request line exceeds --max-line-bytes ({})",
                ctx.cfg.max_line_bytes
            ),
            "invalid_request",
        ));
    }
    c.eof = true;
    c.rbuf.clear();
    c.line_start = None;
}

/// Dispatch pending lines in arrival order until one serializes the
/// connection (id-less request) or the connection is closing.
fn dispatch_pending(c: &mut Conn, token: u64, ctx: &Ctx) {
    while !c.closing && !c.dead && !c.serial_blocked() {
        let Some(item) = c.pending.pop_front() else {
            break;
        };
        match item {
            PendingLine::Reply(line) => c.outq.push_line(line),
            PendingLine::Dispatch(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                dispatch_one(c, token, ctx, &line);
            }
        }
    }
}

/// The reactor's analogue of the threaded `dispatch_line`: one protocol
/// line in, zero (submitted) or one (refusal/admin) reply lines out.
fn dispatch_one(c: &mut Conn, token: u64, ctx: &Ctx, line: &str) {
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            c.outq.push_line(server::error_line_coded(
                &anyhow!("bad request json: {e}"),
                "invalid_request",
            ));
            return;
        }
    };
    if let Some(cmd) = v.get("cmd").and_then(Value::as_str) {
        if cmd == "cancel" {
            dispatch_cancel(c, ctx, &v);
        } else {
            let reply = server::admin_cmd_line(cmd, &ctx.fleet);
            c.outq.push_line(reply);
        }
        return;
    }
    let wire_id = v.get("id").cloned();
    let key = match &wire_id {
        Some(idv) => json::to_string(idv),
        None => SERIAL_KEY.to_owned(),
    };
    // two live requests under one id would make replies unmatchable, so
    // the second is refused up front (the serial slot cannot collide:
    // dispatch stops while it is occupied)
    if wire_id.is_some() && c.inflight.contains_key(&key) {
        c.outq.push_line(server::inject_id(
            server::static_error_line(
                "`id` is already in flight on this connection",
                "invalid_request",
            ),
            wire_id.as_ref(),
        ));
        return;
    }
    let arrival_us = ctx.trace.as_deref().map(TraceSink::arrival_offset_us);
    match server::parse_request_value(&v, &ctx.cfg, &ctx.registry) {
        Ok((req, want_image)) => {
            let client_id = req.client_id.clone();
            let target = ConnTarget {
                shared: ctx.shared.clone(),
                token,
                key: key.clone(),
            };
            match ctx.fleet.submit_to(req, ReplyTo::Target(Arc::new(target))) {
                Ok(ticket) => {
                    let trace = arrival_us.map(|at| TraceCtx {
                        arrival_us: at,
                        envelope: v,
                        client_id,
                    });
                    c.inflight.insert(
                        key,
                        InFlight {
                            ticket,
                            wire_id,
                            want_image,
                            trace,
                        },
                    );
                }
                Err(e) => c
                    .outq
                    .push_line(server::inject_id(server::error_to_line(&e), wire_id.as_ref())),
            }
        }
        Err(e) => c.outq.push_line(server::inject_id(
            server::error_line_coded(&e, "invalid_request"),
            wire_id.as_ref(),
        )),
    }
}

/// `{"cmd": "cancel", "id": X}`: route a best-effort cancel to the shard
/// holding X. No immediate reply on a hit — the canceled request itself
/// answers with `"code": "canceled"` (or its completion, if the cancel
/// lost the race; either way the id resolves exactly once).
fn dispatch_cancel(c: &mut Conn, ctx: &Ctx, v: &Value) {
    let Some(idv) = v.get("id") else {
        c.outq.push_line(server::static_error_line(
            "cancel requires an `id`",
            "invalid_request",
        ));
        return;
    };
    let key = json::to_string(idv);
    match c.inflight.get(&key) {
        Some(inf) => {
            ctx.fleet.cancel(inf.ticket);
        }
        None => c.outq.push_line(server::inject_id(
            server::static_error_line(
                "no such request in flight on this connection",
                "unknown_id",
            ),
            Some(idv),
        )),
    }
}

/// Route one shard reply to its connection. Deliveries for closed
/// connections (or ids the client already resolved) are dropped.
fn on_delivery(conns: &mut HashMap<u64, Conn>, d: Delivery, ctx: &Ctx) {
    let Some(c) = conns.get_mut(&d.token) else {
        return;
    };
    match d.reply {
        JobReply::Progress(n) => {
            let Some(inf) = c.inflight.get(&d.key) else {
                return;
            };
            let line = progress_line(&n, inf.wire_id.as_ref());
            if !c.outq.push_progress(&d.key, line) {
                ctx.fleet
                    .count("conn_progress_dropped_total", &[("kind", "shed")]);
            }
        }
        JobReply::Done(completion, ms) => {
            let Some(inf) = c.inflight.remove(&d.key) else {
                return;
            };
            if let (Some(sink), Some(tc)) = (&ctx.trace, &inf.trace) {
                sink.record(
                    tc.arrival_us,
                    &tc.envelope,
                    tc.client_id.as_deref(),
                    &completion_digest(&completion),
                );
            }
            c.outq.push_line(server::completion_to_line_tagged(
                &completion,
                ms,
                inf.want_image,
                inf.wire_id.as_ref(),
            ));
            c.last_activity = Instant::now();
            dispatch_pending(c, d.token, ctx);
        }
        JobReply::Error(line) => {
            let Some(inf) = c.inflight.remove(&d.key) else {
                return;
            };
            c.outq.push_line(server::inject_id(line, inf.wire_id.as_ref()));
            c.last_activity = Instant::now();
            dispatch_pending(c, d.token, ctx);
        }
    }
}

/// Render one streamed progress event. The id mirrors the completion's:
/// the client's wire id verbatim when it supplied one, else the
/// fleet-assigned id.
fn progress_line(n: &ProgressNote, wire_id: Option<&Value>) -> String {
    let id = wire_id
        .cloned()
        .unwrap_or_else(|| json::num(n.id as f64));
    json::to_string(&json::obj(vec![
        ("event", json::s("progress")),
        ("id", id),
        ("step", json::num(n.step as f64)),
        ("of", json::num(n.of as f64)),
        ("gamma", json::num(n.gamma as f64)),
        ("nfes", json::num(n.nfes as f64)),
    ]))
}

/// End-of-life bookkeeping: once every owed reply is queued, append the
/// deferred fatal refusal (oversized / mid-line timeout) and close; an
/// `eof` connection with nothing left to say closes silently.
fn settle(c: &mut Conn) {
    if c.pending.is_empty() && c.inflight.is_empty() {
        if let Some(line) = c.fatal.take() {
            c.outq.push_line(line);
            c.closing = true;
        } else if c.eof && c.outq.is_empty() {
            c.closing = true;
        }
    }
}

/// The slowloris/idle sweep — same taxonomy, counters and refusal lines
/// as the threaded `read_line_bounded`, measured per line: mid-line
/// stalls get a coded reply then close; idle connections (no partial
/// line, nothing in flight, nothing owed) close silently. A connection
/// waiting on its own in-flight requests is *not* idle.
fn sweep_timeouts(c: &mut Conn, ctx: &Ctx, deadline: Option<Duration>) {
    let Some(dl) = deadline else {
        return;
    };
    if c.closing || c.dead || c.fatal.is_some() {
        return;
    }
    if let Some(t0) = c.line_start {
        if t0.elapsed() >= dl {
            ctx.fleet.count("conn_timeout_total", &[("kind", "midline")]);
            c.fatal = Some(server::static_error_line(
                &format!(
                    "no complete request line within --read-timeout-ms ({})",
                    ctx.cfg.read_timeout_ms
                ),
                "timeout",
            ));
            c.eof = true;
            c.rbuf.clear();
            c.line_start = None;
        }
    } else if c.inflight.is_empty()
        && c.pending.is_empty()
        && c.outq.is_empty()
        && !c.eof
        && c.last_activity.elapsed() >= dl
    {
        ctx.fleet.count("conn_timeout_total", &[("kind", "idle")]);
        c.closing = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn progress_lines_echo_the_wire_id_or_fall_back_to_the_fleet_id() {
        let n = ProgressNote {
            id: 42,
            step: 3,
            of: 8,
            gamma: 0.5,
            nfes: 5,
        };
        let with = progress_line(&n, Some(&json::s("job-1")));
        let v = json::parse(&with).unwrap();
        assert_eq!(v.get("event").and_then(Value::as_str), Some("progress"));
        assert_eq!(v.get("id").and_then(Value::as_str), Some("job-1"));
        assert_eq!(v.get("step").and_then(Value::as_f64), Some(3.0));
        assert_eq!(v.get("of").and_then(Value::as_f64), Some(8.0));
        let without = progress_line(&n, None);
        let v = json::parse(&without).unwrap();
        assert_eq!(v.get("id").and_then(Value::as_f64), Some(42.0));
    }
}
