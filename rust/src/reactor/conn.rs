//! Per-connection state for the reactor: the inbound line accumulator,
//! the in-flight request table (wire id → [`Ticket`]), and the bounded
//! outbound queue with progress coalescing — the write-backpressure
//! half of the §Scale story.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::fleet::{JobReply, ReplyTarget, Ticket};
use crate::util::json::Value;

use super::poll::Waker;

/// Outbound soft budget: past this many queued bytes, *new* progress
/// events for a request that already has none queued are shed (counted
/// as `conn_progress_dropped_total{kind="shed"}`). Completions and
/// errors are never shed.
pub(crate) const PROGRESS_OUT_BUDGET: usize = 256 * 1024;

/// Outbound hard budget: past this, the reactor stops *reading* from the
/// connection (its `POLLIN` interest is dropped), so a client that won't
/// drain its replies throttles itself instead of growing the queue.
pub(crate) const HARD_OUT_BUDGET: usize = 1024 * 1024;

/// Parsed-but-undispatched line cap per connection — bounds memory when
/// a pipelined client keeps writing while an id-less request serializes
/// the dispatch pipeline. At the cap the connection stops being read.
pub(crate) const PENDING_MAX: usize = 1024;

/// Coalescing key for the connection's one id-less in-flight request.
/// Cannot collide with a wire-id key: those are JSON-serialized, so a
/// string id arrives quoted (`"\"x\""`) and a number as digits.
pub(crate) const SERIAL_KEY: &str = "#serial";

/// One queued outbound line (stored with its trailing `\n`).
enum OutItem {
    /// Completion / error / admin reply: never dropped, never replaced.
    Line(String),
    /// A progress event for the request keyed by `.0`: replaceable by a
    /// newer sample while it still waits (at most one queued progress
    /// line per request per connection).
    Progress(String, String),
}

impl OutItem {
    fn bytes(&self) -> &[u8] {
        match self {
            OutItem::Line(s) => s.as_bytes(),
            OutItem::Progress(_, s) => s.as_bytes(),
        }
    }
}

/// Bounded outbound queue. Writes go out through [`OutQueue::flush`] in
/// strict push order; a partially-written front item is tracked by
/// `front_pos` and is never replaced (coalescing skips it).
#[derive(Default)]
pub(crate) struct OutQueue {
    items: VecDeque<OutItem>,
    front_pos: usize,
    bytes: usize,
}

impl OutQueue {
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Queue a reply line (newline appended here). Never refused: the
    /// hard budget is enforced upstream by parking the *read* side.
    pub fn push_line(&mut self, mut line: String) {
        line.push('\n');
        self.bytes += line.len();
        self.items.push_back(OutItem::Line(line));
    }

    /// Queue a progress event for request `key`. If one is already
    /// waiting it is replaced in place (coalesced — the client sees the
    /// freshest sample, in the original position). Returns `false` when
    /// the sample was shed because the queue is over the soft budget.
    pub fn push_progress(&mut self, key: &str, mut line: String) -> bool {
        line.push('\n');
        let skip = usize::from(self.front_pos > 0);
        for item in self.items.iter_mut().skip(skip) {
            if let OutItem::Progress(k, old) = item {
                if k == key {
                    self.bytes = self.bytes - old.len() + line.len();
                    *old = line;
                    return true;
                }
            }
        }
        if self.bytes > PROGRESS_OUT_BUDGET {
            return false;
        }
        self.bytes += line.len();
        self.items.push_back(OutItem::Progress(key.to_owned(), line));
        true
    }

    /// Write as much as the socket accepts without blocking. `Ok(())`
    /// means the socket is healthy (queue may or may not be empty);
    /// `Err` means the connection is dead.
    pub fn flush(&mut self, stream: &TcpStream) -> io::Result<()> {
        while let Some(front) = self.items.front() {
            let buf = &front.bytes()[self.front_pos..];
            match (&mut &*stream).write(buf) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.front_pos += n;
                    self.bytes -= n;
                    if self.front_pos == front.bytes().len() {
                        self.items.pop_front();
                        self.front_pos = 0;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }
}

/// Trace-capture context carried from dispatch to completion delivery
/// (the reactor's analogue of the locals in the threaded
/// `dispatch_line`): arrival offset, the envelope verbatim, client id.
pub(crate) struct TraceCtx {
    pub arrival_us: u64,
    pub envelope: Value,
    pub client_id: Option<Arc<str>>,
}

/// One parsed-off inbound frame awaiting dispatch. Refusals that must
/// keep their place in arrival order (a non-UTF-8 frame between two
/// pipelined requests) ride the same queue as dispatchable lines.
pub(crate) enum PendingLine {
    Dispatch(String),
    /// Pre-rendered reply: emitted, never dispatched.
    Reply(String),
}

/// One in-flight request on a connection.
pub(crate) struct InFlight {
    pub ticket: Ticket,
    /// The client's wire id, verbatim, for echoing (`None` = id-less).
    pub wire_id: Option<Value>,
    pub want_image: bool,
    pub trace: Option<TraceCtx>,
}

/// Per-connection reactor state.
pub(crate) struct Conn {
    pub stream: TcpStream,
    pub peer: String,
    /// Partial-line inbound bytes (bounded by `--max-line-bytes`).
    pub rbuf: Vec<u8>,
    /// When the current partial line's first byte arrived (slowloris
    /// deadline anchor), `None` when `rbuf` is empty.
    pub line_start: Option<Instant>,
    /// Last completed line / reply activity (idle-timeout anchor).
    pub last_activity: Instant,
    /// Complete lines awaiting dispatch (bounded by [`PENDING_MAX`]).
    pub pending: VecDeque<PendingLine>,
    /// In-flight requests keyed by serialized wire id (or [`SERIAL_KEY`]
    /// for the one id-less slot).
    pub inflight: HashMap<String, InFlight>,
    pub outq: OutQueue,
    /// Peer half-closed its write side: stop reading, but finish every
    /// already-received line and deliver every in-flight reply first.
    pub eof: bool,
    /// Deferred terminal refusal (oversized frame, mid-line timeout):
    /// queued after every already-owed reply, then the connection
    /// closes. `Some` implies reads have stopped.
    pub fatal: Option<String>,
    /// Hard close after the outbound queue drains (protocol violation,
    /// timeout); nothing further is read or dispatched.
    pub closing: bool,
    /// Tear down now, queue and all (IO error on the socket).
    pub dead: bool,
}

impl Conn {
    pub fn new(stream: TcpStream, peer: String) -> Conn {
        Conn {
            stream,
            peer,
            rbuf: Vec::new(),
            line_start: None,
            last_activity: Instant::now(),
            pending: VecDeque::new(),
            inflight: HashMap::new(),
            outq: OutQueue::default(),
            eof: false,
            fatal: None,
            closing: false,
            dead: false,
        }
    }

    /// Is the connection's `POLLIN` interest live? Backpressure in both
    /// directions parks the read side instead of buffering unboundedly.
    pub fn wants_read(&self) -> bool {
        !self.dead
            && !self.closing
            && !self.eof
            && self.outq.bytes() <= HARD_OUT_BUDGET
            && self.pending.len() < PENDING_MAX
    }

    /// An id-less request is in flight: dispatch is serialized (reply
    /// order must match arrival order, exactly like the threaded loop).
    pub fn serial_blocked(&self) -> bool {
        self.inflight.contains_key(SERIAL_KEY)
    }
}

/// One reply hop from a shard engine thread to the reactor: the shard
/// pushes, wakes, and returns to its pump — it never renders JSON or
/// touches a socket.
pub(crate) struct Delivery {
    pub token: u64,
    pub key: String,
    pub reply: JobReply,
}

/// State shared between the reactor thread and every shard thread: the
/// delivery queue and the waker that un-parks `poll`.
pub(crate) struct Shared {
    queue: Mutex<VecDeque<Delivery>>,
    pub waker: Waker,
}

impl Shared {
    pub fn new(waker: Waker) -> Shared {
        Shared {
            queue: Mutex::new(VecDeque::new()),
            waker,
        }
    }

    pub fn push(&self, d: Delivery) {
        self.queue.lock().expect("delivery queue lock").push_back(d);
        self.waker.wake();
    }

    /// Swap the queue out (reactor side), reusing `into`'s capacity.
    pub fn drain(&self, into: &mut VecDeque<Delivery>) {
        into.clear();
        std::mem::swap(&mut *self.queue.lock().expect("delivery queue lock"), into);
    }
}

/// The [`ReplyTarget`] handed to [`crate::fleet::Fleet::submit_to`]: one
/// per submitted request, addressing (connection token, request key).
/// Delivery to a token whose connection has since closed is dropped by
/// the reactor — the shard side never needs to know.
pub(crate) struct ConnTarget {
    pub shared: Arc<Shared>,
    pub token: u64,
    pub key: String,
}

impl ReplyTarget for ConnTarget {
    fn deliver(&self, reply: JobReply) {
        self.shared.push(Delivery {
            token: self.token,
            key: self.key.clone(),
            reply,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(s: &str) -> String {
        s.to_owned()
    }

    #[test]
    fn progress_coalesces_in_place_per_key() {
        let mut q = OutQueue::default();
        q.push_line(line("{\"a\":1}"));
        assert!(q.push_progress("7", line("{\"step\":1}")));
        q.push_line(line("{\"b\":2}"));
        // same key: replaced in place, queue length unchanged
        assert!(q.push_progress("7", line("{\"step\":2}")));
        assert_eq!(q.items.len(), 3);
        // different key: appended
        assert!(q.push_progress("8", line("{\"step\":1}")));
        assert_eq!(q.items.len(), 4);
        match &q.items[1] {
            OutItem::Progress(k, s) => {
                assert_eq!(k, "7");
                assert_eq!(s, "{\"step\":2}\n");
            }
            OutItem::Line(_) => panic!("expected progress at slot 1"),
        }
    }

    #[test]
    fn progress_is_shed_over_the_soft_budget_but_lines_never_are() {
        let mut q = OutQueue::default();
        let big = "x".repeat(PROGRESS_OUT_BUDGET + 1);
        q.push_line(big);
        // a fresh progress key is shed...
        assert!(!q.push_progress("1", line("{\"step\":1}")));
        // ...but coalescing onto an already-queued one still works
        q.bytes = 0; // pretend the queue drained
        assert!(q.push_progress("1", line("{\"step\":1}")));
        q.bytes = PROGRESS_OUT_BUDGET + 1;
        assert!(q.push_progress("1", line("{\"step\":2}")));
        // and completions always enqueue
        q.push_line(line("{\"id\":1}"));
        assert!(matches!(q.items.back(), Some(OutItem::Line(_))));
    }

    #[test]
    fn byte_accounting_tracks_pushes_and_replacements() {
        let mut q = OutQueue::default();
        q.push_line(line("abc")); // 4 bytes with newline
        assert_eq!(q.bytes(), 4);
        q.push_progress("k", line("pp")); // 3
        assert_eq!(q.bytes(), 7);
        q.push_progress("k", line("ppppp")); // replaces: 4 + 6
        assert_eq!(q.bytes(), 10);
    }
}
