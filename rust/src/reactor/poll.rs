//! Raw `poll(2)` binding and the self-pipe waker — the two readiness
//! primitives the reactor is built on. The offline vendor set has no
//! `mio`/`libc`, so the syscall is bound directly (the same approach as
//! `crate::server`'s errno table): a `#[repr(C)]` `pollfd` mirror and an
//! `extern "C"` declaration resolved by the platform libc every Rust
//! binary already links. `poll` is POSIX; the constants below are the
//! universal values shared by Linux and the BSDs.

use std::io::{self, Read, Write};
use std::os::raw::{c_int, c_ulong};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;

pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;
pub const POLLNVAL: i16 = 0x020;

/// Mirror of `struct pollfd` (identical layout on every libc the fleet
/// deploys on: `int fd; short events; short revents;`).
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: RawFd,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: RawFd, events: i16) -> PollFd {
        PollFd {
            fd,
            events,
            revents: 0,
        }
    }

    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR) != 0
    }

    pub fn invalid(&self) -> bool {
        self.revents & POLLNVAL != 0
    }
}

extern "C" {
    /// POSIX `poll(2)`. `nfds_t` is `unsigned long` on the glibc/musl
    /// targets this deploys on.
    fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
}

/// Block until at least one fd is ready or `timeout_ms` elapses
/// (`-1` = forever). Retries on EINTR; returns the ready count.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Cross-thread wakeup for a `poll`-parked reactor: shard engine threads
/// finishing a request must interrupt the sleep. Classic self-pipe,
/// built on `UnixStream::pair` (std's portable pipe). Both ends are
/// non-blocking: a full pipe means a wake is already pending, so the
/// dropped byte is harmless — the reactor drains the pipe and then the
/// whole delivery queue every time it wakes.
pub struct Waker {
    tx: UnixStream,
}

impl Waker {
    /// Wake the reactor. Callable from any thread (`&self`; the write is
    /// a single byte, atomic at the pipe level).
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// The reactor-side read end of the waker pipe.
pub struct WakeReader {
    rx: UnixStream,
}

impl WakeReader {
    pub fn fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Swallow every pending wake byte (level-triggered `poll` would
    /// otherwise spin on them).
    pub fn drain(&self) {
        let mut buf = [0u8; 256];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

pub fn waker_pair() -> io::Result<(Waker, WakeReader)> {
    let (rx, tx) = UnixStream::pair()?;
    rx.set_nonblocking(true)?;
    tx.set_nonblocking(true)?;
    Ok((Waker { tx }, WakeReader { rx }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn waker_makes_the_pipe_readable_and_drain_clears_it() {
        let (waker, reader) = waker_pair().unwrap();
        let mut fds = [PollFd::new(reader.fd(), POLLIN)];
        // nothing pending: poll times out
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
        waker.wake();
        waker.wake(); // coalesces, never blocks
        let mut fds = [PollFd::new(reader.fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].readable());
        reader.drain();
        let mut fds = [PollFd::new(reader.fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
    }

    #[test]
    fn wake_from_another_thread_interrupts_a_parked_poll() {
        let (waker, reader) = waker_pair().unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            waker.wake();
        });
        let t0 = Instant::now();
        let mut fds = [PollFd::new(reader.fd(), POLLIN)];
        let n = poll_fds(&mut fds, 5000).unwrap();
        assert_eq!(n, 1);
        assert!(t0.elapsed().as_millis() < 5000);
        t.join().unwrap();
    }
}
