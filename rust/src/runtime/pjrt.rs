//! The PJRT backend: compiles + executes the AOT'd HLO-text artifacts.
//!
//! One `PjRtLoadedExecutable` per (artifact, bucket), compiled lazily on
//! first use and cached for the life of the backend (the paper's models are
//! "compiled once per variant" — §Perf). Batches are padded up to the
//! smallest bucket that fits; padding lanes replay the first item's inputs
//! and their outputs are dropped.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::backend::{Backend, BatchBuf, BatchOut};
use crate::runtime::manifest::Manifest;

pub struct PjrtBackend {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// compile + execute counters (perf accounting)
    pub compiles: usize,
    pub executions: usize,
    /// staging buffers for bucket padding, reused across `denoise_into`
    /// calls (the packed batch is contiguous already; padding lanes replay
    /// row 0 on top of it)
    stage_x: Vec<f32>,
    stage_t: Vec<f32>,
    stage_tok: Vec<i32>,
}

fn f32_literal(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("f32 literal: {e:?}"))
}

fn i32_literal(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("i32 literal: {e:?}"))
}

impl PjrtBackend {
    /// Create a backend over an artifacts directory (`make artifacts`).
    pub fn load(artifacts_dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.validate()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtBackend {
            client,
            manifest,
            execs: HashMap::new(),
            compiles: 0,
            executions: 0,
            stage_x: Vec::new(),
            stage_t: Vec::new(),
            stage_tok: Vec::new(),
        })
    }

    /// Compile (or fetch the cached) executable for an artifact file.
    fn exec(&mut self, file: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.execs.contains_key(file) {
            let path = self.manifest.artifact_path(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            self.compiles += 1;
            self.execs.insert(file.to_owned(), exe);
        }
        Ok(&self.execs[file])
    }

    /// Smallest bucket >= n from `buckets` (error if none fits).
    fn bucket_for(buckets: &[usize], n: usize) -> Result<usize> {
        buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!("batch of {n} exceeds largest bucket {buckets:?}"))
    }

    /// Warm the executable cache for a model's buckets (and the shared
    /// guide/solver artifacts) so serving latency excludes compilation.
    pub fn warmup(&mut self, model: &str) -> Result<()> {
        let files: Vec<String> = {
            let meta = self
                .manifest
                .models
                .get(model)
                .ok_or_else(|| anyhow!("unknown model {model}"))?;
            meta.denoisers.values().cloned().collect()
        };
        for f in files {
            self.exec(&f)?;
        }
        Ok(())
    }

    fn run_tuple(
        &mut self,
        file: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.exec(file)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {file}: {e:?}"))?;
        self.executions += 1;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {file}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {file}: {e:?}"))
    }

    /// Execute the fused guide kernel artifact: returns (eps_cfg, gamma).
    /// Device-side alternative to the host combine (ablation in §Perf).
    pub fn run_guide(
        &mut self,
        eps_c: &[f32],
        eps_u: &[f32],
        s: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let b_actual = s.len();
        let m = self.manifest.flat_dim;
        let buckets: Vec<usize> = self.manifest.guide.keys().copied().collect();
        let b = Self::bucket_for(&buckets, b_actual)?;
        let file = self.manifest.guide[&b].clone();
        let mut ec = eps_c.to_vec();
        let mut eu = eps_u.to_vec();
        let mut sv = s.to_vec();
        for _ in b_actual..b {
            ec.extend_from_slice(&eps_c[..m]);
            eu.extend_from_slice(&eps_u[..m]);
            sv.push(s[0]);
        }
        let out = self.run_tuple(
            &file,
            &[
                f32_literal(&[b, m], &ec)?,
                f32_literal(&[b, m], &eu)?,
                f32_literal(&[b], &sv)?,
            ],
        )?;
        let eps_cfg: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let gamma: Vec<f32> = out[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        Ok((
            eps_cfg[..b_actual * m].to_vec(),
            gamma[..b_actual].to_vec(),
        ))
    }

    /// Execute the fused DPM++(2M) solver artifact: returns (x_next, x0).
    pub fn run_solver(
        &mut self,
        x: &[f32],
        eps: &[f32],
        x0_prev: &[f32],
        coefs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = self.manifest.flat_dim;
        let b_actual = x.len() / m;
        let buckets: Vec<usize> = self.manifest.solver.keys().copied().collect();
        let b = Self::bucket_for(&buckets, b_actual)?;
        let file = self.manifest.solver[&b].clone();
        let pad = |v: &[f32], row: usize| {
            let mut out = v.to_vec();
            for _ in b_actual..b {
                out.extend_from_slice(&v[..row]);
            }
            out
        };
        let out = self.run_tuple(
            &file,
            &[
                f32_literal(&[b, m], &pad(x, m))?,
                f32_literal(&[b, m], &pad(eps, m))?,
                f32_literal(&[b, m], &pad(x0_prev, m))?,
                f32_literal(&[b, 5], &pad(coefs, 5))?,
            ],
        )?;
        let xn: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let x0: Vec<f32> = out[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        Ok((xn[..b_actual * m].to_vec(), x0[..b_actual * m].to_vec()))
    }

    /// Execute the NAS search-gradient artifact (§4, lowered by aot.py):
    /// `(alpha, gumbel, x_t, tokens) -> (loss, grad, mse, soft_nfe)`.
    pub fn run_search_grad(
        &mut self,
        alpha: &[f32],
        gumbel: &[f32],
        x_t: &[f32],
        tokens: &[i32],
    ) -> Result<(f32, Vec<f32>, f32, f32)> {
        let meta = self.manifest.search.clone();
        let file = meta
            .artifact
            .ok_or_else(|| anyhow!("manifest has no search_grad artifact"))?;
        let t = meta.steps;
        let k = meta.options.len();
        let b = meta.batch;
        let img = self.manifest.img;
        let ch = self.manifest.channels;
        anyhow::ensure!(alpha.len() == t * k, "alpha shape");
        anyhow::ensure!(x_t.len() == b * img * img * ch, "x_t shape");
        let out = self.run_tuple(
            &file,
            &[
                f32_literal(&[t, k], alpha)?,
                f32_literal(&[t, k], gumbel)?,
                f32_literal(&[b, img, img, ch], x_t)?,
                i32_literal(&[b, 4], tokens)?,
            ],
        )?;
        let loss: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let grad: Vec<f32> = out[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let mse: Vec<f32> = out[2].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let nfe: Vec<f32> = out[3].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        Ok((loss[0], grad, mse[0], nfe[0]))
    }
}

impl Backend for PjrtBackend {
    fn flat_in(&self, model: &str) -> usize {
        let meta = &self.manifest.models[model];
        self.manifest.img * self.manifest.img * meta.in_channels
    }

    fn flat_out(&self, _model: &str) -> usize {
        self.manifest.flat_dim
    }

    fn buckets(&self) -> &[usize] {
        &self.manifest.buckets
    }

    fn max_batch(&self, model: &str) -> usize {
        self.manifest
            .models
            .get(model)
            .and_then(|m| m.buckets.last().copied())
            .unwrap_or_else(|| *self.manifest.buckets.last().unwrap())
    }

    fn validate_tokens(&self, _model: &str, tokens: &[i32]) -> Result<(), &'static str> {
        // the DiT artifacts are lowered with 4 token slots per item
        if tokens.len() != 4 {
            return Err("this backend's artifacts take exactly 4 token slots");
        }
        Ok(())
    }

    fn denoise_into(&mut self, model: &str, batch: &BatchBuf, out: &mut BatchOut) -> Result<()> {
        anyhow::ensure!(!batch.is_empty(), "empty batch");
        let meta = self
            .manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?
            .clone();
        let b = Self::bucket_for(&meta.buckets, batch.len())?;
        let file = meta.denoisers[&b].clone();
        let img = self.manifest.img;
        let ch = meta.in_channels;
        let flat_in = img * img * ch;
        let flat_out = self.manifest.flat_dim;
        anyhow::ensure!(
            batch.flat_in() == flat_in,
            "packed row length {} != {flat_in} for model {model}",
            batch.flat_in()
        );
        // the DiT artifacts are lowered with 4 token slots per item
        anyhow::ensure!(
            batch.tok_width() == 4,
            "model {model} artifacts take 4 token slots per item, got rows of {}",
            batch.tok_width()
        );

        // the packed batch is already contiguous: lower it straight into
        // the literals when it fills the bucket, and only stage (padding
        // lanes replay row 0; their outputs are dropped) when it does not
        let inputs = if batch.len() == b {
            [
                f32_literal(&[b, img, img, ch], batch.xs())?,
                f32_literal(&[b], batch.ts())?,
                i32_literal(&[b, 4], batch.tokens())?,
            ]
        } else {
            self.stage_x.clear();
            self.stage_t.clear();
            self.stage_tok.clear();
            self.stage_x.extend_from_slice(batch.xs());
            self.stage_t.extend_from_slice(batch.ts());
            self.stage_tok.extend_from_slice(batch.tokens());
            for _ in batch.len()..b {
                self.stage_x.extend_from_slice(batch.x_row(0));
                self.stage_t.push(batch.t(0));
                self.stage_tok.extend_from_slice(batch.token_row(0));
            }
            [
                f32_literal(&[b, img, img, ch], &self.stage_x)?,
                f32_literal(&[b], &self.stage_t)?,
                i32_literal(&[b, 4], &self.stage_tok)?,
            ]
        };
        let result = self.run_tuple(&file, &inputs)?;
        let eps: Vec<f32> = result[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        anyhow::ensure!(eps.len() == b * flat_out, "unexpected output length");
        out.reset(flat_out, batch.len());
        out.data_mut()
            .copy_from_slice(&eps[..batch.len() * flat_out]);
        Ok(())
    }

    fn models(&self) -> Vec<String> {
        self.manifest.models.keys().cloned().collect()
    }
}
