//! PJRT runtime: loads the HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via the
//! `xla` crate. This is the only module that touches the device; everything
//! above it works on [`crate::tensor::Tensor`] buffers.

pub mod manifest;
pub mod pjrt;

pub use manifest::Manifest;
pub use pjrt::PjrtBackend;

/// Load the default artifacts directory (`$AGD_ARTIFACTS` or `artifacts/`
/// relative to the crate root), or `None` with a note — benches and examples
/// use this to skip gracefully on a checkout without `make artifacts`.
pub fn try_load_default() -> Option<PjrtBackend> {
    let dir = std::env::var("AGD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "artifacts not found at {} — run `make artifacts` first",
            dir.display()
        );
        return None;
    }
    match PjrtBackend::load(&dir) {
        Ok(be) => Some(be),
        Err(e) => {
            eprintln!("failed to load artifacts: {e:#}");
            None
        }
    }
}
