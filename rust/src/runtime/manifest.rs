//! `manifest.json` — the build-time contract between `aot.py` and the Rust
//! runtime: artifact paths per (model, bucket), schedule parity table, prompt
//! vocabulary, and search-graph metadata.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub params: usize,
    pub in_channels: usize,
    pub buckets: Vec<usize>,
    /// artifact file per bucket
    pub denoisers: BTreeMap<usize, String>,
}

#[derive(Debug, Clone)]
pub struct SearchMeta {
    pub steps: usize,
    pub batch: usize,
    pub options: Vec<String>,
    pub costs: Vec<f64>,
    pub s_base: f64,
    pub lam_cost: f64,
    pub cost_target: f64,
    pub artifact: Option<String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub flat_dim: usize,
    pub img: usize,
    pub channels: usize,
    pub buckets: Vec<usize>,
    pub default_guidance: f64,
    pub default_steps: usize,
    /// schedule parity table (T = 20): timesteps and folded coefficients as
    /// computed on the python side — pinned against `coordinator::solver`.
    pub timesteps_20: Vec<f64>,
    pub coefs_20: Vec<[f64; 5]>,
    pub vocab_shapes: Vec<String>,
    pub vocab_colors: Vec<String>,
    pub vocab_positions: Vec<String>,
    pub vocab_sizes: Vec<String>,
    pub models: BTreeMap<String, ModelMeta>,
    pub guide: BTreeMap<usize, String>,
    pub solver: BTreeMap<usize, String>,
    pub search: SearchMeta,
}

impl Manifest {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;
        Self::from_value(root, &v)
    }

    pub fn from_value(root: &Path, v: &Value) -> Result<Manifest> {
        let sched = v.req("schedule");
        let vocab = v.req("vocab");
        let arts = v.req("artifacts");
        let defaults = v.req("defaults");

        let bucket_list = |val: &Value| -> Vec<usize> {
            val.as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(Value::as_usize)
                .collect()
        };

        let mut models = BTreeMap::new();
        if let Some(m) = v.req("models").as_obj() {
            for (name, meta) in m {
                let denoisers = arts
                    .req("denoisers")
                    .get(name)
                    .and_then(Value::as_obj)
                    .map(|o| {
                        o.iter()
                            .map(|(b, f)| {
                                (b.parse::<usize>().unwrap(), f.as_str().unwrap().to_owned())
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                models.insert(
                    name.clone(),
                    ModelMeta {
                        params: meta.req("params").as_usize().unwrap_or(0),
                        in_channels: meta.req("in_channels").as_usize().unwrap_or(3),
                        buckets: bucket_list(meta.req("buckets")),
                        denoisers,
                    },
                );
            }
        }

        let str_bucket_map = |val: Option<&Value>| -> BTreeMap<usize, String> {
            val.and_then(Value::as_obj)
                .map(|o| {
                    o.iter()
                        .map(|(b, f)| (b.parse().unwrap(), f.as_str().unwrap().to_owned()))
                        .collect()
                })
                .unwrap_or_default()
        };

        let coefs_20 = sched
            .req("coefs_20")
            .as_arr()
            .context("coefs_20")?
            .iter()
            .map(|row| {
                let r = row.as_f64_vec().unwrap();
                [r[0], r[1], r[2], r[3], r[4]]
            })
            .collect();

        let sv = v.req("search");
        let search = SearchMeta {
            steps: sv.req("steps").as_usize().unwrap(),
            batch: sv.req("batch").as_usize().unwrap(),
            options: sv.req("options").as_str_vec().unwrap(),
            costs: sv.req("costs").as_f64_vec().unwrap(),
            s_base: sv.req("s_base").as_f64().unwrap(),
            lam_cost: sv.req("lam_cost").as_f64().unwrap(),
            cost_target: sv.req("cost_target").as_f64().unwrap(),
            artifact: arts.get("search_grad").and_then(Value::as_str).map(str::to_owned),
        };

        Ok(Manifest {
            root: root.to_path_buf(),
            flat_dim: v.req("flat_dim").as_usize().context("flat_dim")?,
            img: v.req("img").as_usize().unwrap(),
            channels: v.req("channels").as_usize().unwrap(),
            buckets: bucket_list(v.req("buckets")),
            default_guidance: defaults.req("guidance").as_f64().unwrap(),
            default_steps: defaults.req("steps").as_usize().unwrap(),
            timesteps_20: sched.req("timesteps_20").as_f64_vec().unwrap(),
            coefs_20,
            vocab_shapes: vocab.req("shapes").as_str_vec().unwrap(),
            vocab_colors: vocab.req("colors").as_str_vec().unwrap(),
            vocab_positions: vocab.req("positions").as_str_vec().unwrap(),
            vocab_sizes: vocab.req("sizes").as_str_vec().unwrap(),
            models,
            guide: str_bucket_map(arts.get("guide")),
            solver: str_bucket_map(arts.get("solver")),
            search,
        })
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.root.join(file)
    }

    /// Sanity checks: vocab matches `crate::prompts`, schedule matches
    /// `coordinator::solver` to f32-safe precision.
    pub fn validate(&self) -> Result<()> {
        use crate::coordinator::solver;
        use crate::prompts;
        anyhow::ensure!(
            self.vocab_shapes == prompts::SHAPES,
            "shape vocab drift between manifest and prompts.rs"
        );
        anyhow::ensure!(self.vocab_colors == prompts::COLORS, "color vocab drift");
        anyhow::ensure!(
            self.vocab_positions == prompts::POSITIONS,
            "position vocab drift"
        );
        anyhow::ensure!(self.vocab_sizes == prompts::SIZES, "size vocab drift");

        let ts = solver::timesteps(20);
        anyhow::ensure!(self.timesteps_20.len() == ts.len(), "timestep grid length");
        for (a, b) in self.timesteps_20.iter().zip(&ts) {
            anyhow::ensure!((a - b).abs() < 1e-9, "timestep drift: {a} vs {b}");
        }
        let table = solver::coef_table(20);
        for (row_m, row_r) in self.coefs_20.iter().zip(&table) {
            for (a, b) in row_m.iter().zip(&row_r.as_array()) {
                anyhow::ensure!(
                    (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                    "solver coefficient drift: {a} vs {b}"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal manifest value for parser tests (mirrors aot.py's layout).
    fn sample() -> Value {
        let text = r#"{
          "version": 1, "flat_dim": 768, "img": 16, "channels": 3,
          "buckets": [1, 2, 4], "edit_buckets": [1],
          "defaults": {"guidance": 7.5, "steps": 20},
          "schedule": {"kind": "cosine-vp", "cosine_s": 0.008,
            "t_max": 0.98, "t_min": 0.02,
            "timesteps_20": [0.98, 0.02],
            "coefs_20": [[1.0, 2.0, 0.0, 3.0, 4.0]]},
          "vocab": {"shapes": ["circle", "square", "triangle", "cross"],
                    "colors": ["red", "green", "blue", "yellow", "white"],
                    "positions": ["center", "top-left", "top-right",
                                  "bottom-left", "bottom-right"],
                    "sizes": ["small", "large"]},
          "models": {"dit_s": {"params": 99036, "in_channels": 3,
                               "buckets": [1, 2, 4], "checkpoint": "c.npz"}},
          "artifacts": {
            "denoisers": {"dit_s": {"1": "d1.hlo.txt", "2": "d2.hlo.txt",
                                    "4": "d4.hlo.txt"}},
            "guide": {"1": "g1.hlo.txt"},
            "solver": {"1": "s1.hlo.txt"},
            "search_grad": "search.hlo.txt"},
          "search": {"steps": 20, "batch": 4,
            "options": ["uncond", "cond", "cfg_half", "cfg_base", "cfg_double"],
            "costs": [1, 1, 2, 2, 2], "s_base": 7.5,
            "lam_cost": 0.02, "cost_target": 30.0}
        }"#;
        json::parse(text).unwrap()
    }

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::from_value(Path::new("/tmp"), &sample()).unwrap();
        assert_eq!(m.flat_dim, 768);
        assert_eq!(m.buckets, vec![1, 2, 4]);
        let dit = &m.models["dit_s"];
        assert_eq!(dit.params, 99036);
        assert_eq!(dit.denoisers[&2], "d2.hlo.txt");
        assert_eq!(m.guide[&1], "g1.hlo.txt");
        assert_eq!(m.search.artifact.as_deref(), Some("search.hlo.txt"));
        assert_eq!(m.search.costs, vec![1.0, 1.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn vocab_validation_catches_drift() {
        let mut v = sample();
        // valid vocab passes the vocab part (schedule table is fake → use
        // a manifest with only vocab checked by tampering the vocab)
        if let Value::Obj(map) = &mut v {
            if let Some(Value::Obj(vocab)) = map.get_mut("vocab") {
                vocab.insert(
                    "shapes".into(),
                    json::arr(vec![json::s("blob")]),
                );
            }
        }
        let m = Manifest::from_value(Path::new("/tmp"), &v).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn artifact_path_joins_root() {
        let m = Manifest::from_value(Path::new("/data/arts"), &sample()).unwrap();
        assert_eq!(
            m.artifact_path("d1.hlo.txt"),
            PathBuf::from("/data/arts/d1.hlo.txt")
        );
    }
}
