//! `perfstat`: a small benchmarking harness (criterion substitute for the
//! offline vendor set). Warmup + timed iterations + robust summary stats;
//! used by the `cargo bench` targets (all `harness = false`).

use std::time::Instant;

/// Timing summary over iterations.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub min_ms: f64,
}

impl Summary {
    /// Summarize an existing sample set (milliseconds) — the constructor
    /// for harnesses that collect their own timings (e.g. `agd replay`
    /// wire latencies) instead of timing a closure via [`bench`]. An
    /// empty sample set yields an all-zero row rather than an error, so
    /// a fully-shed replay still produces a report.
    pub fn from_samples_ms(name: &str, samples_ms: &[f64]) -> Summary {
        if samples_ms.is_empty() {
            return Summary {
                name: name.to_owned(),
                iters: 0,
                mean_ms: 0.0,
                p50_ms: 0.0,
                p95_ms: 0.0,
                p99_ms: 0.0,
                min_ms: 0.0,
            };
        }
        let mut sorted = samples_ms.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            name: name.to_owned(),
            iters: sorted.len(),
            mean_ms: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50_ms: crate::stats::percentile_sorted(&sorted, 50.0),
            p95_ms: crate::stats::percentile_sorted(&sorted, 95.0),
            p99_ms: crate::stats::percentile_sorted(&sorted, 99.0),
            min_ms: sorted[0],
        }
    }

    /// JSON form of one row — the unit of the machine-readable perf
    /// trajectory (`--out` on the bench harnesses).
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{num, obj, s};
        obj(vec![
            ("name", s(&self.name)),
            ("iters", num(self.iters as f64)),
            ("mean_ms", num(self.mean_ms)),
            ("p50_ms", num(self.p50_ms)),
            ("p95_ms", num(self.p95_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("min_ms", num(self.min_ms)),
        ])
    }

    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            self.iters.to_string(),
            format!("{:.3}", self.mean_ms),
            format!("{:.3}", self.p50_ms),
            format!("{:.3}", self.p95_ms),
            format!("{:.3}", self.p99_ms),
            format!("{:.3}", self.min_ms),
        ]
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Summary::from_samples_ms(name, &samples)
}

/// Print a set of summaries as an aligned table.
pub fn print_summaries(rows: &[Summary]) {
    crate::eval::harness::print_table(
        &["benchmark", "iters", "mean ms", "p50 ms", "p95 ms", "p99 ms", "min ms"],
        &rows.iter().map(Summary::row).collect::<Vec<_>>(),
    );
}

/// Bundle a bench run for a `--out <path>` dump: every [`Summary`] row plus
/// free-form derived scalars (e.g. per-NFE overheads) keyed by name.
pub fn summaries_to_json(
    rows: &[Summary],
    derived: &[(&str, f64)],
) -> crate::util::json::Value {
    use crate::util::json::{arr, num, obj, Value};
    let derived = Value::Obj(
        derived
            .iter()
            .map(|&(k, v)| (k.to_owned(), num(v)))
            .collect(),
    );
    obj(vec![
        ("benchmarks", arr(rows.iter().map(Summary::to_json).collect())),
        ("derived", derived),
    ])
}

/// Write a [`summaries_to_json`] dump to `path`.
pub fn write_json(path: &str, rows: &[Summary], derived: &[(&str, f64)]) {
    let text = crate::util::json::to_string(&summaries_to_json(rows, derived));
    std::fs::write(path, text).unwrap_or_else(|e| panic!("writing --out {path}: {e}"));
    eprintln!("perf rows written to {path}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summaries_round_trip_through_json() {
        let s = bench("spin", 1, 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let v = summaries_to_json(&[s], &[("per_nfe_us", 1.25)]);
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        let rows = back.req("benchmarks").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].req("name").as_str(), Some("spin"));
        assert_eq!(rows[0].req("iters").as_usize(), Some(5));
        assert!(rows[0].req("p50_ms").as_f64().unwrap() >= 0.0);
        assert_eq!(back.req("derived").req("per_nfe_us").as_f64(), Some(1.25));
    }

    #[test]
    fn bench_reports_sane_numbers() {
        let s = bench("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.iters, 20);
        assert!(s.min_ms <= s.p50_ms);
        assert!(s.p50_ms <= s.p95_ms + 1e-9);
        assert!(s.p95_ms <= s.p99_ms + 1e-9);
        assert!(s.mean_ms > 0.0);
    }

    #[test]
    fn from_samples_summarizes_external_timings() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::from_samples_ms("wire", &samples);
        assert_eq!(s.iters, 100);
        assert_eq!(s.min_ms, 1.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        assert!(s.p50_ms >= 49.0 && s.p50_ms <= 51.0, "{}", s.p50_ms);
        assert!(s.p99_ms >= 98.0 && s.p99_ms <= 100.0, "{}", s.p99_ms);
        // order-independence: the constructor sorts
        let mut shuffled = samples.clone();
        shuffled.reverse();
        assert_eq!(Summary::from_samples_ms("wire", &shuffled).p99_ms, s.p99_ms);
        // an all-shed replay (no samples) still yields a row
        let empty = Summary::from_samples_ms("none", &[]);
        assert_eq!(empty.iters, 0);
        assert_eq!(empty.p99_ms, 0.0);
    }
}
