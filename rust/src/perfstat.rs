//! `perfstat`: a small benchmarking harness (criterion substitute for the
//! offline vendor set). Warmup + timed iterations + robust summary stats;
//! used by the `cargo bench` targets (all `harness = false`).

use std::time::Instant;

/// Timing summary over iterations.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

impl Summary {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            self.iters.to_string(),
            format!("{:.3}", self.mean_ms),
            format!("{:.3}", self.p50_ms),
            format!("{:.3}", self.p95_ms),
            format!("{:.3}", self.min_ms),
        ]
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        name: name.to_owned(),
        iters,
        mean_ms: samples.iter().sum::<f64>() / iters as f64,
        p50_ms: crate::stats::percentile_sorted(&samples, 50.0),
        p95_ms: crate::stats::percentile_sorted(&samples, 95.0),
        min_ms: samples[0],
    }
}

/// Print a set of summaries as an aligned table.
pub fn print_summaries(rows: &[Summary]) {
    crate::eval::harness::print_table(
        &["benchmark", "iters", "mean ms", "p50 ms", "p95 ms", "min ms"],
        &rows.iter().map(Summary::row).collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let s = bench("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.iters, 20);
        assert!(s.min_ms <= s.p50_ms);
        assert!(s.p50_ms <= s.p95_ms + 1e-9);
        assert!(s.mean_ms > 0.0);
    }
}
