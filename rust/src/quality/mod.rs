//! Image-quality metrics substrate: SSIM (the paper's replication metric in
//! Table 1 / Figs. 5, 9), PSNR/MSE, and a high-frequency sharpness proxy used
//! by the simulated annotator panel (the paper notes CFG "tends to produce
//! higher frequencies" — Fig. 6).

pub mod ssim;

/// Mean squared error over interleaved RGB buffers.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// PSNR in dB for images in [-1, 1] (dynamic range 2.0).
pub fn psnr(a: &[f32], b: &[f32]) -> f64 {
    let m = mse(a, b);
    if m == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (4.0 / m).log10()
}

/// High-frequency energy: mean squared Laplacian response over the image.
/// A cheap proxy for perceived sharpness / high-frequency content.
pub fn high_freq_energy(img: &[f32], width: usize, height: usize) -> f64 {
    assert_eq!(img.len(), width * height * 3);
    let mut acc = 0.0;
    let mut count = 0usize;
    for y in 1..height - 1 {
        for x in 1..width - 1 {
            for c in 0..3 {
                let at = |yy: usize, xx: usize| img[(yy * width + xx) * 3 + c] as f64;
                let lap =
                    4.0 * at(y, x) - at(y - 1, x) - at(y + 1, x) - at(y, x - 1) - at(y, x + 1);
                acc += lap * lap;
                count += 1;
            }
        }
    }
    acc / count as f64
}

/// Convert interleaved RGB to per-channel luma (Rec. 601) — SSIM operates on
/// luma, matching common SSIM implementations.
pub fn luma(img: &[f32]) -> Vec<f32> {
    img.chunks_exact(3)
        .map(|p| 0.299 * p[0] + 0.587 * p[1] + 0.114 * p[2])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_zero_for_identical() {
        let a = vec![0.3f32; 48];
        assert_eq!(mse(&a, &a), 0.0);
        assert_eq!(psnr(&a, &a), f64::INFINITY);
    }

    #[test]
    fn psnr_known_value() {
        let a = vec![0.0f32; 300];
        let b = vec![0.2f32; 300];
        // mse = 0.04 → psnr = 10 log10(4/0.04) = 20 dB (f32 rounding)
        assert!((psnr(&a, &b) - 20.0).abs() < 1e-3);
    }

    #[test]
    fn high_freq_flat_is_zero_noise_is_high() {
        let flat = vec![0.5f32; 16 * 16 * 3];
        assert_eq!(high_freq_energy(&flat, 16, 16), 0.0);
        let mut rng = crate::util::rng::Rng::new(0);
        let noisy: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.normal() as f32).collect();
        assert!(high_freq_energy(&noisy, 16, 16) > 1.0);
    }

    #[test]
    fn luma_weights() {
        let img = [1.0f32, 0.0, 0.0, 0.0, 1.0, 0.0];
        let l = luma(&img);
        assert!((l[0] - 0.299).abs() < 1e-6);
        assert!((l[1] - 0.587).abs() < 1e-6);
    }
}
