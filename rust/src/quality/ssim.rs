//! SSIM (Wang et al. 2004) with a gaussian window — the metric the paper
//! uses to quantify how closely an efficient policy replicates the CFG
//! baseline (Table 1, Figs. 5/9).
//!
//! Operates on luma; the window size shrinks gracefully for small images
//! (our 16x16 testbed uses a 7x7 window, σ = 1.5, matching the standard
//! parameterization scaled to resolution).

use super::luma;

const K1: f64 = 0.01;
const K2: f64 = 0.03;

/// Gaussian window weights (normalized), side = 2*radius + 1.
fn gaussian_window(radius: usize, sigma: f64) -> Vec<f64> {
    let side = 2 * radius + 1;
    let mut w = vec![0.0; side * side];
    let mut sum = 0.0;
    for y in 0..side {
        for x in 0..side {
            let dy = y as f64 - radius as f64;
            let dx = x as f64 - radius as f64;
            let g = (-(dx * dx + dy * dy) / (2.0 * sigma * sigma)).exp();
            w[y * side + x] = g;
            sum += g;
        }
    }
    for v in &mut w {
        *v /= sum;
    }
    w
}

/// SSIM between two luma planes with dynamic range `l_range`.
pub fn ssim_luma(a: &[f32], b: &[f32], width: usize, height: usize, l_range: f64) -> f64 {
    assert_eq!(a.len(), width * height);
    assert_eq!(b.len(), width * height);
    let radius = 3usize.min((width.min(height) - 1) / 2);
    let win = gaussian_window(radius, 1.5);
    let side = 2 * radius + 1;
    let c1 = (K1 * l_range).powi(2);
    let c2 = (K2 * l_range).powi(2);

    let mut acc = 0.0;
    let mut count = 0usize;
    for cy in radius..height - radius {
        for cx in radius..width - radius {
            let (mut ma, mut mb) = (0.0f64, 0.0f64);
            for wy in 0..side {
                for wx in 0..side {
                    let w = win[wy * side + wx];
                    let idx = (cy + wy - radius) * width + (cx + wx - radius);
                    ma += w * a[idx] as f64;
                    mb += w * b[idx] as f64;
                }
            }
            let (mut va, mut vb, mut cov) = (0.0f64, 0.0f64, 0.0f64);
            for wy in 0..side {
                for wx in 0..side {
                    let w = win[wy * side + wx];
                    let idx = (cy + wy - radius) * width + (cx + wx - radius);
                    let da = a[idx] as f64 - ma;
                    let db = b[idx] as f64 - mb;
                    va += w * da * da;
                    vb += w * db * db;
                    cov += w * da * db;
                }
            }
            let s = ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                / ((ma * ma + mb * mb + c1) * (va + vb + c2));
            acc += s;
            count += 1;
        }
    }
    acc / count as f64
}

/// SSIM between two RGB images in [-1, 1] (converted to luma internally).
pub fn ssim_rgb(a: &[f32], b: &[f32], width: usize, height: usize) -> f64 {
    ssim_luma(&luma(a), &luma(b), width, height, 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_img(seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..16 * 16 * 3)
            .map(|_| rng.range(-1.0, 1.0) as f32)
            .collect()
    }

    #[test]
    fn identical_images_score_one() {
        let a = random_img(0);
        assert!((ssim_rgb(&a, &a, 16, 16) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn symmetric() {
        let a = random_img(1);
        let b = random_img(2);
        let ab = ssim_rgb(&a, &b, 16, 16);
        let ba = ssim_rgb(&b, &a, 16, 16);
        assert!((ab - ba).abs() < 1e-12);
    }

    #[test]
    fn bounded() {
        for s in 0..5 {
            let a = random_img(s);
            let b = random_img(s + 100);
            let v = ssim_rgb(&a, &b, 16, 16);
            assert!((-1.0..=1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn degrades_monotonically_with_noise() {
        let a = random_img(3);
        let mut rng = Rng::new(99);
        let noise: Vec<f32> = (0..a.len()).map(|_| rng.normal() as f32).collect();
        let mut prev = 1.0;
        for &level in &[0.05f32, 0.15, 0.4, 1.0] {
            let b: Vec<f32> = a
                .iter()
                .zip(&noise)
                .map(|(&x, &n)| x + level * n)
                .collect();
            let s = ssim_rgb(&a, &b, 16, 16);
            assert!(s < prev, "ssim did not decrease at noise {level}: {s} >= {prev}");
            prev = s;
        }
    }

    #[test]
    fn unrelated_images_score_low() {
        let a = random_img(10);
        let b = random_img(20);
        assert!(ssim_rgb(&a, &b, 16, 16) < 0.3);
    }

    #[test]
    fn window_normalized() {
        let w = gaussian_window(3, 1.5);
        let sum: f64 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }
}
