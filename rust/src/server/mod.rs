//! Line-protocol serving front-end (std::net + mini-JSON; the offline
//! vendor set has no tokio, so the event loop is threads + channels).
//!
//! Protocol: one JSON object per line.
//!
//! request  {"prompt": "a large red circle at the center", "policy": "ag",
//!           "gamma_bar": 0.991, "steps": 20, "guidance": 7.5, "seed": 1,
//!           "negative": "green", "image": false,
//!           "client_id": "web", "priority": 1, "deadline_ms": 2500}
//! response {"id": 3, "policy": "ag(ḡ=0.991)", "nfes": 31, "cfg_steps": 11,
//!           "truncated_at": 10, "ms": 128.4, "image": [...]?}
//! error    {"error": "...", "registered": ["ag", "cfg", ...]?}
//! shed     {"error": "queue full: ...", "code": "queue_full", ...}
//! command  {"cmd": "stats"}
//!          → {"scheduler": "cost-aware", "active": 3, "queue_depth": 9,
//!             "queued_nfes": 118, ..., "telemetry": {"counters": {...},
//!             "gauges": {...}, "histograms": {...}}}
//! command  {"cmd": "metrics"}
//!          → Prometheus text exposition of the same telemetry registry
//!            (`# TYPE`-annotated counter/gauge/histogram samples). This
//!            is the one multi-line reply in the protocol: it is
//!            terminated by a blank line, so scrapers read until the
//!            first empty line (everything else stays one line per
//!            reply).
//!
//! The `"policy"` field is a [`PolicySpec`]: either a bare registered name
//! (`"linear-ag"`, `"compressed-cfg"`, a `--policy-file` alias, …) or an
//! object `{"kind": "searched", "choices": [...]}`. Top-level convenience
//! fields (`guidance` → `s`, `gamma_bar`, `cfg_steps`, `period`,
//! `choices`, `coeffs`, …) fill parameters the policy object leaves unset,
//! so simple clients never need the nested form. Unknown policy names
//! produce a structured JSON error listing the registered policies instead
//! of a dropped connection.
//!
//! Scheduling envelope fields are optional: `client_id` names the
//! fair-share lane (and the `client=` telemetry label), `priority` and
//! `deadline_ms` feed the `deadline` scheduler. `deadline_ms` counts
//! *from the request's arrival* (the engine anchors it to its own clock,
//! so client clock skew cannot invert the EDF order). The discipline itself is
//! server-side (`agd serve --scheduler fifo|cost-aware|deadline|
//! fair-share`), as are the admission budgets (`--max-queued-nfes`,
//! `--max-in-flight`, and the per-client `--max-in-flight-per-client`) —
//! a request past a budget is shed with a `queue_full` error while
//! in-flight requests run to completion. `--workers N` sizes the engine's
//! worker pool (default: available parallelism); it changes throughput
//! only, never results.
//!
//! The engine runs on a dedicated thread (it owns the PJRT client);
//! connection handlers forward requests through an mpsc channel and block on
//! a per-request response channel — requests from many connections batch
//! together inside the engine exactly like the drain-mode benches.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::coordinator::engine::Engine;
use crate::coordinator::request::{Completion, Request};
use crate::coordinator::spec::{PolicyRegistry, PolicySpec, SpecError};
use crate::prompts::Prompt;
use crate::sched::{Admission, AdmitError, SchedulerKind};
use crate::util::json::{self, Value};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub model: String,
    pub default_steps: usize,
    pub default_guidance: f64,
    pub default_gamma_bar: f64,
    /// Scheduling discipline the engine runs (`--scheduler`).
    pub scheduler: SchedulerKind,
    /// Admission budgets (`--max-in-flight` / `--max-queued-nfes` /
    /// `--max-in-flight-per-client`).
    pub admission: Admission,
    /// Worker lanes for the engine's parallel hot loops (`--workers`);
    /// 0 = available parallelism (§Perf: parallel execution).
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:7458".into(),
            model: "dit_b".into(),
            default_steps: 20,
            default_guidance: 7.5,
            default_gamma_bar: 0.9988,
            scheduler: SchedulerKind::Fifo,
            admission: Admission::unlimited(),
            workers: 0,
        }
    }
}

/// Top-level request fields that are *not* policy parameters.
const ENVELOPE_KEYS: &[&str] = &[
    "prompt", "policy", "steps", "seed", "negative", "image", "model", "src_image", "guidance",
    "client_id", "priority", "deadline_ms",
];

/// Parse one protocol line into a [`Request`] (without an id — the engine
/// thread assigns ids).
pub fn parse_request_line(
    line: &str,
    cfg: &ServerConfig,
    registry: &PolicyRegistry,
) -> Result<(Request, bool)> {
    let v = json::parse(line).map_err(|e| anyhow!("bad request json: {e}"))?;
    parse_request_value(&v, cfg, registry)
}

/// Build a [`Request`] from an already-parsed protocol object — the
/// serving path parses each line exactly once (`dispatch_line`).
pub fn parse_request_value(
    v: &Value,
    cfg: &ServerConfig,
    registry: &PolicyRegistry,
) -> Result<(Request, bool)> {
    let prompt_text = v
        .get("prompt")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("missing `prompt`"))?;
    let prompt = Prompt::parse(prompt_text).ok_or_else(|| anyhow!("unparseable prompt"))?;
    let steps = v
        .get("steps")
        .and_then(Value::as_usize)
        .unwrap_or(cfg.default_steps);

    // policy spec: bare name or object; top-level fields fill the gaps.
    let mut spec = match v.get("policy") {
        None => PolicySpec::new("ag"),
        Some(pv) => PolicySpec::from_json(pv)?,
    };
    if let Some(obj) = v.as_obj() {
        for (key, val) in obj {
            if !ENVELOPE_KEYS.contains(&key.as_str()) {
                spec.set_default(key, val.clone());
            }
        }
    }
    if let Some(g) = v.get("guidance").and_then(Value::as_f64) {
        spec.set_default("s", json::num(g));
    }
    // expand `--policy-file` aliases now, so the server defaults below fill
    // only what neither the client nor the preset set
    let mut spec = registry.resolve(&spec)?;
    // the server's configured defaults fill whatever is still unset
    spec.set_default("s", json::num(cfg.default_guidance));
    if spec.canonical_kind() == "ag" {
        spec.set_default("gamma_bar", json::num(cfg.default_gamma_bar));
    }
    let policy = registry.build(&spec)?;
    // reject bad policy/request combinations here (error reply) rather
    // than letting them panic the engine thread mid-generation
    policy
        .validate(steps)
        .map_err(|e| anyhow!("policy `{}` rejected the request: {e}", policy.name()))?;

    let mut req = Request::new(
        0,
        &v.get("model")
            .and_then(Value::as_str)
            .unwrap_or(&cfg.model)
            .to_owned(),
        prompt.tokens(),
        v.get("seed").and_then(Value::as_f64).unwrap_or(0.0) as u64,
        steps,
        policy,
    );
    if let Some(neg) = v.get("negative").and_then(Value::as_str) {
        let p = Prompt::parse(neg).unwrap();
        // negative prompts set only the slots mentioned; color-only is the
        // common case, so map any parsed attribute that differs from default
        let mut toks = vec![0i32; 4];
        let lower = neg.to_lowercase();
        if crate::prompts::SHAPES.iter().any(|s| lower.contains(s)) {
            toks[0] = p.shape as i32 + 1;
        }
        if crate::prompts::COLORS.iter().any(|s| lower.contains(s)) {
            toks[1] = p.color as i32 + 1;
        }
        if crate::prompts::POSITIONS.iter().any(|s| lower.contains(s)) {
            toks[2] = p.position as i32 + 1;
        }
        if crate::prompts::SIZES.iter().any(|s| lower.contains(s)) {
            toks[3] = p.size as i32 + 1;
        }
        req.neg_tokens = Some(toks);
    }
    if let Some(src) = v.get("src_image") {
        let vals = src
            .as_f64_vec()
            .ok_or_else(|| anyhow!("`src_image` must be an array of numbers"))?;
        req.src_image = Some(vals.into_iter().map(|f| f as f32).collect());
    }
    // scheduling envelope: fair-share lane, EDF deadline, priority
    if let Some(client) = v.get("client_id").and_then(Value::as_str) {
        req.client_id = Some(Arc::from(client));
    }
    if let Some(p) = v.get("priority").and_then(Value::as_f64) {
        req.priority = p as i32;
    }
    if let Some(d) = v.get("deadline_ms").and_then(Value::as_f64) {
        req.deadline_ms = Some(d as u64);
    }
    let want_image = v.get("image").and_then(Value::as_bool).unwrap_or(false);
    Ok((req, want_image))
}

/// Encode a completion as a protocol line (the serving policy's display
/// name is echoed so clients can attribute per-policy cost).
pub fn completion_to_line(c: &Completion, ms: f64, with_image: bool) -> String {
    use json::{arr, num, obj, s};
    let mut fields = vec![
        ("id", num(c.id as f64)),
        ("policy", s(&c.policy)),
        ("nfes", num(c.nfes as f64)),
        ("cfg_steps", num(c.cfg_steps as f64)),
        ("ms", num((ms * 100.0).round() / 100.0)),
        (
            "truncated_at",
            c.truncated_at.map(|t| num(t as f64)).unwrap_or(Value::Null),
        ),
    ];
    if with_image {
        fields.push((
            "image",
            arr(c.image.iter().map(|&v| num(v as f64)).collect()),
        ));
    }
    json::to_string(&obj(fields))
}

/// Encode an error as a structured protocol line (proper JSON escaping).
/// Unknown-policy errors carry the registered names; admission shedding
/// carries `"code": "queue_full"` plus the budget numbers so clients can
/// back off proportionally; malformed requests refused at the door carry
/// `"code": "invalid_request"`.
pub fn error_to_line(e: &anyhow::Error) -> String {
    let mut fields = vec![("error", json::s(&format!("{e:#}")))];
    if let Some(SpecError::UnknownPolicy { known, .. }) = e.downcast_ref::<SpecError>() {
        fields.push((
            "registered",
            json::arr(known.iter().map(|n| json::s(n)).collect()),
        ));
    }
    if let Some(refused) = e.downcast_ref::<AdmitError>() {
        match refused {
            AdmitError::InFlightFull { in_flight, max } => {
                fields.push(("code", json::s("queue_full")));
                fields.push(("in_flight", json::num(*in_flight as f64)));
                fields.push(("max_in_flight", json::num(*max as f64)));
            }
            AdmitError::NfeBudgetFull {
                queued_nfes,
                request_nfes,
                max,
            } => {
                fields.push(("code", json::s("queue_full")));
                fields.push(("queued_nfes", json::num(*queued_nfes as f64)));
                fields.push(("request_nfes", json::num(*request_nfes as f64)));
                fields.push(("max_queued_nfes", json::num(*max as f64)));
            }
            AdmitError::ClientBusy {
                client,
                in_flight,
                max,
            } => {
                fields.push(("code", json::s("queue_full")));
                fields.push(("client", json::s(client)));
                fields.push(("in_flight", json::num(*in_flight as f64)));
                fields.push(("max_in_flight_per_client", json::num(*max as f64)));
            }
            AdmitError::Invalid { reason } => {
                fields.push(("code", json::s("invalid_request")));
                fields.push(("reason", json::s(reason)));
            }
        }
    }
    json::to_string(&json::obj(fields))
}

struct Job {
    req: Request,
    want_image: bool,
    started: Instant,
    reply: Sender<String>,
}

/// What connection handlers send to the engine thread.
enum Msg {
    Job(Job),
    /// `{"cmd": "stats"}`: reply with the engine's stats snapshot.
    Stats(Sender<String>),
    /// `{"cmd": "metrics"}`: reply with the Prometheus text exposition of
    /// the telemetry registry.
    Metrics(Sender<String>),
}

/// Engine thread: batch whatever is queued, reply per request.
fn engine_loop<B: Backend>(mut engine: Engine<B>, rx: Receiver<Msg>) {
    let mut next_id: u64 = 0;
    let mut jobs: HashMap<u64, Job> = HashMap::new();
    loop {
        // admit new work; block when fully idle (no busy spin)
        if engine.idle() {
            match rx.recv() {
                Ok(msg) => handle_msg(&mut engine, &mut jobs, &mut next_id, msg),
                Err(_) => return, // all senders gone → shut down
            }
        }
        loop {
            match rx.try_recv() {
                Ok(msg) => handle_msg(&mut engine, &mut jobs, &mut next_id, msg),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if engine.idle() {
                        return;
                    }
                    break;
                }
            }
        }
        match engine.pump() {
            Ok(completions) => {
                for c in completions {
                    if let Some(job) = jobs.remove(&c.id) {
                        let ms = job.started.elapsed().as_secs_f64() * 1e3;
                        let line = completion_to_line(&c, ms, job.want_image);
                        let _ = job.reply.send(line);
                    }
                }
            }
            Err(e) => {
                log::error!("engine pump failed: {e:#}");
                let line = error_to_line(&e);
                for (_, job) in jobs.drain() {
                    let _ = job.reply.send(line.clone());
                }
                return;
            }
        }
    }
}

fn handle_msg<B: Backend>(
    engine: &mut Engine<B>,
    jobs: &mut HashMap<u64, Job>,
    next_id: &mut u64,
    msg: Msg,
) {
    match msg {
        Msg::Job(job) => admit(engine, jobs, next_id, job),
        Msg::Stats(reply) => {
            let _ = reply.send(json::to_string(&engine.stats_json()));
        }
        Msg::Metrics(reply) => {
            let _ = reply.send(engine.telemetry().to_prometheus());
        }
    }
}

/// Assign an id and admit against the budget; a shed request gets its
/// `queue_full` reply immediately and never touches the queue.
fn admit<B: Backend>(
    engine: &mut Engine<B>,
    jobs: &mut HashMap<u64, Job>,
    next_id: &mut u64,
    mut job: Job,
) {
    job.req.id = *next_id;
    *next_id += 1;
    match engine.try_submit(job.req.clone()) {
        Ok(()) => {
            jobs.insert(job.req.id, job);
        }
        Err(e) => {
            let _ = job.reply.send(error_to_line(&anyhow::Error::new(e)));
        }
    }
}

/// Dispatch one protocol line: a `{"cmd": ..}` control line or a
/// generation request. Returns the reply line, or None when the engine
/// thread is gone and the connection should close.
fn dispatch_line(
    line: &str,
    tx: &Sender<Msg>,
    cfg: &ServerConfig,
    registry: &PolicyRegistry,
) -> Option<String> {
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return Some(error_to_line(&anyhow!("bad request json: {e}"))),
    };
    if let Some(cmd) = v.get("cmd").and_then(Value::as_str) {
        if cmd == "stats" {
            let (rtx, rrx) = channel();
            if tx.send(Msg::Stats(rtx)).is_err() {
                return None;
            }
            return rrx.recv().ok();
        }
        if cmd == "metrics" {
            let (rtx, rrx) = channel();
            if tx.send(Msg::Metrics(rtx)).is_err() {
                return None;
            }
            // the exposition is multi-line; the connection handler's
            // closing "\n" turns the trailing newline into the blank-line
            // terminator the protocol docs promise
            return rrx.recv().ok();
        }
        return Some(error_to_line(&anyhow!(
            "unknown cmd `{cmd}` (supported: stats, metrics)"
        )));
    }
    match parse_request_value(&v, cfg, registry) {
        Ok((req, want_image)) => {
            let (rtx, rrx) = channel();
            let job = Job {
                req,
                want_image,
                started: Instant::now(),
                reply: rtx,
            };
            if tx.send(Msg::Job(job)).is_err() {
                return None;
            }
            rrx.recv().ok()
        }
        Err(e) => Some(error_to_line(&e)),
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<Msg>,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_default();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let Some(reply_line) = dispatch_line(&line, &tx, &cfg, &registry) else {
            break;
        };
        if writer.write_all(reply_line.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    log::info!("connection {peer} closed");
}

/// Serve forever with the built-in policy registry.
pub fn serve<B, F>(factory: F, cfg: ServerConfig) -> Result<()>
where
    B: Backend + 'static,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    serve_with_registry(factory, cfg, Arc::new(PolicyRegistry::builtin()))
}

/// Serve forever (or until the listener errors) with a caller-supplied
/// registry — the hook for deployments that register custom policies.
///
/// `factory` constructs the backend *inside* the engine thread — the PJRT
/// client is thread-affine (not `Send`), so it must be born where it runs.
pub fn serve_with_registry<B, F>(
    factory: F,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
) -> Result<()>
where
    B: Backend + 'static,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    let (tx, rx) = channel::<Msg>();
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!(
        "agd serving on {} (model {}, scheduler {})",
        cfg.addr,
        cfg.model,
        cfg.scheduler.name()
    );
    let (scheduler, admission) = (cfg.scheduler, cfg.admission);
    let workers = if cfg.workers == 0 {
        crate::exec::default_workers()
    } else {
        cfg.workers
    };
    std::thread::spawn(move || {
        let engine =
            factory().and_then(|be| Engine::with_scheduler(be, scheduler.build(), admission));
        match engine {
            Ok(mut engine) => {
                // the worker pool spawns once, here, inside the engine
                // thread (§Perf: parallel execution)
                engine.set_workers(workers);
                engine_loop(engine, rx)
            }
            Err(e) => log::error!("backend construction failed: {e:#}"),
        }
    });
    for stream in listener.incoming() {
        let stream = stream?;
        let tx = tx.clone();
        let cfg = cfg.clone();
        let registry = registry.clone();
        std::thread::spawn(move || handle_conn(stream, tx, cfg, registry));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::ols::OlsCoeffs;
    use crate::sim::gmm::Gmm;

    fn cfg() -> ServerConfig {
        ServerConfig {
            model: "gmm".into(),
            ..Default::default()
        }
    }

    fn reg() -> PolicyRegistry {
        PolicyRegistry::builtin()
    }

    fn parse(line: &str) -> Result<(Request, bool)> {
        parse_request_line(line, &cfg(), &reg())
    }

    #[test]
    fn parses_minimal_request() {
        let (req, img) = parse(r#"{"prompt": "red circle"}"#).unwrap();
        assert_eq!(req.tokens, vec![1, 1, 1, 1]);
        assert_eq!(req.steps, 20);
        assert!(!img);
        assert!(req.policy.name().starts_with("ag("));
        // the configured default gamma-bar flows into the default policy
        assert!(req.policy.name().contains("0.9988"));
    }

    #[test]
    fn parses_full_request() {
        let line = r#"{"prompt": "a large blue square at the top-left",
            "policy": "cfg", "steps": 10, "guidance": 5.0, "seed": 9,
            "negative": "red", "image": true}"#;
        let (req, img) = parse(line).unwrap();
        assert_eq!(req.steps, 10);
        assert!(img);
        assert_eq!(req.policy.name(), "cfg(s=5)");
        assert_eq!(req.neg_tokens, Some(vec![0, 1, 0, 0])); // red = color 1
        assert_eq!(req.seed, 9);
    }

    #[test]
    fn parses_every_registered_policy_kind() {
        // server parity: policies that used to be CLI/bench-only are now
        // reachable through the line protocol via PolicySpec.
        let coeffs = json::to_string(&OlsCoeffs::identity(8).to_json());
        let lines = [
            format!(r#"{{"prompt": "x", "policy": "linear-ag", "steps": 8, "coeffs": {coeffs}}}"#),
            r#"{"prompt": "x", "policy": "ag-prefix", "cfg_steps": 3}"#.to_owned(),
            r#"{"prompt": "x", "policy": "alternating"}"#.to_owned(),
            r#"{"prompt": "x", "policy": "searched", "choices": ["cfg", "cond", "uncond", 2.5]}"#
                .to_owned(),
            r#"{"prompt": "x", "policy": "pix2pix", "src_image": [0.0, 0.5]}"#.to_owned(),
            r#"{"prompt": "x", "policy": "compressed-cfg", "period": 5}"#.to_owned(),
            r#"{"prompt": "x", "policy": "adaptive-scale", "s_max": 6.0, "s_min": 1.0}"#.to_owned(),
            r#"{"prompt": "x", "policy": {"kind": "ag-prefix", "cfg_steps": 2, "s": 3.0}}"#
                .to_owned(),
        ];
        for line in &lines {
            let (req, _) = parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert!(req.policy.max_nfes(req.steps) >= req.steps, "{line}");
        }
        // a coefficient table shorter than the request is an error reply,
        // not an engine-thread panic
        let short = format!(
            r#"{{"prompt": "x", "policy": "linear-ag", "steps": 20, "coeffs": {coeffs}}}"#
        );
        let err = parse(&short).unwrap_err();
        assert!(err.to_string().contains("cover"), "{err}");

        // spot-check parameters actually reached the policies
        let (req, _) = parse(&lines[1]).unwrap();
        assert_eq!(req.policy.max_nfes(20), 23); // 3 guided + 17 cond
        let (req, _) = parse(&lines[4]).unwrap();
        assert_eq!(req.src_image.as_deref(), Some(&[0.0f32, 0.5][..]));
        let (req, _) = parse(&lines[7]).unwrap();
        assert_eq!(req.policy.max_nfes(20), 22); // nested object form
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse("not json").is_err());
        assert!(parse(r#"{"no_prompt": 1}"#).is_err());
        assert!(parse(r#"{"prompt": "x", "policy": "warp"}"#).is_err());
    }

    #[test]
    fn unknown_policy_yields_structured_error_listing_registered() {
        let err = parse(r#"{"prompt": "x", "policy": "warp"}"#).unwrap_err();
        let line = error_to_line(&err);
        let v = json::parse(&line).unwrap_or_else(|e| panic!("error line not json: {line} ({e})"));
        assert!(v.req("error").as_str().unwrap().contains("warp"));
        let registered = v.req("registered").as_str_vec().unwrap();
        assert!(registered.contains(&"ag".to_owned()));
        assert!(registered.contains(&"compressed-cfg".to_owned()));
        assert!(registered.contains(&"adaptive-scale".to_owned()));

        // non-spec errors still produce valid JSON (escaping included)
        let err = parse(r#"{"prompt": 42}"#).unwrap_err();
        let line = error_to_line(&err);
        assert!(json::parse(&line).is_ok(), "{line}");
    }

    #[test]
    fn completion_roundtrip_line() {
        let c = Completion {
            id: 7,
            policy: "ag(ḡ=0.991)".into(),
            image: vec![0.5, -0.5],
            nfes: 31,
            cfg_steps: 11,
            truncated_at: Some(10),
            gammas: vec![],
            gammas_eps: vec![],
            trajectory: None,
            iterates: vec![],
        };
        let line = completion_to_line(&c, 12.345, true);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.req("nfes").as_f64(), Some(31.0));
        assert_eq!(v.req("truncated_at").as_f64(), Some(10.0));
        assert_eq!(v.req("policy").as_str(), Some("ag(ḡ=0.991)"));
        assert_eq!(v.req("image").as_arr().unwrap().len(), 2);
        let line2 = completion_to_line(&c, 1.0, false);
        assert!(json::parse(&line2).unwrap().get("image").is_none());
    }

    #[test]
    fn scheduling_envelope_fields_parse() {
        let line = r#"{"prompt": "red circle", "client_id": "web-42",
            "priority": 3, "deadline_ms": 2500}"#;
        let (req, _) = parse(line).unwrap();
        assert_eq!(req.client_id.as_deref(), Some("web-42"));
        assert_eq!(req.priority, 3);
        assert_eq!(req.deadline_ms, Some(2500));
        // none of them leak into policy parameters
        assert!(req.policy.name().starts_with("ag("));
        // and they stay optional
        let (req, _) = parse(r#"{"prompt": "red circle"}"#).unwrap();
        assert_eq!(req.client_id, None);
        assert_eq!(req.priority, 0);
        assert_eq!(req.deadline_ms, None);
    }

    #[test]
    fn alias_presets_resolve_under_server_defaults() {
        let mut reg = PolicyRegistry::builtin();
        reg.register_alias(
            "fast-ag",
            PolicySpec::new("ag").with("gamma_bar", json::num(0.5)),
        )
        .unwrap();
        // the preset's gamma_bar beats the server default, while the
        // server's guidance default still fills the unset `s`
        let (req, _) = parse_request_line(
            r#"{"prompt": "red circle", "policy": "fast-ag"}"#,
            &cfg(),
            &reg,
        )
        .unwrap();
        assert_eq!(req.policy.name(), "ag(ḡ=0.5)");
        // an explicit client value beats the preset
        let (req, _) = parse_request_line(
            r#"{"prompt": "red circle", "policy": "fast-ag", "gamma_bar": 0.7}"#,
            &cfg(),
            &reg,
        )
        .unwrap();
        assert_eq!(req.policy.name(), "ag(ḡ=0.7)");
    }

    #[test]
    fn queue_full_errors_are_structured() {
        let e = anyhow::Error::new(AdmitError::NfeBudgetFull {
            queued_nfes: 90,
            request_nfes: 40,
            max: 100,
        });
        let line = error_to_line(&e);
        let v = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("queued_nfes").as_f64(), Some(90.0));
        assert_eq!(v.req("max_queued_nfes").as_f64(), Some(100.0));
        assert!(v.req("error").as_str().unwrap().contains("queue full"));
    }

    #[test]
    fn per_client_queue_full_errors_name_the_limit() {
        let e = anyhow::Error::new(AdmitError::ClientBusy {
            client: Arc::from("web-1"),
            in_flight: 3,
            max: 3,
        });
        let line = error_to_line(&e);
        let v = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("client").as_str(), Some("web-1"));
        assert_eq!(v.req("in_flight").as_f64(), Some(3.0));
        assert_eq!(v.req("max_in_flight_per_client").as_f64(), Some(3.0));
        assert!(v.req("error").as_str().unwrap().contains("per-client limit"));
    }

    #[test]
    fn invalid_request_errors_are_structured() {
        let e = anyhow::Error::new(AdmitError::Invalid {
            reason: "tokens must be non-empty (all-zero = unconditional)",
        });
        let line = error_to_line(&e);
        let v = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        assert_eq!(v.req("code").as_str(), Some("invalid_request"));
        assert!(v.req("reason").as_str().unwrap().contains("tokens"));
        assert!(v.req("error").as_str().unwrap().contains("invalid request"));
    }

    /// Spin up a listener + engine thread on the GMM backend; returns the
    /// address to connect to.
    fn spawn_test_server(scheduler: SchedulerKind, admission: Admission) -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let scfg = ServerConfig {
            addr: addr.to_string(),
            model: "gmm".into(),
            scheduler,
            admission,
            ..Default::default()
        };
        let (tx, rx) = channel::<Msg>();
        std::thread::spawn(move || {
            let backend = GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05));
            let mut engine =
                Engine::with_scheduler(backend, scheduler.build(), admission).unwrap();
            // exercise the sharded execution path under real TCP traffic
            engine.set_workers(2);
            engine_loop(engine, rx)
        });
        let registry = Arc::new(PolicyRegistry::builtin());
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let tx = tx.clone();
                let scfg = scfg.clone();
                let registry = registry.clone();
                std::thread::spawn(move || handle_conn(stream.unwrap(), tx, scfg, registry));
            }
        });
        addr
    }

    /// One request/reply exchange on an open connection.
    fn roundtrip(conn: &mut TcpStream, line: &str) -> Value {
        use std::io::{BufRead, BufReader, Write};
        conn.write_all(line.as_bytes()).unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        json::parse(reply.trim()).unwrap_or_else(|e| panic!("{reply}: {e}"))
    }

    /// Full TCP round trip against the GMM backend.
    #[test]
    fn tcp_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let scfg = ServerConfig {
            addr: addr.to_string(),
            model: "gmm".into(),
            ..Default::default()
        };
        let (tx, rx) = channel::<Msg>();
        std::thread::spawn(move || {
            let backend = GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05));
            engine_loop(Engine::new(backend).unwrap(), rx)
        });
        {
            let scfg = scfg.clone();
            let registry = Arc::new(PolicyRegistry::builtin());
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    let tx = tx.clone();
                    let scfg = scfg.clone();
                    let registry = registry.clone();
                    std::thread::spawn(move || {
                        handle_conn(stream.unwrap(), tx, scfg, registry)
                    });
                }
            });
        }
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(
            br#"{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0}"#,
        )
        .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_none(), "{line}");
        assert!(v.req("nfes").as_f64().unwrap() <= 16.0);
        assert!(
            v.req("policy").as_str().unwrap().starts_with("ag("),
            "{line}"
        );

        // a plugin policy over the same connection: compressed-cfg at
        // period 4 over 8 steps costs exactly 2·2 + 6 = 10 NFEs.
        let mut conn = reader.into_inner();
        conn.write_all(
            br#"{"prompt": "red circle", "policy": "compressed-cfg", "period": 4, "steps": 8, "guidance": 2.0}"#,
        )
        .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(v.req("nfes").as_f64(), Some(10.0), "{line}");

        // unknown policy: structured error, connection stays usable
        let mut conn = reader.into_inner();
        conn.write_all(br#"{"prompt": "red circle", "policy": "warp"}"#)
            .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_some(), "{line}");
        assert!(v.req("registered").as_str_vec().unwrap().len() >= 10);
    }

    /// Admission over the wire: a request past the queued-NFE budget gets
    /// a structured `queue_full` reply, nothing panics, and the connection
    /// keeps serving admissible requests.
    #[test]
    fn tcp_queue_full_shed_and_recovery() {
        // budget below one 8-step CFG request (16 NFEs) but enough for a
        // 4-step one (8 NFEs)
        let admission = Admission {
            max_queued_nfes: Some(10),
            ..Admission::unlimited()
        };
        let addr = spawn_test_server(SchedulerKind::CostAware, admission);
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 8, "guidance": 2.0}"#,
        );
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("max_queued_nfes").as_f64(), Some(10.0));
        assert_eq!(v.req("request_nfes").as_f64(), Some(16.0));
        assert!(v.req("error").as_str().unwrap().contains("queue full"));
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4, "guidance": 2.0}"#,
        );
        assert!(v.get("error").is_none(), "in-budget request must complete");
        assert_eq!(v.req("nfes").as_f64(), Some(8.0));
    }

    /// Per-client quota over the wire: the same client is shed past its
    /// in-flight quota with a `queue_full` line naming the per-client
    /// limit. (Requests on this synchronous test connection complete
    /// before the next is sent, so the quota is exercised with limit 0 —
    /// the shed path — while other clients stay unaffected.)
    #[test]
    fn tcp_per_client_quota_sheds() {
        let admission = Admission {
            max_in_flight_per_client: Some(0),
            ..Admission::unlimited()
        };
        let addr = spawn_test_server(SchedulerKind::Fifo, admission);
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4, "client_id": "greedy"}"#,
        );
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("client").as_str(), Some("greedy"));
        assert_eq!(v.req("max_in_flight_per_client").as_f64(), Some(0.0));
        assert!(v.req("error").as_str().unwrap().contains("per-client limit"));
    }

    /// `{"cmd": "metrics"}` returns Prometheus exposition text terminated
    /// by a blank line, generated from the same registry as the JSON
    /// stats dump.
    #[test]
    fn tcp_metrics_command_returns_prometheus_text() {
        use std::io::{BufRead, BufReader, Write};
        let addr = spawn_test_server(SchedulerKind::Fifo, Admission::unlimited());
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
        let nfes = v.req("nfes").as_f64().unwrap();
        conn.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut exposition = String::new();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            if line.trim().is_empty() {
                break;
            }
            exposition.push_str(&line);
        }
        assert!(
            exposition.contains("# TYPE nfes_total counter"),
            "{exposition}"
        );
        assert!(
            exposition.contains(&format!("nfes_total{{policy=\"ag\"}} {nfes}")),
            "{exposition}"
        );
        assert!(exposition.contains("# TYPE active_requests gauge"), "{exposition}");
        assert!(
            exposition.contains("# TYPE queue_wait_ms histogram"),
            "{exposition}"
        );
        assert!(exposition.contains("queue_wait_ms_count{policy=\"ag\"} 1"), "{exposition}");
        // the connection is still usable after the multi-line reply
        let mut conn = reader.into_inner();
        let stats = roundtrip(&mut conn, r#"{"cmd": "stats"}"#);
        assert!(stats.get("scheduler").is_some());
    }

    /// `{"cmd": "stats"}` dumps the scheduler name and the telemetry
    /// registry, with per-policy and per-client labels.
    #[test]
    fn tcp_stats_command_dumps_telemetry() {
        let addr = spawn_test_server(SchedulerKind::FairShare, Admission::unlimited());
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0, "client_id": "cli-a"}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
        let nfes = v.req("nfes").as_f64().unwrap();
        let stats = roundtrip(&mut conn, r#"{"cmd": "stats"}"#);
        assert_eq!(stats.req("scheduler").as_str(), Some("fair-share"));
        assert_eq!(stats.req("active").as_f64(), Some(0.0));
        let counters = stats.req("telemetry").req("counters");
        assert_eq!(counters.req("nfes_total{policy=ag}").as_f64(), Some(nfes));
        assert_eq!(
            counters
                .req("requests_completed_total{client=cli-a,policy=ag}")
                .as_f64(),
            Some(1.0)
        );
        // unknown cmd: structured error, connection stays usable
        let v = roundtrip(&mut conn, r#"{"cmd": "reboot"}"#);
        assert!(v.req("error").as_str().unwrap().contains("reboot"));
        let stats = roundtrip(&mut conn, r#"{"cmd": "stats"}"#);
        assert!(stats.get("scheduler").is_some());
    }
}
