//! Line-protocol serving front-end (std::net + mini-JSON; the offline
//! vendor set has no tokio, so the event loop is threads + channels).
//!
//! Protocol: one JSON object per line.
//!
//! request  {"prompt": "a large red circle at the center", "policy": "ag",
//!           "gamma_bar": 0.991, "steps": 20, "guidance": 7.5, "seed": 1,
//!           "negative": "green", "image": false}
//! response {"id": 3, "nfes": 31, "cfg_steps": 11, "truncated_at": 10,
//!           "ms": 128.4, "image": [...]?}
//! error    {"error": "...", "registered": ["ag", "cfg", ...]?}
//!
//! The `"policy"` field is a [`PolicySpec`]: either a bare registered name
//! (`"linear-ag"`, `"compressed-cfg"`, …) or an object
//! `{"kind": "searched", "choices": [...]}`. Top-level convenience fields
//! (`guidance` → `s`, `gamma_bar`, `cfg_steps`, `period`, `choices`,
//! `coeffs`, …) fill parameters the policy object leaves unset, so simple
//! clients never need the nested form. Unknown policy names produce a
//! structured JSON error listing the registered policies instead of a
//! dropped connection.
//!
//! The engine runs on a dedicated thread (it owns the PJRT client);
//! connection handlers forward requests through an mpsc channel and block on
//! a per-request response channel — requests from many connections batch
//! together inside the engine exactly like the drain-mode benches.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::coordinator::engine::Engine;
use crate::coordinator::request::{Completion, Request};
use crate::coordinator::spec::{PolicyRegistry, PolicySpec, SpecError};
use crate::prompts::Prompt;
use crate::util::json::{self, Value};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub model: String,
    pub default_steps: usize,
    pub default_guidance: f64,
    pub default_gamma_bar: f64,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:7458".into(),
            model: "dit_b".into(),
            default_steps: 20,
            default_guidance: 7.5,
            default_gamma_bar: 0.9988,
        }
    }
}

/// Top-level request fields that are *not* policy parameters.
const ENVELOPE_KEYS: &[&str] = &[
    "prompt", "policy", "steps", "seed", "negative", "image", "model", "src_image", "guidance",
];

/// Parse one protocol line into a [`Request`] (without an id — the engine
/// thread assigns ids).
pub fn parse_request_line(
    line: &str,
    cfg: &ServerConfig,
    registry: &PolicyRegistry,
) -> Result<(Request, bool)> {
    let v = json::parse(line).map_err(|e| anyhow!("bad request json: {e}"))?;
    let prompt_text = v
        .get("prompt")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("missing `prompt`"))?;
    let prompt = Prompt::parse(prompt_text).ok_or_else(|| anyhow!("unparseable prompt"))?;
    let steps = v
        .get("steps")
        .and_then(Value::as_usize)
        .unwrap_or(cfg.default_steps);

    // policy spec: bare name or object; top-level fields fill the gaps.
    let mut spec = match v.get("policy") {
        None => PolicySpec::new("ag"),
        Some(pv) => PolicySpec::from_json(pv)?,
    };
    if let Some(obj) = v.as_obj() {
        for (key, val) in obj {
            if !ENVELOPE_KEYS.contains(&key.as_str()) {
                spec.set_default(key, val.clone());
            }
        }
    }
    if let Some(g) = v.get("guidance").and_then(Value::as_f64) {
        spec.set_default("s", json::num(g));
    }
    // the server's configured defaults fill whatever is still unset
    spec.set_default("s", json::num(cfg.default_guidance));
    if spec.canonical_kind() == "ag" {
        spec.set_default("gamma_bar", json::num(cfg.default_gamma_bar));
    }
    let policy = registry.build(&spec)?;
    // reject bad policy/request combinations here (error reply) rather
    // than letting them panic the engine thread mid-generation
    policy
        .validate(steps)
        .map_err(|e| anyhow!("policy `{}` rejected the request: {e}", policy.name()))?;

    let mut req = Request::new(
        0,
        &v.get("model")
            .and_then(Value::as_str)
            .unwrap_or(&cfg.model)
            .to_owned(),
        prompt.tokens(),
        v.get("seed").and_then(Value::as_f64).unwrap_or(0.0) as u64,
        steps,
        policy,
    );
    if let Some(neg) = v.get("negative").and_then(Value::as_str) {
        let p = Prompt::parse(neg).unwrap();
        // negative prompts set only the slots mentioned; color-only is the
        // common case, so map any parsed attribute that differs from default
        let mut toks = vec![0i32; 4];
        let lower = neg.to_lowercase();
        if crate::prompts::SHAPES.iter().any(|s| lower.contains(s)) {
            toks[0] = p.shape as i32 + 1;
        }
        if crate::prompts::COLORS.iter().any(|s| lower.contains(s)) {
            toks[1] = p.color as i32 + 1;
        }
        if crate::prompts::POSITIONS.iter().any(|s| lower.contains(s)) {
            toks[2] = p.position as i32 + 1;
        }
        if crate::prompts::SIZES.iter().any(|s| lower.contains(s)) {
            toks[3] = p.size as i32 + 1;
        }
        req.neg_tokens = Some(toks);
    }
    if let Some(src) = v.get("src_image") {
        let vals = src
            .as_f64_vec()
            .ok_or_else(|| anyhow!("`src_image` must be an array of numbers"))?;
        req.src_image = Some(vals.into_iter().map(|f| f as f32).collect());
    }
    let want_image = v.get("image").and_then(Value::as_bool).unwrap_or(false);
    Ok((req, want_image))
}

/// Encode a completion as a protocol line.
pub fn completion_to_line(c: &Completion, ms: f64, with_image: bool) -> String {
    use json::{arr, num, obj};
    let mut fields = vec![
        ("id", num(c.id as f64)),
        ("nfes", num(c.nfes as f64)),
        ("cfg_steps", num(c.cfg_steps as f64)),
        ("ms", num((ms * 100.0).round() / 100.0)),
        (
            "truncated_at",
            c.truncated_at.map(|t| num(t as f64)).unwrap_or(Value::Null),
        ),
    ];
    if with_image {
        fields.push((
            "image",
            arr(c.image.iter().map(|&v| num(v as f64)).collect()),
        ));
    }
    json::to_string(&obj(fields))
}

/// Encode an error as a structured protocol line (proper JSON escaping;
/// unknown-policy errors carry the registered names).
pub fn error_to_line(e: &anyhow::Error) -> String {
    let mut fields = vec![("error", json::s(&format!("{e:#}")))];
    if let Some(SpecError::UnknownPolicy { known, .. }) = e.downcast_ref::<SpecError>() {
        fields.push((
            "registered",
            json::arr(known.iter().map(|n| json::s(n)).collect()),
        ));
    }
    json::to_string(&json::obj(fields))
}

struct Job {
    req: Request,
    want_image: bool,
    started: Instant,
    reply: Sender<String>,
}

/// Engine thread: batch whatever is queued, reply per request.
fn engine_loop<B: Backend>(mut engine: Engine<B>, rx: Receiver<Job>) {
    let mut next_id: u64 = 0;
    let mut jobs: HashMap<u64, Job> = HashMap::new();
    loop {
        // admit new work; block when fully idle (no busy spin)
        if engine.idle() {
            match rx.recv() {
                Ok(job) => admit(&mut engine, &mut jobs, &mut next_id, job),
                Err(_) => return, // all senders gone → shut down
            }
        }
        loop {
            match rx.try_recv() {
                Ok(job) => admit(&mut engine, &mut jobs, &mut next_id, job),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if engine.idle() {
                        return;
                    }
                    break;
                }
            }
        }
        match engine.pump() {
            Ok(completions) => {
                for c in completions {
                    if let Some(job) = jobs.remove(&c.id) {
                        let ms = job.started.elapsed().as_secs_f64() * 1e3;
                        let line = completion_to_line(&c, ms, job.want_image);
                        let _ = job.reply.send(line);
                    }
                }
            }
            Err(e) => {
                log::error!("engine pump failed: {e:#}");
                let line = error_to_line(&e);
                for (_, job) in jobs.drain() {
                    let _ = job.reply.send(line.clone());
                }
                return;
            }
        }
    }
}

fn admit<B: Backend>(
    engine: &mut Engine<B>,
    jobs: &mut HashMap<u64, Job>,
    next_id: &mut u64,
    mut job: Job,
) {
    job.req.id = *next_id;
    *next_id += 1;
    engine.submit(job.req.clone());
    jobs.insert(job.req.id, job);
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<Job>,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_default();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match parse_request_line(&line, &cfg, &registry) {
            Ok((req, want_image)) => {
                let (rtx, rrx) = channel();
                let job = Job {
                    req,
                    want_image,
                    started: Instant::now(),
                    reply: rtx,
                };
                if tx.send(job).is_err() {
                    break;
                }
                match rrx.recv() {
                    Ok(l) => l,
                    Err(_) => break,
                }
            }
            Err(e) => error_to_line(&e),
        };
        if writer.write_all(reply_line.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    log::info!("connection {peer} closed");
}

/// Serve forever with the built-in policy registry.
pub fn serve<B, F>(factory: F, cfg: ServerConfig) -> Result<()>
where
    B: Backend + 'static,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    serve_with_registry(factory, cfg, Arc::new(PolicyRegistry::builtin()))
}

/// Serve forever (or until the listener errors) with a caller-supplied
/// registry — the hook for deployments that register custom policies.
///
/// `factory` constructs the backend *inside* the engine thread — the PJRT
/// client is thread-affine (not `Send`), so it must be born where it runs.
pub fn serve_with_registry<B, F>(
    factory: F,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
) -> Result<()>
where
    B: Backend + 'static,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    let (tx, rx) = channel::<Job>();
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("agd serving on {} (model {})", cfg.addr, cfg.model);
    std::thread::spawn(move || match factory().and_then(Engine::new) {
        Ok(engine) => engine_loop(engine, rx),
        Err(e) => log::error!("backend construction failed: {e:#}"),
    });
    for stream in listener.incoming() {
        let stream = stream?;
        let tx = tx.clone();
        let cfg = cfg.clone();
        let registry = registry.clone();
        std::thread::spawn(move || handle_conn(stream, tx, cfg, registry));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::ols::OlsCoeffs;
    use crate::sim::gmm::Gmm;

    fn cfg() -> ServerConfig {
        ServerConfig {
            model: "gmm".into(),
            ..Default::default()
        }
    }

    fn reg() -> PolicyRegistry {
        PolicyRegistry::builtin()
    }

    fn parse(line: &str) -> Result<(Request, bool)> {
        parse_request_line(line, &cfg(), &reg())
    }

    #[test]
    fn parses_minimal_request() {
        let (req, img) = parse(r#"{"prompt": "red circle"}"#).unwrap();
        assert_eq!(req.tokens, vec![1, 1, 1, 1]);
        assert_eq!(req.steps, 20);
        assert!(!img);
        assert!(req.policy.name().starts_with("ag("));
        // the configured default gamma-bar flows into the default policy
        assert!(req.policy.name().contains("0.9988"));
    }

    #[test]
    fn parses_full_request() {
        let line = r#"{"prompt": "a large blue square at the top-left",
            "policy": "cfg", "steps": 10, "guidance": 5.0, "seed": 9,
            "negative": "red", "image": true}"#;
        let (req, img) = parse(line).unwrap();
        assert_eq!(req.steps, 10);
        assert!(img);
        assert_eq!(req.policy.name(), "cfg(s=5)");
        assert_eq!(req.neg_tokens, Some(vec![0, 1, 0, 0])); // red = color 1
        assert_eq!(req.seed, 9);
    }

    #[test]
    fn parses_every_registered_policy_kind() {
        // server parity: policies that used to be CLI/bench-only are now
        // reachable through the line protocol via PolicySpec.
        let coeffs = json::to_string(&OlsCoeffs::identity(8).to_json());
        let lines = [
            format!(r#"{{"prompt": "x", "policy": "linear-ag", "steps": 8, "coeffs": {coeffs}}}"#),
            r#"{"prompt": "x", "policy": "ag-prefix", "cfg_steps": 3}"#.to_owned(),
            r#"{"prompt": "x", "policy": "alternating"}"#.to_owned(),
            r#"{"prompt": "x", "policy": "searched", "choices": ["cfg", "cond", "uncond", 2.5]}"#
                .to_owned(),
            r#"{"prompt": "x", "policy": "pix2pix", "src_image": [0.0, 0.5]}"#.to_owned(),
            r#"{"prompt": "x", "policy": "compressed-cfg", "period": 5}"#.to_owned(),
            r#"{"prompt": "x", "policy": "adaptive-scale", "s_max": 6.0, "s_min": 1.0}"#.to_owned(),
            r#"{"prompt": "x", "policy": {"kind": "ag-prefix", "cfg_steps": 2, "s": 3.0}}"#
                .to_owned(),
        ];
        for line in &lines {
            let (req, _) = parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert!(req.policy.max_nfes(req.steps) >= req.steps, "{line}");
        }
        // a coefficient table shorter than the request is an error reply,
        // not an engine-thread panic
        let short = format!(
            r#"{{"prompt": "x", "policy": "linear-ag", "steps": 20, "coeffs": {coeffs}}}"#
        );
        let err = parse(&short).unwrap_err();
        assert!(err.to_string().contains("cover"), "{err}");

        // spot-check parameters actually reached the policies
        let (req, _) = parse(&lines[1]).unwrap();
        assert_eq!(req.policy.max_nfes(20), 23); // 3 guided + 17 cond
        let (req, _) = parse(&lines[4]).unwrap();
        assert_eq!(req.src_image.as_deref(), Some(&[0.0f32, 0.5][..]));
        let (req, _) = parse(&lines[7]).unwrap();
        assert_eq!(req.policy.max_nfes(20), 22); // nested object form
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse("not json").is_err());
        assert!(parse(r#"{"no_prompt": 1}"#).is_err());
        assert!(parse(r#"{"prompt": "x", "policy": "warp"}"#).is_err());
    }

    #[test]
    fn unknown_policy_yields_structured_error_listing_registered() {
        let err = parse(r#"{"prompt": "x", "policy": "warp"}"#).unwrap_err();
        let line = error_to_line(&err);
        let v = json::parse(&line).unwrap_or_else(|e| panic!("error line not json: {line} ({e})"));
        assert!(v.req("error").as_str().unwrap().contains("warp"));
        let registered = v.req("registered").as_str_vec().unwrap();
        assert!(registered.contains(&"ag".to_owned()));
        assert!(registered.contains(&"compressed-cfg".to_owned()));
        assert!(registered.contains(&"adaptive-scale".to_owned()));

        // non-spec errors still produce valid JSON (escaping included)
        let err = parse(r#"{"prompt": 42}"#).unwrap_err();
        let line = error_to_line(&err);
        assert!(json::parse(&line).is_ok(), "{line}");
    }

    #[test]
    fn completion_roundtrip_line() {
        let c = Completion {
            id: 7,
            image: vec![0.5, -0.5],
            nfes: 31,
            cfg_steps: 11,
            truncated_at: Some(10),
            gammas: vec![],
            gammas_eps: vec![],
            trajectory: None,
            iterates: vec![],
        };
        let line = completion_to_line(&c, 12.345, true);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.req("nfes").as_f64(), Some(31.0));
        assert_eq!(v.req("truncated_at").as_f64(), Some(10.0));
        assert_eq!(v.req("image").as_arr().unwrap().len(), 2);
        let line2 = completion_to_line(&c, 1.0, false);
        assert!(json::parse(&line2).unwrap().get("image").is_none());
    }

    /// Full TCP round trip against the GMM backend.
    #[test]
    fn tcp_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let scfg = ServerConfig {
            addr: addr.to_string(),
            model: "gmm".into(),
            ..Default::default()
        };
        let (tx, rx) = channel::<Job>();
        std::thread::spawn(move || {
            let backend = GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05));
            engine_loop(Engine::new(backend).unwrap(), rx)
        });
        {
            let scfg = scfg.clone();
            let registry = Arc::new(PolicyRegistry::builtin());
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    let tx = tx.clone();
                    let scfg = scfg.clone();
                    let registry = registry.clone();
                    std::thread::spawn(move || {
                        handle_conn(stream.unwrap(), tx, scfg, registry)
                    });
                }
            });
        }
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(
            br#"{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0}"#,
        )
        .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_none(), "{line}");
        assert!(v.req("nfes").as_f64().unwrap() <= 16.0);

        // a plugin policy over the same connection: compressed-cfg at
        // period 4 over 8 steps costs exactly 2·2 + 6 = 10 NFEs.
        let mut conn = reader.into_inner();
        conn.write_all(
            br#"{"prompt": "red circle", "policy": "compressed-cfg", "period": 4, "steps": 8, "guidance": 2.0}"#,
        )
        .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(v.req("nfes").as_f64(), Some(10.0), "{line}");

        // unknown policy: structured error, connection stays usable
        let mut conn = reader.into_inner();
        conn.write_all(br#"{"prompt": "red circle", "policy": "warp"}"#)
            .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_some(), "{line}");
        assert!(v.req("registered").as_str_vec().unwrap().len() >= 10);
    }
}
