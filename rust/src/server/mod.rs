//! Line-protocol serving front-end (std::net + mini-JSON; the offline
//! vendor set has no tokio, so the event loop is threads + channels).
//!
//! Protocol: one JSON object per line.
//!
//! request  {"prompt": "a large red circle at the center", "policy": "ag",
//!           "gamma_bar": 0.991, "steps": 20, "guidance": 7.5, "seed": 1,
//!           "negative": "green", "image": false}
//! response {"id": 3, "nfes": 31, "cfg_steps": 11, "truncated_at": 10,
//!           "ms": 128.4, "image": [...]?}
//!
//! The engine runs on a dedicated thread (it owns the PJRT client);
//! connection handlers forward requests through an mpsc channel and block on
//! a per-request response channel — requests from many connections batch
//! together inside the engine exactly like the drain-mode benches.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::coordinator::engine::Engine;
use crate::coordinator::policy::GuidancePolicy;
use crate::coordinator::request::{Completion, Request};
use crate::prompts::Prompt;
use crate::util::json::{self, Value};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub model: String,
    pub default_steps: usize,
    pub default_guidance: f64,
    pub default_gamma_bar: f64,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:7458".into(),
            model: "dit_b".into(),
            default_steps: 20,
            default_guidance: 7.5,
            default_gamma_bar: 0.9988,
        }
    }
}

/// Parse one protocol line into a [`Request`] (without an id — the engine
/// thread assigns ids).
pub fn parse_request_line(line: &str, cfg: &ServerConfig) -> Result<(Request, bool)> {
    let v = json::parse(line).map_err(|e| anyhow!("bad request json: {e}"))?;
    let prompt_text = v
        .get("prompt")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("missing `prompt`"))?;
    let prompt = Prompt::parse(prompt_text).ok_or_else(|| anyhow!("unparseable prompt"))?;
    let steps = v
        .get("steps")
        .and_then(Value::as_usize)
        .unwrap_or(cfg.default_steps);
    let s = v
        .get("guidance")
        .and_then(Value::as_f64)
        .unwrap_or(cfg.default_guidance) as f32;
    let gamma_bar = v
        .get("gamma_bar")
        .and_then(Value::as_f64)
        .unwrap_or(cfg.default_gamma_bar);
    let policy = match v.get("policy").and_then(Value::as_str).unwrap_or("ag") {
        "cfg" => GuidancePolicy::Cfg { s },
        "cond" | "distilled" => GuidancePolicy::CondOnly,
        "ag" => GuidancePolicy::Ag { s, gamma_bar },
        other => return Err(anyhow!("unknown policy `{other}`")),
    };
    let mut req = Request::new(
        0,
        &v.get("model")
            .and_then(Value::as_str)
            .unwrap_or(&cfg.model)
            .to_owned(),
        prompt.tokens(),
        v.get("seed").and_then(Value::as_f64).unwrap_or(0.0) as u64,
        steps,
        policy,
    );
    if let Some(neg) = v.get("negative").and_then(Value::as_str) {
        let p = Prompt::parse(neg).unwrap();
        // negative prompts set only the slots mentioned; color-only is the
        // common case, so map any parsed attribute that differs from default
        let mut toks = vec![0i32; 4];
        let lower = neg.to_lowercase();
        if crate::prompts::SHAPES.iter().any(|s| lower.contains(s)) {
            toks[0] = p.shape as i32 + 1;
        }
        if crate::prompts::COLORS.iter().any(|s| lower.contains(s)) {
            toks[1] = p.color as i32 + 1;
        }
        if crate::prompts::POSITIONS.iter().any(|s| lower.contains(s)) {
            toks[2] = p.position as i32 + 1;
        }
        if crate::prompts::SIZES.iter().any(|s| lower.contains(s)) {
            toks[3] = p.size as i32 + 1;
        }
        req.neg_tokens = Some(toks);
    }
    let want_image = v.get("image").and_then(Value::as_bool).unwrap_or(false);
    Ok((req, want_image))
}

/// Encode a completion as a protocol line.
pub fn completion_to_line(c: &Completion, ms: f64, with_image: bool) -> String {
    use json::{arr, num, obj};
    let mut fields = vec![
        ("id", num(c.id as f64)),
        ("nfes", num(c.nfes as f64)),
        ("cfg_steps", num(c.cfg_steps as f64)),
        ("ms", num((ms * 100.0).round() / 100.0)),
        (
            "truncated_at",
            c.truncated_at.map(|t| num(t as f64)).unwrap_or(Value::Null),
        ),
    ];
    if with_image {
        fields.push((
            "image",
            arr(c.image.iter().map(|&v| num(v as f64)).collect()),
        ));
    }
    json::to_string(&obj(fields))
}

struct Job {
    req: Request,
    want_image: bool,
    started: Instant,
    reply: Sender<String>,
}

/// Engine thread: batch whatever is queued, reply per request.
fn engine_loop<B: Backend>(mut engine: Engine<B>, rx: Receiver<Job>) {
    let mut next_id: u64 = 0;
    let mut jobs: HashMap<u64, Job> = HashMap::new();
    loop {
        // admit new work; block when fully idle (no busy spin)
        if engine.idle() {
            match rx.recv() {
                Ok(job) => admit(&mut engine, &mut jobs, &mut next_id, job),
                Err(_) => return, // all senders gone → shut down
            }
        }
        loop {
            match rx.try_recv() {
                Ok(job) => admit(&mut engine, &mut jobs, &mut next_id, job),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if engine.idle() {
                        return;
                    }
                    break;
                }
            }
        }
        match engine.pump() {
            Ok(completions) => {
                for c in completions {
                    if let Some(job) = jobs.remove(&c.id) {
                        let ms = job.started.elapsed().as_secs_f64() * 1e3;
                        let line = completion_to_line(&c, ms, job.want_image);
                        let _ = job.reply.send(line);
                    }
                }
            }
            Err(e) => {
                log::error!("engine pump failed: {e:#}");
                for (_, job) in jobs.drain() {
                    let _ = job.reply.send(format!("{{\"error\":\"{e}\"}}"));
                }
                return;
            }
        }
    }
}

fn admit<B: Backend>(
    engine: &mut Engine<B>,
    jobs: &mut HashMap<u64, Job>,
    next_id: &mut u64,
    mut job: Job,
) {
    job.req.id = *next_id;
    *next_id += 1;
    engine.submit(job.req.clone());
    jobs.insert(job.req.id, job);
}

fn handle_conn(stream: TcpStream, tx: Sender<Job>, cfg: ServerConfig) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_default();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match parse_request_line(&line, &cfg) {
            Ok((req, want_image)) => {
                let (rtx, rrx) = channel();
                let job = Job {
                    req,
                    want_image,
                    started: Instant::now(),
                    reply: rtx,
                };
                if tx.send(job).is_err() {
                    break;
                }
                match rrx.recv() {
                    Ok(l) => l,
                    Err(_) => break,
                }
            }
            Err(e) => format!("{{\"error\":\"{e}\"}}"),
        };
        if writer.write_all(reply_line.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    log::info!("connection {peer} closed");
}

/// Serve forever (or until the listener errors).
///
/// `factory` constructs the backend *inside* the engine thread — the PJRT
/// client is thread-affine (not `Send`), so it must be born where it runs.
pub fn serve<B, F>(factory: F, cfg: ServerConfig) -> Result<()>
where
    B: Backend + 'static,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    let (tx, rx) = channel::<Job>();
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("agd serving on {} (model {})", cfg.addr, cfg.model);
    std::thread::spawn(move || match factory() {
        Ok(backend) => engine_loop(Engine::new(backend), rx),
        Err(e) => log::error!("backend construction failed: {e:#}"),
    });
    for stream in listener.incoming() {
        let stream = stream?;
        let tx = tx.clone();
        let cfg = cfg.clone();
        std::thread::spawn(move || handle_conn(stream, tx, cfg));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::sim::gmm::Gmm;

    fn cfg() -> ServerConfig {
        ServerConfig {
            model: "gmm".into(),
            ..Default::default()
        }
    }

    #[test]
    fn parses_minimal_request() {
        let (req, img) =
            parse_request_line(r#"{"prompt": "red circle"}"#, &cfg()).unwrap();
        assert_eq!(req.tokens, vec![1, 1, 1, 1]);
        assert_eq!(req.steps, 20);
        assert!(!img);
        assert!(matches!(req.policy, GuidancePolicy::Ag { .. }));
    }

    #[test]
    fn parses_full_request() {
        let line = r#"{"prompt": "a large blue square at the top-left",
            "policy": "cfg", "steps": 10, "guidance": 5.0, "seed": 9,
            "negative": "red", "image": true}"#;
        let (req, img) = parse_request_line(line, &cfg()).unwrap();
        assert_eq!(req.steps, 10);
        assert!(img);
        assert!(matches!(req.policy, GuidancePolicy::Cfg { s } if s == 5.0));
        assert_eq!(req.neg_tokens, Some(vec![0, 1, 0, 0])); // red = color 1
        assert_eq!(req.seed, 9);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_request_line("not json", &cfg()).is_err());
        assert!(parse_request_line(r#"{"no_prompt": 1}"#, &cfg()).is_err());
        assert!(
            parse_request_line(r#"{"prompt": "x", "policy": "warp"}"#, &cfg()).is_err()
        );
    }

    #[test]
    fn completion_roundtrip_line() {
        let c = Completion {
            id: 7,
            image: vec![0.5, -0.5],
            nfes: 31,
            cfg_steps: 11,
            truncated_at: Some(10),
            gammas: vec![],
            gammas_eps: vec![],
            trajectory: None,
            iterates: vec![],
        };
        let line = completion_to_line(&c, 12.345, true);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.req("nfes").as_f64(), Some(31.0));
        assert_eq!(v.req("truncated_at").as_f64(), Some(10.0));
        assert_eq!(v.req("image").as_arr().unwrap().len(), 2);
        let line2 = completion_to_line(&c, 1.0, false);
        assert!(json::parse(&line2).unwrap().get("image").is_none());
    }

    /// Full TCP round trip against the GMM backend.
    #[test]
    fn tcp_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let scfg = ServerConfig {
            addr: addr.to_string(),
            model: "gmm".into(),
            ..Default::default()
        };
        let (tx, rx) = channel::<Job>();
        std::thread::spawn(move || {
            let backend = GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05));
            engine_loop(Engine::new(backend), rx)
        });
        {
            let scfg = scfg.clone();
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    let tx = tx.clone();
                    let scfg = scfg.clone();
                    std::thread::spawn(move || handle_conn(stream.unwrap(), tx, scfg));
                }
            });
        }
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(
            br#"{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0}"#,
        )
        .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_none(), "{line}");
        assert!(v.req("nfes").as_f64().unwrap() <= 16.0);
    }
}
