//! Line-protocol serving front-end (std::net + mini-JSON; the offline
//! vendor set has no tokio, so the event loop is threads + channels).
//!
//! # §Scale: fleet topology
//!
//! The server is an **engine fleet** ([`crate::fleet`]): connection
//! handlers hand each parsed request to a router, which places it on one
//! of `--shards N` engine replicas — every shard is its own thread owning
//! its own backend instance, scheduler, worker pool and buffer pool (the
//! PJRT client is thread-affine, so scale-out replicates whole engines;
//! with real devices the shard index maps to a device). Placement
//! (`--placement`) is `least-loaded` by live queued-NFE snapshot
//! (default), `round-robin`, or `client-hash` for cache affinity.
//! Placement changes which shard *batches* a request, never its bytes:
//! per-request results are identical for every shard count.
//!
//! Admission is **two-level**: `--max-in-flight`/`--max-queued-nfes`
//! bound the whole fleet at the router, `--shard-max-in-flight`/
//! `--shard-max-queued-nfes` bound each shard's engine; a shed line
//! carries `"scope": "global"|"shard"`. `--shed-infeasible` additionally
//! refuses requests whose `deadline_ms` cannot cover the placed shard's
//! backlog at its observed per-NFE service rate (code
//! `deadline_infeasible`). The per-client quota
//! (`--max-in-flight-per-client`) is enforced shard-side; under
//! `client-hash` placement it is exact fleet-wide.
//!
//! Two front ends serve the same protocol (`--net reactor|threads`):
//! the default poll-based reactor ([`crate::reactor`]) multiplexes every
//! connection onto one event-loop thread — enabling pipelined wire ids,
//! streamed progress, wire-level cancellation, and thousands of idle
//! connections at no per-connection thread cost — while `--net threads`
//! keeps the historical thread-per-connection loop as the A/B baseline.
//! Both render replies through the same functions, so completions are
//! byte-identical across front ends. The full framing contract (ids,
//! ordering, backpressure, the error-code catalogue) lives in
//! `docs/PROTOCOL.md`.
//!
//! # Protocol: one JSON object per line
//!
//! request  {"prompt": "a large red circle at the center", "policy": "ag",
//!           "gamma_bar": 0.991, "steps": 20, "guidance": 7.5, "seed": 1,
//!           "negative": "green", "image": false,
//!           "client_id": "web", "priority": 1, "deadline_ms": 2500}
//! response {"id": 3, "policy": "ag(ḡ=0.991)", "nfes": 31, "cfg_steps": 11,
//!           "truncated_at": 10, "ms": 128.4, "image": [...]?}
//! error    {"error": "...", "code": "invalid_request",
//!           "registered": ["ag", "cfg", ...]?}
//! shed     {"error": "queue full: ...", "code": "queue_full",
//!           "scope": "global"|"shard", ...}
//!          {"error": "deadline infeasible: ...",
//!           "code": "deadline_infeasible", "deadline_ms": 50,
//!           "estimated_ms": 420, "queued_nfes": 84}
//!          {"error": "server is draining: ...", "code": "draining"}
//! command  {"cmd": "stats"}
//!          → {"scheduler": "cost-aware", "shards": 4,
//!             "placement": "least-loaded", "draining": false,
//!             "active": 3, "queue_depth": 9, "queued_nfes": 118,
//!             "per_shard": [{"shard": 0, "active": 1, ...}, ...],
//!             "telemetry": {"counters": {...}, ...}}
//!            Fleet totals plus a per-shard breakdown; telemetry series
//!            appear twice — summed (fleet total) and under a `shard=`
//!            label.
//! command  {"cmd": "metrics"}
//!          → Prometheus text exposition of the merged fleet registry
//!            (`# TYPE`-annotated counter/gauge/histogram samples, fleet
//!            totals + `shard=`-labelled series). This is the one
//!            multi-line reply in the protocol: it is terminated by a
//!            blank line, so scrapers read until the first empty line
//!            (everything else stays one line per reply).
//! command  {"cmd": "spans"}
//!          → {"spans": [{"type": "span"|"guidance", "req": 3,
//!             "shard": 0, ...}, ...], "dropped": 0}
//!            §Observability: drains every shard's span ring — request
//!            lifecycle spans (for `"trace": true` requests) and one
//!            guidance-decision event per denoising step of every
//!            request. Save the reply to a file and render it with
//!            `agd profile --spans FILE` (Chrome trace JSON + per-stage
//!            percentiles + the per-policy NFE-savings ledger); full
//!            schema in `docs/OBSERVABILITY.md`. Draining clears the
//!            rings; `dropped` counts ring overwrites (monotonic).
//! tagged   {"id": 7, "prompt": ...} → {"id": 7, "policy": ..., ...}
//!            An optional client-chosen `id` (any JSON value) is echoed
//!            verbatim on every reply and progress event for that
//!            request. Id-tagged requests *pipeline*: the reactor keeps
//!            them all in flight at once and replies in completion
//!            order. Id-less requests keep the historical contract —
//!            dispatch serializes, replies in arrival order. A second
//!            live request under the same id on one connection is
//!            refused (`invalid_request`) since its replies would be
//!            unmatchable.
//! progress {"prompt": ..., "progress": true, ...}
//!          → {"event": "progress", "id": 7, "step": 4, "of": 20,
//!             "gamma": 0.93, "nfes": 9}   (0-based step, one per step)
//!            Opt-in per-step streaming ahead of the completion. Under
//!            write backpressure stale samples are coalesced/shed
//!            (`conn_progress_dropped_total`) — the completion never is.
//! command  {"cmd": "cancel", "id": 7}
//!          → the canceled request itself answers with
//!            `"code": "canceled"` (or its completion, if the cancel
//!            lost the race; the id resolves exactly once). Cancelling
//!            revokes queued work, refunds the admission budget and the
//!            per-client quota, and counts `requests_canceled_total`.
//!            An id not in flight on this connection answers
//!            `"code": "unknown_id"`. Reactor front end only: the
//!            threaded loop serves synchronously, so there is no window
//!            in which a cancel can arrive.
//! command  {"cmd": "drain"}
//!          → {"drained": true, "shards": N}, sent only after every shard
//!            has finished all in-flight work (nothing is dropped) and
//!            every engine thread has been joined. Drain is terminal:
//!            from the moment it starts, new requests are refused with
//!            `"code": "draining"` — it is the graceful-shutdown path.
//!            ⚠ Drain is an *administrative* command with no
//!            authentication: anyone who can reach the port can quiesce
//!            the server. Bind to loopback (the default) or front the
//!            port with an authenticating proxy on untrusted networks.
//!
//! A fleet whose every shard has died (failed backend construction, fatal
//! pump errors) refuses requests with `"code": "unavailable"` — distinct
//! from `"draining"` so clients fail over instead of politely waiting out
//! a shutdown that never announced itself. A request caught on a shard
//! that dies mid-flight is refused with `"code": "shard_failed"` (plus
//! the shard index) rather than silently dropped.
//!
//! # §Robustness: input hardening
//!
//! Every structured refusal carries a `"code"`; the full set is
//! `invalid_request` · `unknown_cmd` · `queue_full` ·
//! `deadline_infeasible` · `draining` · `unavailable` · `shard_failed` ·
//! `timeout` · `canceled` · `unknown_id`. Beyond bad JSON, two
//! wire-level attacks are handled per connection:
//!
//! * **Oversized frames** — a request line longer than `--max-line-bytes`
//!   (default 1 MiB) is refused with `"code": "invalid_request"` and the
//!   connection is closed without buffering the rest; the handler never
//!   allocates more than the cap per line.
//! * **Slowloris** — a writer that trickles bytes without ever finishing
//!   a line is cut off by `--read-timeout-ms` (default 60000; 0
//!   disables): an idle connection (no partial line) is closed silently,
//!   a mid-line stall gets `"code": "timeout"` first. Counted as
//!   `conn_timeout_total{kind="idle"|"midline"}`; malformed frames as
//!   `conn_bad_line_total{kind="oversized"|"utf8"}`.
//!
//! # §Robustness: trace capture, replay, chaos
//!
//! `agd serve --trace-out FILE` appends one JSONL record per served
//! request — arrival-offset µs, the request envelope verbatim, client
//! id, and the completion digest ([`crate::chaos::trace`]):
//!
//! ```text
//! {"offset_us": 18234, "client_id": "web-1", "digest": "9f1c…",
//!  "envelope": {"prompt": "red circle", "steps": 8, "image": true}}
//! ```
//!
//! `agd replay --trace FILE --speed X --connections N [--addr H:P]
//! [--pipeline DEPTH]` re-issues a trace open-loop over real TCP
//! connections and writes wire latency (p50/p95/p99), shed codes, and
//! digest-match counts to `BENCH_replay.json` ([`crate::chaos::replay`]).
//! `--pipeline DEPTH` tags each request with a wire id and keeps up to
//! DEPTH in flight per connection, matching replies by echoed id instead
//! of FIFO order. Because the digest is computable on both ends of the
//! wire, capture → replay round trips prove served completions
//! byte-identical.
//!
//! Fault injection is scripted: `scenarios/*.txt` files (ops: `connect` ·
//! `send` · `expect-ok` · `expect-code` · `expect-id` · `expect-id-code` ·
//! `expect-closed` · `send-raw` · `send-raw-repeat` · `slowloris` ·
//! `disconnect` · `kill-shard` · `fault` · `wait-respawn` · `drain` ·
//! `sleep`; grammar in [`crate::chaos::director`]) run against a live
//! listener via [`serve_on`] in `rust/tests/chaos_integration.rs` and
//! `rust/tests/reactor_integration.rs`.
//!
//! # §Robustness: surviving backend faults and shard deaths
//!
//! Three layers stand between an injected (or real) backend fault and a
//! client-visible error (`docs/ROBUSTNESS.md` has the full taxonomy):
//!
//! * **Backend faults** — `agd serve --fault-spec SPEC` arms scheduled
//!   faults inside every shard's denoise path
//!   ([`crate::chaos::fault::FaultSpec`] grammar: `error-every=N`,
//!   `error-at=K`, `stall-at=K:MS`, `fail-after=K`); the chaos
//!   director's `fault` op re-arms the same plan on a live fleet.
//! * **Engine retry** — `--max-batch-retries N` lets each shard retry a
//!   transiently-failed batch after rolling it back (seeded
//!   decorrelated-jitter backoff), so retried completions stay
//!   byte-identical; fatal faults escalate immediately.
//! * **Fleet salvage + respawn** — a dying shard hands its never-started
//!   requests back to the router for re-placement on survivors (their
//!   replies arrive as if nothing happened); mid-batch work is refused
//!   with `"code": "shard_failed"`. With `--shard-respawn` a supervisor
//!   thread rebuilds dead shards from the same backend factory under
//!   capped exponential backoff, and the `wait-respawn` scenario op
//!   blocks until the shard is placeable again.
//!
//! The `"policy"` field is a [`PolicySpec`]: either a bare registered name
//! (`"linear-ag"`, `"compressed-cfg"`, a `--policy-file` alias, …) or an
//! object `{"kind": "searched", "choices": [...]}`. Top-level convenience
//! fields (`guidance` → `s`, `gamma_bar`, `cfg_steps`, `period`,
//! `choices`, `coeffs`, …) fill parameters the policy object leaves unset,
//! so simple clients never need the nested form. Unknown policy names
//! produce a structured JSON error listing the registered policies instead
//! of a dropped connection.
//!
//! Setting `"trace": true` on a request opts it into lifecycle-span
//! recording (§Observability, [`crate::trace`]): its completion line
//! gains a `"timeline"` array covering admission → placement → queue →
//! batch → denoise → combine → complete, and the same spans land in the
//! shard's ring for `{"cmd": "spans"}`. Guidance-decision events are
//! recorded for every request regardless. See `docs/OBSERVABILITY.md`.
//!
//! Scheduling envelope fields are optional: `client_id` names the
//! fair-share lane (and the `client=` telemetry label), `priority` and
//! `deadline_ms` feed the `deadline` scheduler. `deadline_ms` counts
//! *from the request's arrival* (the engine anchors it to its own clock,
//! so client clock skew cannot invert the EDF order). The discipline
//! itself is server-side (`agd serve --scheduler fifo|cost-aware|
//! deadline|fair-share`), applied identically inside every shard.
//! `--workers N` sizes each shard's worker pool (0 = available
//! parallelism split across shards); it changes throughput only, never
//! results.
//!
//! The accept loop classifies listener errors: transient ones (EMFILE,
//! aborted handshakes, EINTR — see `transient_accept_error`) are logged
//! and the loop keeps accepting, because one bad accept must not kill a
//! serving fleet; permanent ones still propagate so a supervisor sees
//! the crash.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::chaos::fault::{FaultPlan, FaultSpec, FaultyBackend};
use crate::chaos::trace::{completion_digest, TraceSink};
use crate::coordinator::request::{Completion, Request};
use crate::coordinator::spec::{PolicyRegistry, PolicySpec, SpecError};
use crate::fleet::{
    Canceled, Fleet, FleetConfig, JobReply, Placement, RouteError, ScopedShed, ShardFailed,
};
use crate::prompts::Prompt;
use crate::sched::{Admission, AdmitError, SchedulerKind};
use crate::backend::Backend;
use crate::util::json::{self, Value};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub model: String,
    pub default_steps: usize,
    pub default_guidance: f64,
    pub default_gamma_bar: f64,
    /// Scheduling discipline every shard engine runs (`--scheduler`).
    pub scheduler: SchedulerKind,
    /// Fleet-global admission budgets, checked at the router
    /// (`--max-in-flight` / `--max-queued-nfes`); its per-client member
    /// (`--max-in-flight-per-client`) is enforced shard-side.
    pub admission: Admission,
    /// Per-shard engine budgets (`--shard-max-in-flight` /
    /// `--shard-max-queued-nfes`).
    pub shard_admission: Admission,
    /// Engine replicas (`--shards`).
    pub shards: usize,
    /// Request placement across shards (`--placement`).
    pub placement: Placement,
    /// Shed deadline-infeasible requests at shard admission
    /// (`--shed-infeasible`).
    pub shed_infeasible: bool,
    /// Worker lanes per shard (`--workers`); 0 = available parallelism
    /// split across the shards (§Perf: parallel execution).
    pub workers: usize,
    /// Hard cap on one request line (`--max-line-bytes`, default 1 MiB):
    /// a longer line is refused with `"code": "invalid_request"` and the
    /// connection closed, without ever buffering more than the cap
    /// (§Robustness: input hardening).
    pub max_line_bytes: usize,
    /// Per-connection read deadline in ms (`--read-timeout-ms`, default
    /// 60000; 0 = no deadline): idle connections are closed silently, a
    /// mid-line stall — the slowloris pattern — gets `"code": "timeout"`
    /// first (§Robustness: input hardening).
    pub read_timeout_ms: u64,
    /// Append one JSONL trace record per served request
    /// (`--trace-out FILE`; [`crate::chaos::trace`]).
    pub trace_out: Option<String>,
    /// §Robustness: arm the fault-injection layer at startup
    /// (`--fault-spec`, e.g. `"error-every=50,stall-at=120:200"`;
    /// grammar in [`crate::chaos::fault::FaultSpec`] and
    /// `docs/ROBUSTNESS.md`). Every shard backend is wrapped in a
    /// [`crate::chaos::fault::FaultyBackend`] regardless — a disarmed
    /// plan is free — so the chaos director's `fault` op can arm faults
    /// at runtime even when this is `None`.
    pub fault_spec: Option<String>,
    /// §Robustness: per-batch transient-fault retry budget
    /// (`--max-batch-retries`, default 0 = escalate immediately; the
    /// pre-retry rollback makes retried completions byte-identical).
    pub max_batch_retries: usize,
    /// §Robustness: supervisor respawns dead shards with capped
    /// exponential backoff (`--shard-respawn`; default off — a dead
    /// shard stays dead and survivors absorb the load).
    pub shard_respawn: bool,
    /// §Robustness: checkpoint every N completed denoising steps per
    /// request (`--checkpoint-steps`, default 0 = off — byte- and
    /// allocation-identical to a server without the feature). Armed, a
    /// dying shard's started requests resume mid-trajectory on
    /// survivors instead of being refused.
    pub checkpoint_steps: usize,
    /// §Scale: which connection front end serves the listener (`--net`).
    /// The poll-based reactor (default) multiplexes every connection on
    /// one thread with pipelined request ids, streaming progress, and
    /// wire-level cancel; `threads` keeps the historical
    /// thread-per-connection loop as the A/B baseline.
    pub net: NetMode,
    /// §Observability: continuous span shipping (`--spans-out FILE`) — a
    /// background thread drains every shard's span ring to JSONL on a
    /// short cadence, so spans land on disk instead of dropping on ring
    /// overwrite between `{"cmd": "spans"}` polls. Mirrors `--trace-out`.
    pub spans_out: Option<String>,
}

/// Connection front end selector (`agd serve --net reactor|threads`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetMode {
    /// Poll-based readiness loop ([`crate::reactor`]): one thread
    /// multiplexing every connection, pipelined ids, per-step progress,
    /// `{"cmd":"cancel"}`, bounded per-connection write queues.
    Reactor,
    /// Thread-per-connection blocking loop — the historical front end,
    /// kept for one release as the A/B baseline.
    Threads,
}

impl NetMode {
    pub fn parse(s: &str) -> Option<NetMode> {
        match s {
            "reactor" => Some(NetMode::Reactor),
            "threads" => Some(NetMode::Threads),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            NetMode::Reactor => "reactor",
            NetMode::Threads => "threads",
        }
    }
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:7458".into(),
            model: "dit_b".into(),
            default_steps: 20,
            default_guidance: 7.5,
            default_gamma_bar: 0.9988,
            scheduler: SchedulerKind::Fifo,
            admission: Admission::unlimited(),
            shard_admission: Admission::unlimited(),
            shards: 1,
            placement: Placement::LeastLoaded,
            shed_infeasible: false,
            workers: 0,
            max_line_bytes: 1 << 20,
            read_timeout_ms: 60_000,
            trace_out: None,
            fault_spec: None,
            max_batch_retries: 0,
            shard_respawn: false,
            checkpoint_steps: 0,
            net: NetMode::Reactor,
            spans_out: None,
        }
    }
}

impl ServerConfig {
    /// The fleet topology this config describes (the per-client quota
    /// travels with the shard budgets — it is enforced shard-side).
    /// Public so harnesses that drive [`serve_on`] directly (the chaos
    /// integration tests) launch their [`Fleet`] with exactly the
    /// serving semantics.
    pub fn fleet_config(&self) -> FleetConfig {
        FleetConfig {
            shards: self.shards.max(1),
            placement: self.placement,
            scheduler: self.scheduler,
            global_admission: Admission {
                max_in_flight: self.admission.max_in_flight,
                max_queued_nfes: self.admission.max_queued_nfes,
                max_in_flight_per_client: None,
            },
            shard_admission: Admission {
                max_in_flight: self.shard_admission.max_in_flight,
                max_queued_nfes: self.shard_admission.max_queued_nfes,
                max_in_flight_per_client: self.admission.max_in_flight_per_client,
            },
            workers: self.workers,
            shed_infeasible: self.shed_infeasible,
            max_batch_retries: self.max_batch_retries,
            respawn: self.shard_respawn,
            checkpoint_steps: self.checkpoint_steps,
        }
    }
}

/// Top-level request fields that are *not* policy parameters.
const ENVELOPE_KEYS: &[&str] = &[
    "prompt", "policy", "steps", "seed", "negative", "image", "model", "src_image", "guidance",
    "client_id", "priority", "deadline_ms", "trace", "id", "progress",
];

/// Parse one protocol line into a [`Request`] (without an id — the fleet
/// router assigns globally unique ids at placement).
pub fn parse_request_line(
    line: &str,
    cfg: &ServerConfig,
    registry: &PolicyRegistry,
) -> Result<(Request, bool)> {
    let v = json::parse(line).map_err(|e| anyhow!("bad request json: {e}"))?;
    parse_request_value(&v, cfg, registry)
}

/// Build a [`Request`] from an already-parsed protocol object — the
/// serving path parses each line exactly once (`dispatch_line`).
pub fn parse_request_value(
    v: &Value,
    cfg: &ServerConfig,
    registry: &PolicyRegistry,
) -> Result<(Request, bool)> {
    let prompt_text = v
        .get("prompt")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("missing `prompt`"))?;
    let prompt = Prompt::parse(prompt_text).ok_or_else(|| anyhow!("unparseable prompt"))?;
    let steps = v
        .get("steps")
        .and_then(Value::as_usize)
        .unwrap_or(cfg.default_steps);

    // policy spec: bare name or object; top-level fields fill the gaps.
    let mut spec = match v.get("policy") {
        None => PolicySpec::new("ag"),
        Some(pv) => PolicySpec::from_json(pv)?,
    };
    if let Some(obj) = v.as_obj() {
        for (key, val) in obj {
            if !ENVELOPE_KEYS.contains(&key.as_str()) {
                spec.set_default(key, val.clone());
            }
        }
    }
    if let Some(g) = v.get("guidance").and_then(Value::as_f64) {
        spec.set_default("s", json::num(g));
    }
    // expand `--policy-file` aliases now, so the server defaults below fill
    // only what neither the client nor the preset set
    let mut spec = registry.resolve(&spec)?;
    // the server's configured defaults fill whatever is still unset
    spec.set_default("s", json::num(cfg.default_guidance));
    if spec.canonical_kind() == "ag" {
        spec.set_default("gamma_bar", json::num(cfg.default_gamma_bar));
    }
    let policy = registry.build(&spec)?;
    // reject bad policy/request combinations here (error reply) rather
    // than letting them panic an engine thread mid-generation
    policy
        .validate(steps)
        .map_err(|e| anyhow!("policy `{}` rejected the request: {e}", policy.name()))?;

    let mut req = Request::new(
        0,
        &v.get("model")
            .and_then(Value::as_str)
            .unwrap_or(&cfg.model)
            .to_owned(),
        prompt.tokens(),
        v.get("seed").and_then(Value::as_f64).unwrap_or(0.0) as u64,
        steps,
        policy,
    );
    if let Some(neg) = v.get("negative").and_then(Value::as_str) {
        let p = Prompt::parse(neg).unwrap();
        // negative prompts set only the slots mentioned; color-only is the
        // common case, so map any parsed attribute that differs from default
        let mut toks = vec![0i32; 4];
        let lower = neg.to_lowercase();
        if crate::prompts::SHAPES.iter().any(|s| lower.contains(s)) {
            toks[0] = p.shape as i32 + 1;
        }
        if crate::prompts::COLORS.iter().any(|s| lower.contains(s)) {
            toks[1] = p.color as i32 + 1;
        }
        if crate::prompts::POSITIONS.iter().any(|s| lower.contains(s)) {
            toks[2] = p.position as i32 + 1;
        }
        if crate::prompts::SIZES.iter().any(|s| lower.contains(s)) {
            toks[3] = p.size as i32 + 1;
        }
        req.neg_tokens = Some(toks);
    }
    if let Some(src) = v.get("src_image") {
        let vals = src
            .as_f64_vec()
            .ok_or_else(|| anyhow!("`src_image` must be an array of numbers"))?;
        req.src_image = Some(vals.into_iter().map(|f| f as f32).collect());
    }
    // scheduling envelope: fair-share lane, EDF deadline, priority
    if let Some(client) = v.get("client_id").and_then(Value::as_str) {
        req.client_id = Some(Arc::from(client));
    }
    if let Some(p) = v.get("priority").and_then(Value::as_f64) {
        req.priority = p as i32;
    }
    if let Some(d) = v.get("deadline_ms").and_then(Value::as_f64) {
        req.deadline_ms = Some(d as u64);
    }
    // §Observability: opt this request into lifecycle-span recording; its
    // timeline is echoed on the completion line
    if v.get("trace").and_then(Value::as_bool) == Some(true) {
        req.trace = true;
    }
    // opt into per-step `{"event":"progress",..}` streaming — honoured by
    // the reactor front end; the threaded baseline cannot stream and
    // silently drops the samples
    if v.get("progress").and_then(Value::as_bool) == Some(true) {
        req.progress = true;
    }
    let want_image = v.get("image").and_then(Value::as_bool).unwrap_or(false);
    Ok((req, want_image))
}

/// Encode a completion as a protocol line (the serving policy's display
/// name is echoed so clients can attribute per-policy cost).
pub fn completion_to_line(c: &Completion, ms: f64, with_image: bool) -> String {
    completion_to_line_tagged(c, ms, with_image, None)
}

/// [`completion_to_line`] with the client's own wire id echoed in place
/// of the fleet-assigned one — the pipelined protocol (a client that
/// tags requests with `"id"` gets that id back verbatim on every reply,
/// which is what lets it match replies arriving out of order). `None`
/// keeps the fleet id, byte-identical to the historical rendering.
pub fn completion_to_line_tagged(
    c: &Completion,
    ms: f64,
    with_image: bool,
    wire_id: Option<&Value>,
) -> String {
    use json::{arr, num, obj, s};
    let mut fields = vec![
        (
            "id",
            wire_id.cloned().unwrap_or_else(|| num(c.id as f64)),
        ),
        ("policy", s(&c.policy)),
        ("nfes", num(c.nfes as f64)),
        ("cfg_steps", num(c.cfg_steps as f64)),
        ("ms", num((ms * 100.0).round() / 100.0)),
        (
            "truncated_at",
            c.truncated_at.map(|t| num(t as f64)).unwrap_or(Value::Null),
        ),
    ];
    if with_image {
        fields.push((
            "image",
            arr(c.image.iter().map(|&v| num(v as f64)).collect()),
        ));
    }
    // §Observability: the span timeline for `"trace": true` requests
    if let Some(tl) = &c.timeline {
        fields.push(("timeline", tl.clone()));
    }
    json::to_string(&obj(fields))
}

/// Push the structured fields of one admission refusal: the `code` plus
/// the budget numbers clients back off against.
fn admit_error_fields(refused: &AdmitError, fields: &mut Vec<(&'static str, Value)>) {
    match refused {
        AdmitError::InFlightFull { in_flight, max } => {
            fields.push(("code", json::s("queue_full")));
            fields.push(("in_flight", json::num(*in_flight as f64)));
            fields.push(("max_in_flight", json::num(*max as f64)));
        }
        AdmitError::NfeBudgetFull {
            queued_nfes,
            request_nfes,
            max,
        } => {
            fields.push(("code", json::s("queue_full")));
            fields.push(("queued_nfes", json::num(*queued_nfes as f64)));
            fields.push(("request_nfes", json::num(*request_nfes as f64)));
            fields.push(("max_queued_nfes", json::num(*max as f64)));
        }
        AdmitError::ClientBusy {
            client,
            in_flight,
            max,
        } => {
            fields.push(("code", json::s("queue_full")));
            fields.push(("client", json::s(client)));
            fields.push(("in_flight", json::num(*in_flight as f64)));
            fields.push(("max_in_flight_per_client", json::num(*max as f64)));
        }
        AdmitError::DeadlineInfeasible {
            deadline_ms,
            estimated_ms,
            queued_nfes,
        } => {
            fields.push(("code", json::s("deadline_infeasible")));
            fields.push(("deadline_ms", json::num(*deadline_ms as f64)));
            fields.push(("estimated_ms", json::num(*estimated_ms as f64)));
            fields.push(("queued_nfes", json::num(*queued_nfes as f64)));
        }
        AdmitError::Invalid { reason } => {
            fields.push(("code", json::s("invalid_request")));
            fields.push(("reason", json::s(reason)));
        }
    }
}

/// The structured fields an error downcasts to (shared by
/// [`error_to_line`] and the code-defaulting request path).
fn error_fields(e: &anyhow::Error) -> Vec<(&'static str, Value)> {
    let mut fields = vec![("error", json::s(&format!("{e:#}")))];
    if let Some(SpecError::UnknownPolicy { known, .. }) = e.downcast_ref::<SpecError>() {
        fields.push((
            "registered",
            json::arr(known.iter().map(|n| json::s(n)).collect()),
        ));
    }
    if let Some(scoped) = e.downcast_ref::<ScopedShed>() {
        admit_error_fields(&scoped.inner, &mut fields);
        fields.push(("scope", json::s(scoped.scope)));
    } else if let Some(refused) = e.downcast_ref::<AdmitError>() {
        admit_error_fields(refused, &mut fields);
    }
    // a shard that died mid-flight: not the client's fault, retryable on
    // the survivors — the code + shard index say so
    if let Some(failed) = e.downcast_ref::<ShardFailed>() {
        fields.push(("code", json::s("shard_failed")));
        fields.push(("shard", json::num(failed.shard as f64)));
    }
    // the client pulled the request back with {"cmd":"cancel"}: the work
    // was torn down and the admission/quota charges refunded
    if e.downcast_ref::<Canceled>().is_some() {
        fields.push(("code", json::s("canceled")));
    }
    match e.downcast_ref::<RouteError>() {
        // graceful drain: clients should stop sending and disconnect
        Some(RouteError::Draining) => fields.push(("code", json::s("draining"))),
        // every shard is dead (not a drain): clients should fail over,
        // not politely wait out a shutdown that never announced itself
        Some(RouteError::Closed) => fields.push(("code", json::s("unavailable"))),
        None => {}
    }
    fields
}

/// Encode an error as a structured protocol line (proper JSON escaping).
/// Unknown-policy errors carry the registered names; admission shedding
/// carries `"code": "queue_full"` plus the budget numbers (and, from a
/// fleet, the `"scope"` that tripped) so clients can back off
/// proportionally; infeasible deadlines carry `"code":
/// "deadline_infeasible"`; a draining fleet replies `"code": "draining"`,
/// an all-shards-dead fleet `"code": "unavailable"`, and a shard death
/// mid-flight `"code": "shard_failed"`; malformed requests refused at
/// the door carry `"code": "invalid_request"`.
pub fn error_to_line(e: &anyhow::Error) -> String {
    json::to_string(&json::obj(error_fields(e)))
}

/// Error line with `code` defaulting to `code` when no downcast set one —
/// the request path uses this so *every* refusal is machine-readable
/// (a bad-JSON frame or unknown policy is `"invalid_request"`, an
/// unrecognized `{"cmd"}` is `"unknown_cmd"`).
pub(crate) fn error_line_coded(e: &anyhow::Error, code: &str) -> String {
    let mut fields = error_fields(e);
    if !fields.iter().any(|(k, _)| *k == "code") {
        fields.push(("code", json::s(code)));
    }
    json::to_string(&json::obj(fields))
}

/// A protocol error line from scratch (no anyhow error to downcast) —
/// the wire-hardening replies (oversized frame, mid-line timeout).
pub(crate) fn static_error_line(msg: &str, code: &str) -> String {
    json::to_string(&json::obj(vec![
        ("error", json::s(msg)),
        ("code", json::s(code)),
    ]))
}

/// Splice the client's wire id onto an already-rendered reply line (the
/// error renderers never emit an `"id"` themselves, so the splice cannot
/// collide). Identity when the client supplied no id — keeping id-less
/// traffic byte-identical to the historical protocol.
pub(crate) fn inject_id(line: String, wire_id: Option<&Value>) -> String {
    match wire_id {
        Some(idv) if line.ends_with('}') => {
            let mut out = line;
            out.pop();
            out.push_str(",\"id\":");
            out.push_str(&json::to_string(idv));
            out.push('}');
            out
        }
        _ => line,
    }
}

/// Handle one administrative `{"cmd": ..}` verb — shared by the threaded
/// front end ([`dispatch_line`]) and the reactor (`cancel` is *not* here:
/// it needs the connection's in-flight id table, so each front end
/// implements it).
pub(crate) fn admin_cmd_line(cmd: &str, fleet: &Fleet) -> String {
    match cmd {
        "stats" => match fleet.stats_json() {
            Ok(v) => json::to_string(&v),
            Err(e) => error_to_line(&e),
        },
        // the exposition is multi-line; the connection handler's
        // closing "\n" turns the trailing newline into the blank-line
        // terminator the protocol docs promise
        "metrics" => match fleet.metrics_prometheus() {
            Ok(text) => text,
            Err(e) => error_to_line(&e),
        },
        // §Observability: drain every shard's span ring (one reply
        // object; see docs/OBSERVABILITY.md and `agd profile`)
        "spans" => match fleet.drain_spans() {
            Ok(batches) => json::to_string(&crate::trace::batches_to_json(&batches)),
            Err(e) => error_to_line(&e),
        },
        // graceful quiesce: stop admitting, wait for every shard to go
        // idle, join the engine threads, then acknowledge
        "drain" => {
            let shards = fleet.shutdown();
            json::to_string(&json::obj(vec![
                ("drained", Value::Bool(true)),
                ("shards", json::num(shards as f64)),
            ]))
        }
        other => error_line_coded(
            &anyhow!("unknown cmd `{other}` (supported: stats, metrics, spans, drain, cancel)"),
            "unknown_cmd",
        ),
    }
}

/// Dispatch one protocol line: a `{"cmd": ..}` control line or a
/// generation request. Returns the reply line, or None when the fleet is
/// gone mid-request and the connection should close. When a trace sink
/// is wired (`--trace-out`), every *served* request appends one record —
/// arrival offset sampled here at entry (so replay reproduces arrival
/// spacing), digest computed from the completion the client was sent.
fn dispatch_line(
    line: &str,
    fleet: &Fleet,
    cfg: &ServerConfig,
    registry: &PolicyRegistry,
    trace: Option<&TraceSink>,
) -> Option<String> {
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return Some(error_line_coded(
                &anyhow!("bad request json: {e}"),
                "invalid_request",
            ))
        }
    };
    if let Some(cmd) = v.get("cmd").and_then(Value::as_str) {
        // the threaded front end serves each connection synchronously —
        // by the time a cancel line is read, the previous request already
        // completed — so every cancel misses. The reactor implements the
        // verb for real; this keeps the A/B baseline protocol-complete.
        if cmd == "cancel" {
            let line = static_error_line(
                "no such request in flight on this connection \
                 (the threaded front end serves synchronously; \
                 use --net reactor for wire-level cancellation)",
                "unknown_id",
            );
            return Some(inject_id(line, v.get("id")));
        }
        return Some(admin_cmd_line(cmd, fleet));
    }
    let wire_id = v.get("id");
    let arrival_us = trace.map(TraceSink::arrival_offset_us);
    match parse_request_value(&v, cfg, registry) {
        Ok((req, want_image)) => {
            let client_id = req.client_id.clone();
            match fleet.submit(req) {
                Ok(reply) => loop {
                    match reply.recv() {
                        // a blocking front end cannot stream: progress
                        // samples for opted-in requests are dropped here
                        Ok(JobReply::Progress(_)) => continue,
                        Ok(JobReply::Done(c, ms)) => {
                            if let (Some(sink), Some(at)) = (trace, arrival_us) {
                                sink.record(at, &v, client_id.as_deref(), &completion_digest(&c));
                            }
                            break Some(completion_to_line_tagged(&c, ms, want_image, wire_id));
                        }
                        Ok(JobReply::Error(line)) => break Some(inject_id(line, wire_id)),
                        Err(_) => break None, // shard died mid-request
                    }
                },
                Err(e) => Some(inject_id(error_to_line(&e), wire_id)),
            }
        }
        Err(e) => Some(inject_id(error_line_coded(&e, "invalid_request"), wire_id)),
    }
}

/// One bounded, deadline-aware line read (§Robustness: input hardening).
enum LineRead {
    Line(String),
    /// Complete line, not UTF-8: refusable without closing.
    BadUtf8,
    /// The cap tripped before a newline arrived: refuse + close, and
    /// never buffer more than the cap.
    TooLong,
    /// Deadline passed with no partial line: silent close.
    IdleTimeout,
    /// Deadline passed mid-line — the slowloris shape: coded reply + close.
    MidLineTimeout,
    /// EOF or a hard IO error.
    Closed,
}

/// Read one `\n`-terminated line without ever holding more than `max`
/// bytes, honouring the socket's read timeout (`deadline`, if any)
/// *per line*: a writer trickling one byte per `timeout-ε` still trips
/// the deadline, because it is measured from the line's first byte.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    max: usize,
    deadline: Option<Duration>,
) -> LineRead {
    let mut buf: Vec<u8> = Vec::new();
    let mut line_started: Option<Instant> = None;
    loop {
        if let (Some(dl), Some(t0)) = (deadline, line_started) {
            if t0.elapsed() >= dl {
                return LineRead::MidLineTimeout;
            }
        }
        let chunk = match reader.fill_buf() {
            Ok([]) => return LineRead::Closed, // EOF (mid-line EOF included)
            Ok(c) => c,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // the socket read timeout fired: idle vs slowloris is
                // whether a line is in progress
                return if buf.is_empty() {
                    LineRead::IdleTimeout
                } else {
                    LineRead::MidLineTimeout
                };
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return LineRead::Closed,
        };
        if line_started.is_none() {
            line_started = Some(Instant::now());
        }
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            if buf.len() + pos > max {
                reader.consume(pos + 1);
                return LineRead::TooLong;
            }
            buf.extend_from_slice(&chunk[..pos]);
            reader.consume(pos + 1);
            return match String::from_utf8(buf) {
                Ok(s) => LineRead::Line(s),
                Err(_) => LineRead::BadUtf8,
            };
        }
        let n = chunk.len();
        if buf.len() + n > max {
            reader.consume(n);
            return LineRead::TooLong;
        }
        buf.extend_from_slice(chunk);
        reader.consume(n);
    }
}

fn handle_conn(
    stream: TcpStream,
    fleet: Arc<Fleet>,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
    trace: Option<Arc<TraceSink>>,
) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_default();
    let deadline = (cfg.read_timeout_ms > 0).then(|| Duration::from_millis(cfg.read_timeout_ms));
    if stream.set_read_timeout(deadline).is_err() {
        log::warn!("connection {peer}: set_read_timeout failed");
    }
    // a failed clone (fd pressure) closes this connection, not the server
    let mut reader = match stream.try_clone() {
        Ok(clone) => BufReader::new(clone),
        Err(e) => {
            log::warn!("connection {peer}: stream clone failed: {e}");
            return;
        }
    };
    let mut writer = stream;
    let mut send = |writer: &mut TcpStream, line: &str| -> bool {
        writer.write_all(line.as_bytes()).is_ok() && writer.write_all(b"\n").is_ok()
    };
    loop {
        match read_line_bounded(&mut reader, cfg.max_line_bytes, deadline) {
            LineRead::Line(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                let Some(reply_line) =
                    dispatch_line(&line, &fleet, &cfg, &registry, trace.as_deref())
                else {
                    break;
                };
                if !send(&mut writer, &reply_line) {
                    break;
                }
            }
            // a complete non-UTF-8 frame is refusable in-band; the
            // connection survives (framing is intact)
            LineRead::BadUtf8 => {
                fleet.count("conn_bad_line_total", &[("kind", "utf8")]);
                let line =
                    static_error_line("request line is not valid UTF-8", "invalid_request");
                if !send(&mut writer, &line) {
                    break;
                }
            }
            // past the cap the rest of the frame is undelimited garbage:
            // refuse and close
            LineRead::TooLong => {
                fleet.count("conn_bad_line_total", &[("kind", "oversized")]);
                let line = static_error_line(
                    &format!(
                        "request line exceeds --max-line-bytes ({})",
                        cfg.max_line_bytes
                    ),
                    "invalid_request",
                );
                let _ = send(&mut writer, &line);
                break;
            }
            LineRead::IdleTimeout => {
                fleet.count("conn_timeout_total", &[("kind", "idle")]);
                break;
            }
            LineRead::MidLineTimeout => {
                fleet.count("conn_timeout_total", &[("kind", "midline")]);
                let line = static_error_line(
                    &format!(
                        "no complete request line within --read-timeout-ms ({})",
                        cfg.read_timeout_ms
                    ),
                    "timeout",
                );
                let _ = send(&mut writer, &line);
                break;
            }
            LineRead::Closed => break,
        }
    }
    log::info!("connection {peer} closed");
}

/// Accept-loop errors worth surviving: interruptions, handshake races
/// the peer already abandoned, and resource-pressure conditions that
/// clear on their own (EMFILE/ENFILE/ENOBUFS/ENOMEM have no stable
/// `ErrorKind`, so they are matched by raw OS errno). Anything else —
/// an invalidated listener, a torn-down address — is permanent and must
/// kill `serve` so a supervisor restarts it.
pub(crate) fn transient_accept_error(e: &std::io::Error) -> bool {
    use std::io::ErrorKind;
    matches!(
        e.kind(),
        ErrorKind::Interrupted
            | ErrorKind::WouldBlock
            | ErrorKind::ConnectionAborted
            | ErrorKind::ConnectionReset
            | ErrorKind::TimedOut
            | ErrorKind::OutOfMemory
    ) || matches!(
        e.raw_os_error(),
        Some(libc_errno::ENFILE)
            | Some(libc_errno::EMFILE)
            | Some(libc_errno::ENOBUFS)
            | Some(libc_errno::ENOMEM)
    )
}

/// The handful of errno values the accept loop classifies (no libc crate
/// in the offline vendor set; these are the Linux values, which is what
/// the serving fleet deploys on — on other platforms the `ErrorKind` arm
/// still catches the common cases).
mod libc_errno {
    pub const ENOMEM: i32 = 12;
    pub const ENFILE: i32 = 23;
    pub const EMFILE: i32 = 24;
    pub const ENOBUFS: i32 = 105;
}

/// Serve forever with the built-in policy registry.
pub fn serve<B, F>(factory: F, cfg: ServerConfig) -> Result<()>
where
    B: Backend + 'static,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    serve_with_registry(factory, cfg, Arc::new(PolicyRegistry::builtin()))
}

/// Serve forever with a caller-supplied registry — the hook for
/// deployments that register custom policies.
///
/// `factory` constructs one backend *inside each* shard's engine thread —
/// the PJRT client is thread-affine (not `Send`), so it must be born where
/// it runs; with `--shards N` it is called N times.
pub fn serve_with_registry<B, F>(
    factory: F,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
) -> Result<()>
where
    B: Backend + 'static,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!(
        "agd serving on {} (model {}, scheduler {}, {} shard(s), placement {})",
        cfg.addr,
        cfg.model,
        cfg.scheduler.name(),
        cfg.shards.max(1),
        cfg.placement.name()
    );
    // §Robustness: every shard backend goes behind the fault-injection
    // wrapper. A disarmed plan adds one relaxed atomic load per batch, so
    // the wrapper is unconditional — which is what lets the chaos
    // director arm faults on a *running* fleet (`fault error-every=50`)
    // without a restart. `--fault-spec` merely pre-arms the same plan.
    let plan = Arc::new(FaultPlan::default());
    if let Some(spec) = &cfg.fault_spec {
        let parsed = FaultSpec::parse(spec).map_err(|e| anyhow!("--fault-spec: {e}"))?;
        plan.arm(parsed);
    }
    let shard_plan = plan.clone();
    let fleet = Arc::new(Fleet::launch(
        move |shard| factory().map(|be| FaultyBackend::with_shard(be, shard_plan.clone(), shard as u64)),
        cfg.fleet_config(),
    ));
    fleet.set_fault_plan(plan);
    serve_on(listener, fleet, cfg, registry)
}

/// §Observability: the `--spans-out` pump — a detached background thread
/// draining every shard's span ring to a JSONL file on a short cadence,
/// mirroring `--trace-out`'s always-on capture. Rings hold
/// [`crate::trace::DEFAULT_SPAN_CAP`] events and overwrite on overflow;
/// between `{"cmd": "spans"}` polls that means silent loss under load —
/// this sink turns drop-on-full into append-to-disk. The thread exits on
/// its own when the fleet shuts down (`drain_spans` errors once every
/// shard is gone). Each line is one event object (the same schema
/// `{"cmd": "spans"}` replies carry, plus the shard id already stamped);
/// ring overwrites that still happen between sweeps are surfaced as the
/// monotonic `dropped` total in `{"cmd": "stats"}`.
fn spawn_span_pump(path: &str, fleet: &Arc<Fleet>) -> Result<()> {
    use std::io::BufWriter;
    let file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(|e| anyhow!("--spans-out {path}: {e}"))?;
    let mut out = BufWriter::new(file);
    let fleet = fleet.clone();
    std::thread::spawn(move || loop {
        std::thread::sleep(Duration::from_millis(500));
        match fleet.drain_spans() {
            Ok(batches) => {
                for batch in &batches {
                    for ev in &batch.events {
                        let row = crate::trace::event_to_json(ev, batch.shard, &batch.policies);
                        if out
                            .write_all(json::to_string(&row).as_bytes())
                            .and_then(|_| out.write_all(b"\n"))
                            .is_err()
                        {
                            log::warn!("--spans-out: write failed; span shipping stopped");
                            return;
                        }
                    }
                }
                let _ = out.flush();
            }
            // every shard gone: fleet drained/shut down — stop shipping
            Err(_) => return,
        }
    });
    Ok(())
}

/// Serve an already-bound listener with an already-launched fleet — the
/// production path of [`serve_with_registry`], public so the chaos
/// harness (`rust/tests/chaos_integration.rs`) can drive the *real*
/// serving loop (hardened reads, trace capture, counters and all) on an
/// ephemeral port while keeping a [`Fleet`] handle to inject faults into.
/// Dispatches on [`ServerConfig::net`]: the poll-based reactor (default)
/// or the legacy thread-per-connection loop. Blocks until the listener
/// fails permanently.
pub fn serve_on(
    listener: TcpListener,
    fleet: Arc<Fleet>,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
) -> Result<()> {
    let trace = match &cfg.trace_out {
        Some(path) => Some(Arc::new(TraceSink::create(path)?)),
        None => None,
    };
    if let Some(path) = &cfg.spans_out {
        spawn_span_pump(path, &fleet)?;
    }
    match cfg.net {
        NetMode::Reactor => crate::reactor::serve_reactor(listener, fleet, cfg, registry, trace),
        NetMode::Threads => serve_threads(listener, fleet, cfg, registry, trace),
    }
}

/// The historical accept loop: one OS thread per connection, blocking
/// line reads (`--net threads`; the A/B baseline against the reactor).
fn serve_threads(
    listener: TcpListener,
    fleet: Arc<Fleet>,
    cfg: ServerConfig,
    registry: Arc<PolicyRegistry>,
    trace: Option<Arc<TraceSink>>,
) -> Result<()> {
    for stream in listener.incoming() {
        // transient accept failures (EMFILE, aborted handshakes, EINTR)
        // must not kill the fleet: log, back off a beat, keep accepting.
        // A *permanent* listener failure still propagates, so supervisors
        // see the crash instead of a healthy-looking dead service.
        let stream = match stream {
            Ok(s) => s,
            Err(e) if transient_accept_error(&e) => {
                log::warn!("accept failed (transient, continuing): {e}");
                std::thread::sleep(std::time::Duration::from_millis(10));
                continue;
            }
            Err(e) => return Err(e.into()),
        };
        let fleet = fleet.clone();
        let cfg = cfg.clone();
        let registry = registry.clone();
        let trace = trace.clone();
        std::thread::spawn(move || handle_conn(stream, fleet, cfg, registry, trace));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::ols::OlsCoeffs;
    use crate::sim::gmm::Gmm;

    fn cfg() -> ServerConfig {
        ServerConfig {
            model: "gmm".into(),
            ..Default::default()
        }
    }

    fn reg() -> PolicyRegistry {
        PolicyRegistry::builtin()
    }

    fn parse(line: &str) -> Result<(Request, bool)> {
        parse_request_line(line, &cfg(), &reg())
    }

    #[test]
    fn fleet_config_forwards_the_robustness_knobs() {
        let scfg = ServerConfig {
            max_batch_retries: 3,
            shard_respawn: true,
            checkpoint_steps: 2,
            ..cfg()
        };
        let fc = scfg.fleet_config();
        assert_eq!(fc.max_batch_retries, 3);
        assert!(fc.respawn);
        assert_eq!(fc.checkpoint_steps, 2);
        // and the defaults keep every behaviour off — no retry, no
        // respawn, no checkpointing — so pre-existing deployments are
        // unchanged
        let fc = cfg().fleet_config();
        assert_eq!(fc.max_batch_retries, 0);
        assert!(!fc.respawn);
        assert_eq!(fc.checkpoint_steps, 0);
    }

    #[test]
    fn parses_minimal_request() {
        let (req, img) = parse(r#"{"prompt": "red circle"}"#).unwrap();
        assert_eq!(req.tokens, vec![1, 1, 1, 1]);
        assert_eq!(req.steps, 20);
        assert!(!img);
        assert!(req.policy.name().starts_with("ag("));
        // the configured default gamma-bar flows into the default policy
        assert!(req.policy.name().contains("0.9988"));
    }

    #[test]
    fn parses_full_request() {
        let line = r#"{"prompt": "a large blue square at the top-left",
            "policy": "cfg", "steps": 10, "guidance": 5.0, "seed": 9,
            "negative": "red", "image": true}"#;
        let (req, img) = parse(line).unwrap();
        assert_eq!(req.steps, 10);
        assert!(img);
        assert_eq!(req.policy.name(), "cfg(s=5)");
        assert_eq!(req.neg_tokens, Some(vec![0, 1, 0, 0])); // red = color 1
        assert_eq!(req.seed, 9);
    }

    #[test]
    fn parses_every_registered_policy_kind() {
        // server parity: policies that used to be CLI/bench-only are now
        // reachable through the line protocol via PolicySpec.
        let coeffs = json::to_string(&OlsCoeffs::identity(8).to_json());
        let lines = [
            format!(r#"{{"prompt": "x", "policy": "linear-ag", "steps": 8, "coeffs": {coeffs}}}"#),
            r#"{"prompt": "x", "policy": "ag-prefix", "cfg_steps": 3}"#.to_owned(),
            r#"{"prompt": "x", "policy": "alternating"}"#.to_owned(),
            r#"{"prompt": "x", "policy": "searched", "choices": ["cfg", "cond", "uncond", 2.5]}"#
                .to_owned(),
            r#"{"prompt": "x", "policy": "pix2pix", "src_image": [0.0, 0.5]}"#.to_owned(),
            r#"{"prompt": "x", "policy": "compressed-cfg", "period": 5}"#.to_owned(),
            r#"{"prompt": "x", "policy": "adaptive-scale", "s_max": 6.0, "s_min": 1.0}"#.to_owned(),
            r#"{"prompt": "x", "policy": {"kind": "ag-prefix", "cfg_steps": 2, "s": 3.0}}"#
                .to_owned(),
        ];
        for line in &lines {
            let (req, _) = parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert!(req.policy.max_nfes(req.steps) >= req.steps, "{line}");
        }
        // a coefficient table shorter than the request is an error reply,
        // not an engine-thread panic
        let short = format!(
            r#"{{"prompt": "x", "policy": "linear-ag", "steps": 20, "coeffs": {coeffs}}}"#
        );
        let err = parse(&short).unwrap_err();
        assert!(err.to_string().contains("cover"), "{err}");

        // spot-check parameters actually reached the policies
        let (req, _) = parse(&lines[1]).unwrap();
        assert_eq!(req.policy.max_nfes(20), 23); // 3 guided + 17 cond
        let (req, _) = parse(&lines[4]).unwrap();
        assert_eq!(req.src_image.as_deref(), Some(&[0.0f32, 0.5][..]));
        let (req, _) = parse(&lines[7]).unwrap();
        assert_eq!(req.policy.max_nfes(20), 22); // nested object form
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse("not json").is_err());
        assert!(parse(r#"{"no_prompt": 1}"#).is_err());
        assert!(parse(r#"{"prompt": "x", "policy": "warp"}"#).is_err());
    }

    #[test]
    fn unknown_policy_yields_structured_error_listing_registered() {
        let err = parse(r#"{"prompt": "x", "policy": "warp"}"#).unwrap_err();
        let line = error_to_line(&err);
        let v = json::parse(&line).unwrap_or_else(|e| panic!("error line not json: {line} ({e})"));
        assert!(v.req("error").as_str().unwrap().contains("warp"));
        let registered = v.req("registered").as_str_vec().unwrap();
        assert!(registered.contains(&"ag".to_owned()));
        assert!(registered.contains(&"compressed-cfg".to_owned()));
        assert!(registered.contains(&"adaptive-scale".to_owned()));

        // non-spec errors still produce valid JSON (escaping included)
        let err = parse(r#"{"prompt": 42}"#).unwrap_err();
        let line = error_to_line(&err);
        assert!(json::parse(&line).is_ok(), "{line}");
    }

    #[test]
    fn completion_roundtrip_line() {
        let c = Completion {
            id: 7,
            policy: "ag(ḡ=0.991)".into(),
            image: vec![0.5, -0.5],
            nfes: 31,
            cfg_steps: 11,
            truncated_at: Some(10),
            gammas: vec![],
            gammas_eps: vec![],
            trajectory: None,
            iterates: vec![],
            timeline: None,
        };
        let line = completion_to_line(&c, 12.345, true);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.req("nfes").as_f64(), Some(31.0));
        assert_eq!(v.req("truncated_at").as_f64(), Some(10.0));
        assert_eq!(v.req("policy").as_str(), Some("ag(ḡ=0.991)"));
        assert_eq!(v.req("image").as_arr().unwrap().len(), 2);
        let line2 = completion_to_line(&c, 1.0, false);
        assert!(json::parse(&line2).unwrap().get("image").is_none());
    }

    #[test]
    fn scheduling_envelope_fields_parse() {
        let line = r#"{"prompt": "red circle", "client_id": "web-42",
            "priority": 3, "deadline_ms": 2500}"#;
        let (req, _) = parse(line).unwrap();
        assert_eq!(req.client_id.as_deref(), Some("web-42"));
        assert_eq!(req.priority, 3);
        assert_eq!(req.deadline_ms, Some(2500));
        // none of them leak into policy parameters
        assert!(req.policy.name().starts_with("ag("));
        // and they stay optional
        let (req, _) = parse(r#"{"prompt": "red circle"}"#).unwrap();
        assert_eq!(req.client_id, None);
        assert_eq!(req.priority, 0);
        assert_eq!(req.deadline_ms, None);
    }

    #[test]
    fn alias_presets_resolve_under_server_defaults() {
        let mut reg = PolicyRegistry::builtin();
        reg.register_alias(
            "fast-ag",
            PolicySpec::new("ag").with("gamma_bar", json::num(0.5)),
        )
        .unwrap();
        // the preset's gamma_bar beats the server default, while the
        // server's guidance default still fills the unset `s`
        let (req, _) = parse_request_line(
            r#"{"prompt": "red circle", "policy": "fast-ag"}"#,
            &cfg(),
            &reg,
        )
        .unwrap();
        assert_eq!(req.policy.name(), "ag(ḡ=0.5)");
        // an explicit client value beats the preset
        let (req, _) = parse_request_line(
            r#"{"prompt": "red circle", "policy": "fast-ag", "gamma_bar": 0.7}"#,
            &cfg(),
            &reg,
        )
        .unwrap();
        assert_eq!(req.policy.name(), "ag(ḡ=0.7)");
    }

    #[test]
    fn queue_full_errors_are_structured() {
        let e = anyhow::Error::new(AdmitError::NfeBudgetFull {
            queued_nfes: 90,
            request_nfes: 40,
            max: 100,
        });
        let line = error_to_line(&e);
        let v = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("queued_nfes").as_f64(), Some(90.0));
        assert_eq!(v.req("max_queued_nfes").as_f64(), Some(100.0));
        assert!(v.req("error").as_str().unwrap().contains("queue full"));
        // an un-scoped admission error has no scope field…
        assert!(v.get("scope").is_none());
        // …while a fleet-scoped shed names the level that tripped
        let e = anyhow::Error::new(ScopedShed {
            scope: "global",
            inner: AdmitError::InFlightFull {
                in_flight: 8,
                max: 8,
            },
        });
        let v = json::parse(&error_to_line(&e)).unwrap();
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("scope").as_str(), Some("global"));
        assert_eq!(v.req("max_in_flight").as_f64(), Some(8.0));
    }

    #[test]
    fn deadline_infeasible_errors_are_structured() {
        let e = anyhow::Error::new(AdmitError::DeadlineInfeasible {
            deadline_ms: 50,
            estimated_ms: 420,
            queued_nfes: 84,
        });
        let line = error_to_line(&e);
        let v = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        assert_eq!(v.req("code").as_str(), Some("deadline_infeasible"));
        assert_eq!(v.req("deadline_ms").as_f64(), Some(50.0));
        assert_eq!(v.req("estimated_ms").as_f64(), Some(420.0));
        assert_eq!(v.req("queued_nfes").as_f64(), Some(84.0));
        assert!(v.req("error").as_str().unwrap().contains("deadline infeasible"));
    }

    #[test]
    fn draining_errors_are_structured() {
        let e = anyhow::Error::new(RouteError::Draining);
        let v = json::parse(&error_to_line(&e)).unwrap();
        assert_eq!(v.req("code").as_str(), Some("draining"));
        assert!(v.req("error").as_str().unwrap().contains("draining"));
        // a dead fleet is NOT a graceful drain — clients must fail over,
        // so the code differs
        let e = anyhow::Error::new(RouteError::Closed);
        let v = json::parse(&error_to_line(&e)).unwrap();
        assert_eq!(v.req("code").as_str(), Some("unavailable"));
    }

    #[test]
    fn per_client_queue_full_errors_name_the_limit() {
        let e = anyhow::Error::new(AdmitError::ClientBusy {
            client: Arc::from("web-1"),
            in_flight: 3,
            max: 3,
        });
        let line = error_to_line(&e);
        let v = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("client").as_str(), Some("web-1"));
        assert_eq!(v.req("in_flight").as_f64(), Some(3.0));
        assert_eq!(v.req("max_in_flight_per_client").as_f64(), Some(3.0));
        assert!(v.req("error").as_str().unwrap().contains("per-client limit"));
    }

    #[test]
    fn invalid_request_errors_are_structured() {
        let e = anyhow::Error::new(AdmitError::Invalid {
            reason: "tokens must be non-empty (all-zero = unconditional)",
        });
        let line = error_to_line(&e);
        let v = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        assert_eq!(v.req("code").as_str(), Some("invalid_request"));
        assert!(v.req("reason").as_str().unwrap().contains("tokens"));
        assert!(v.req("error").as_str().unwrap().contains("invalid request"));
    }

    /// Spin up the *real* accept loop ([`serve_on`]) + fleet on the GMM
    /// backend over an ephemeral port; returns the address to connect to
    /// (and the fleet, so tests can inspect/drain it).
    fn spawn_test_server(scfg: ServerConfig) -> (std::net::SocketAddr, Arc<Fleet>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let scfg = ServerConfig {
            addr: addr.to_string(),
            model: "gmm".into(),
            // exercise the sharded execution path under real TCP traffic
            workers: 2,
            ..scfg
        };
        let fleet = Arc::new(Fleet::launch(
            |_shard| Ok(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05))),
            scfg.fleet_config(),
        ));
        let registry = Arc::new(PolicyRegistry::builtin());
        {
            let fleet = fleet.clone();
            std::thread::spawn(move || {
                let _ = serve_on(listener, fleet, scfg, registry);
            });
        }
        (addr, fleet)
    }

    /// One request/reply exchange on an open connection.
    fn roundtrip(conn: &mut TcpStream, line: &str) -> Value {
        use std::io::{BufRead, BufReader, Write};
        conn.write_all(line.as_bytes()).unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        json::parse(reply.trim()).unwrap_or_else(|e| panic!("{reply}: {e}"))
    }

    /// Full TCP round trip against a 2-shard GMM fleet.
    #[test]
    fn tcp_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        let (addr, _fleet) = spawn_test_server(ServerConfig {
            shards: 2,
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(
            br#"{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0}"#,
        )
        .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_none(), "{line}");
        assert!(v.req("nfes").as_f64().unwrap() <= 16.0);
        assert!(
            v.req("policy").as_str().unwrap().starts_with("ag("),
            "{line}"
        );

        // a plugin policy over the same connection: compressed-cfg at
        // period 4 over 8 steps costs exactly 2·2 + 6 = 10 NFEs.
        let mut conn = reader.into_inner();
        conn.write_all(
            br#"{"prompt": "red circle", "policy": "compressed-cfg", "period": 4, "steps": 8, "guidance": 2.0}"#,
        )
        .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(v.req("nfes").as_f64(), Some(10.0), "{line}");

        // unknown policy: structured error, connection stays usable
        let mut conn = reader.into_inner();
        conn.write_all(br#"{"prompt": "red circle", "policy": "warp"}"#)
            .unwrap();
        conn.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_some(), "{line}");
        assert!(v.req("registered").as_str_vec().unwrap().len() >= 10);
    }

    /// Admission over the wire: a request past the fleet-global queued-NFE
    /// budget gets a structured `queue_full` reply with `"scope":
    /// "global"`, nothing panics, and the connection keeps serving
    /// admissible requests.
    #[test]
    fn tcp_queue_full_shed_and_recovery() {
        // budget below one 8-step CFG request (16 NFEs) but enough for a
        // 4-step one (8 NFEs)
        let (addr, _fleet) = spawn_test_server(ServerConfig {
            scheduler: SchedulerKind::CostAware,
            admission: Admission {
                max_queued_nfes: Some(10),
                ..Admission::unlimited()
            },
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 8, "guidance": 2.0}"#,
        );
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("scope").as_str(), Some("global"));
        assert_eq!(v.req("max_queued_nfes").as_f64(), Some(10.0));
        assert_eq!(v.req("request_nfes").as_f64(), Some(16.0));
        assert!(v.req("error").as_str().unwrap().contains("queue full"));
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4, "guidance": 2.0}"#,
        );
        assert!(v.get("error").is_none(), "in-budget request must complete");
        assert_eq!(v.req("nfes").as_f64(), Some(8.0));
    }

    /// Per-client quota over the wire: the same client is shed past its
    /// in-flight quota with a `queue_full` line naming the per-client
    /// limit. The quota is enforced shard-side, so the scope says so.
    /// (Requests on this synchronous test connection complete before the
    /// next is sent, so the quota is exercised with limit 0 — the shed
    /// path — while other clients stay unaffected.)
    #[test]
    fn tcp_per_client_quota_sheds() {
        let (addr, _fleet) = spawn_test_server(ServerConfig {
            admission: Admission {
                max_in_flight_per_client: Some(0),
                ..Admission::unlimited()
            },
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4, "client_id": "greedy"}"#,
        );
        assert_eq!(v.req("code").as_str(), Some("queue_full"));
        assert_eq!(v.req("scope").as_str(), Some("shard"));
        assert_eq!(v.req("client").as_str(), Some("greedy"));
        assert_eq!(v.req("max_in_flight_per_client").as_f64(), Some(0.0));
        assert!(v.req("error").as_str().unwrap().contains("per-client limit"));
    }

    /// `{"cmd": "metrics"}` returns Prometheus exposition text terminated
    /// by a blank line, generated from the merged fleet registry — fleet
    /// totals plus `shard=`-labelled series.
    #[test]
    fn tcp_metrics_command_returns_prometheus_text() {
        use std::io::{BufRead, BufReader, Write};
        let (addr, _fleet) = spawn_test_server(ServerConfig {
            shards: 2,
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
        let nfes = v.req("nfes").as_f64().unwrap();
        conn.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut exposition = String::new();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            if line.trim().is_empty() {
                break;
            }
            exposition.push_str(&line);
        }
        assert!(
            exposition.contains("# TYPE nfes_total counter"),
            "{exposition}"
        );
        // fleet total (unlabelled) and the shard-labelled series both
        // carry the request's NFEs (least-loaded put it on one shard)
        assert!(
            exposition.contains(&format!("nfes_total{{policy=\"ag\"}} {nfes}")),
            "{exposition}"
        );
        assert!(
            exposition.contains(&format!("nfes_total{{policy=\"ag\",shard=\"0\"}} {nfes}")),
            "{exposition}"
        );
        assert!(exposition.contains("fleet_shards 2"), "{exposition}");
        assert!(exposition.contains("# TYPE active_requests gauge"), "{exposition}");
        assert!(
            exposition.contains("# TYPE queue_wait_ms histogram"),
            "{exposition}"
        );
        assert!(exposition.contains("queue_wait_ms_count{policy=\"ag\"} 1"), "{exposition}");
        // the connection is still usable after the multi-line reply
        let mut conn = reader.into_inner();
        let stats = roundtrip(&mut conn, r#"{"cmd": "stats"}"#);
        assert!(stats.get("scheduler").is_some());
    }

    /// `{"cmd": "stats"}` dumps the fleet topology, totals, per-shard
    /// breakdown, and the merged telemetry registry with per-policy and
    /// per-client labels.
    #[test]
    fn tcp_stats_command_dumps_telemetry() {
        let (addr, _fleet) = spawn_test_server(ServerConfig {
            scheduler: SchedulerKind::FairShare,
            shards: 2,
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "ag", "steps": 8, "guidance": 2.0, "client_id": "cli-a"}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
        let nfes = v.req("nfes").as_f64().unwrap();
        let stats = roundtrip(&mut conn, r#"{"cmd": "stats"}"#);
        assert_eq!(stats.req("scheduler").as_str(), Some("fair-share"));
        assert_eq!(stats.req("shards").as_f64(), Some(2.0));
        assert_eq!(stats.req("placement").as_str(), Some("least-loaded"));
        assert_eq!(stats.req("draining").as_bool(), Some(false));
        assert_eq!(stats.req("active").as_f64(), Some(0.0));
        assert_eq!(stats.req("per_shard").as_arr().unwrap().len(), 2);
        let counters = stats.req("telemetry").req("counters");
        assert_eq!(counters.req("nfes_total{policy=ag}").as_f64(), Some(nfes));
        assert_eq!(
            counters
                .req("requests_completed_total{client=cli-a,policy=ag}")
                .as_f64(),
            Some(1.0)
        );
        // unknown cmd: structured error, connection stays usable
        let v = roundtrip(&mut conn, r#"{"cmd": "reboot"}"#);
        assert!(v.req("error").as_str().unwrap().contains("reboot"));
        let stats = roundtrip(&mut conn, r#"{"cmd": "stats"}"#);
        assert!(stats.get("scheduler").is_some());
    }

    /// `{"cmd": "drain"}`: in-flight work completes, every engine thread
    /// is joined, the ack reports the shard count, and subsequent requests
    /// are refused with `"code": "draining"`.
    #[test]
    fn tcp_drain_command_quiesces_the_fleet() {
        let (addr, fleet) = spawn_test_server(ServerConfig {
            shards: 2,
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4, "guidance": 2.0}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
        let v = roundtrip(&mut conn, r#"{"cmd": "drain"}"#);
        assert_eq!(v.req("drained").as_bool(), Some(true));
        assert_eq!(v.req("shards").as_f64(), Some(2.0));
        assert!(fleet.is_draining());
        // the same connection gets a structured refusal for new work
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4}"#,
        );
        assert_eq!(v.req("code").as_str(), Some("draining"));
        assert!(v.req("error").as_str().unwrap().contains("draining"));
        // drain is idempotent over the wire too
        let v = roundtrip(&mut conn, r#"{"cmd": "drain"}"#);
        assert_eq!(v.req("drained").as_bool(), Some(true));
    }

    /// §Observability over the wire: `"trace": true` echoes the request's
    /// lifecycle timeline on the completion line (all seven stages), and
    /// `{"cmd": "spans"}` drains the shard rings — span events for the
    /// traced request plus guidance events for every request. A second
    /// drain returns an empty batch set.
    #[test]
    fn tcp_trace_opt_in_and_spans_command() {
        let (addr, _fleet) = spawn_test_server(ServerConfig {
            shards: 2,
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        // untraced request: guidance events only, no timeline echo
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4, "guidance": 2.0}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
        assert!(v.get("timeline").is_none(), "{v:?}");
        // traced request: the completion line carries the timeline
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "ag", "steps": 8,
                "guidance": 2.0, "trace": true}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
        let tl = v.req("timeline").as_arr().expect("timeline array");
        assert!(!tl.is_empty());
        for stage in crate::trace::Stage::ALL {
            assert!(
                tl.iter().any(|e| e.get("stage").and_then(Value::as_str)
                    == Some(stage.name())),
                "timeline missing stage {} in {v:?}",
                stage.name()
            );
        }
        // the spans verb drains both requests' events from the rings
        let v = roundtrip(&mut conn, r#"{"cmd": "spans"}"#);
        let spans = v.req("spans").as_arr().expect("spans array");
        assert!(v.req("dropped").as_f64().is_some(), "{v:?}");
        assert!(
            spans.iter().any(|e| e.get("type").and_then(Value::as_str)
                == Some("guidance")),
            "{v:?}"
        );
        assert!(
            spans.iter().any(|e| e.get("type").and_then(Value::as_str)
                == Some("span")),
            "{v:?}"
        );
        // guidance events cover both policies even though only one traced
        for policy in ["cfg", "ag"] {
            assert!(
                spans.iter().any(|e| {
                    e.get("policy").and_then(Value::as_str) == Some(policy)
                }),
                "no guidance events for {policy}: {v:?}"
            );
        }
        // draining cleared the rings
        let v = roundtrip(&mut conn, r#"{"cmd": "spans"}"#);
        assert_eq!(v.req("spans").as_arr().map(<[Value]>::len), Some(0), "{v:?}");
    }

    /// Structured `shard_failed` lines: a mid-flight shard death
    /// downcasts to [`ShardFailed`], names the shard, and tells the
    /// client the request is retryable on the survivors.
    #[test]
    fn shard_failed_errors_are_structured() {
        let e = anyhow::Error::new(ShardFailed {
            shard: 3,
            reason: "engine pump failed: boom".into(),
        });
        let line = error_to_line(&e);
        let v = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        assert_eq!(v.req("code").as_str(), Some("shard_failed"));
        assert_eq!(v.req("shard").as_f64(), Some(3.0));
        assert!(v.req("error").as_str().unwrap().contains("boom"));
        assert!(v.req("error").as_str().unwrap().contains("shard 3"));
    }

    /// §Robustness: the malformed-frame table. Every complete-but-bad
    /// frame gets a structured, coded refusal in-band; none of them kill
    /// the connection (framing stays intact) or the fleet.
    #[test]
    fn tcp_malformed_frames_are_refused_in_band() {
        use std::io::{BufRead, BufReader, Write};
        let (addr, fleet) = spawn_test_server(ServerConfig::default());
        let mut conn = TcpStream::connect(addr).unwrap();
        let table: &[(&[u8], &str)] = &[
            (br#"{"prompt": "red circle""#, "invalid_request"), // truncated JSON
            (b"not json at all", "invalid_request"),
            (br#"{"cmd": "reboot"}"#, "unknown_cmd"),
            (b"{\"prompt\": \"\xff\xfe broken\"}", "invalid_request"), // non-UTF-8
        ];
        for (payload, want_code) in table {
            conn.write_all(payload).unwrap();
            conn.write_all(b"\n").unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            let v = json::parse(reply.trim()).unwrap_or_else(|e| panic!("{reply}: {e}"));
            assert_eq!(v.req("code").as_str(), Some(*want_code), "{reply}");
            assert!(v.get("error").is_some(), "{reply}");
        }
        // the connection AND the fleet still serve real work afterwards
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4, "guidance": 2.0}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
        assert_eq!(v.req("nfes").as_f64(), Some(8.0));
        // and the unframeable refusal was counted by kind
        let m = fleet.metrics_prometheus().unwrap();
        assert!(m.contains(r#"conn_bad_line_total{kind="utf8"} 1"#), "{m}");
    }

    /// §Robustness: the line-length cap. A frame past `--max-line-bytes`
    /// is refused with `invalid_request` and the connection is closed —
    /// past the cap the rest of the frame is undelimited garbage — while
    /// the listener keeps serving fresh connections.
    #[test]
    fn tcp_oversized_line_is_refused_and_closed() {
        use std::io::{BufRead, BufReader, Write};
        let (addr, fleet) = spawn_test_server(ServerConfig {
            max_line_bytes: 256,
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut big = vec![b'x'; 4096];
        big.push(b'\n');
        conn.write_all(&big).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = json::parse(reply.trim()).unwrap_or_else(|e| panic!("{reply}: {e}"));
        assert_eq!(v.req("code").as_str(), Some("invalid_request"), "{reply}");
        assert!(v.req("error").as_str().unwrap().contains("max-line-bytes"));
        // …and the server hangs up: the next read is EOF
        let mut end = String::new();
        assert_eq!(reader.read_line(&mut end).unwrap(), 0, "{end}");
        let m = fleet.metrics_prometheus().unwrap();
        assert!(
            m.contains(r#"conn_bad_line_total{kind="oversized"} 1"#),
            "{m}"
        );
        // the listener itself survives: a fresh connection still serves
        let mut conn = TcpStream::connect(addr).unwrap();
        let v = roundtrip(
            &mut conn,
            r#"{"prompt": "red circle", "policy": "cfg", "steps": 4, "guidance": 2.0}"#,
        );
        assert!(v.get("error").is_none(), "{v:?}");
    }

    /// §Robustness: slowloris cutoff. A connection that starts a frame
    /// but never finishes it is cut off at `--read-timeout-ms` with a
    /// coded `timeout` reply; a fully idle connection is closed silently.
    /// Both cutoffs are counted by kind.
    #[test]
    fn tcp_slowloris_and_idle_connections_time_out() {
        use std::io::{BufRead, BufReader, Write};
        let (addr, fleet) = spawn_test_server(ServerConfig {
            read_timeout_ms: 200,
            ..Default::default()
        });
        // slowloris: open a frame, never finish it
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"prompt\": ").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = json::parse(reply.trim()).unwrap_or_else(|e| panic!("{reply}: {e}"));
        assert_eq!(v.req("code").as_str(), Some("timeout"), "{reply}");
        assert!(v.req("error").as_str().unwrap().contains("read-timeout-ms"));
        let mut end = String::new();
        assert_eq!(reader.read_line(&mut end).unwrap(), 0, "{end}");
        // idle: no bytes at all → silent close
        let idle = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(idle.try_clone().unwrap());
        let mut end = String::new();
        assert_eq!(reader.read_line(&mut end).unwrap(), 0, "{end}");
        drop(idle);
        let m = fleet.metrics_prometheus().unwrap();
        assert!(m.contains(r#"conn_timeout_total{kind="midline"} 1"#), "{m}");
        assert!(m.contains(r#"conn_timeout_total{kind="idle"} 1"#), "{m}");
    }

    /// Tentpole hook: `--trace-out` appends one JSONL record per *served*
    /// request — arrival offset, original envelope, client id, and a
    /// completion digest that matches what the client computes from the
    /// reply it actually received. Refused frames are not recorded.
    #[test]
    fn tcp_trace_capture_round_trips_digests() {
        use crate::chaos::{read_trace, reply_digest};
        let path = std::env::temp_dir().join(format!(
            "agd_trace_capture_{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let (addr, _fleet) = spawn_test_server(ServerConfig {
            trace_out: Some(path.to_str().unwrap().to_owned()),
            ..Default::default()
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reply_digests = Vec::new();
        for (i, policy) in ["cfg", "ag"].iter().enumerate() {
            let line = format!(
                r#"{{"prompt": "red circle", "policy": "{policy}", "steps": 6, "guidance": 2.0, "seed": {i}, "image": true, "client_id": "cap-{i}"}}"#
            );
            let v = roundtrip(&mut conn, &line);
            assert!(v.get("error").is_none(), "{v:?}");
            reply_digests.push(reply_digest(&v).expect("reply has image+nfes+cfg_steps"));
        }
        // a refused frame must NOT be recorded
        let v = roundtrip(&mut conn, "not json");
        assert_eq!(v.req("code").as_str(), Some("invalid_request"));
        let records = read_trace(path.to_str().unwrap()).unwrap();
        assert_eq!(records.len(), 2, "only served requests are recorded");
        for (i, rec) in records.iter().enumerate() {
            assert_eq!(rec.client_id.as_deref(), Some(format!("cap-{i}").as_str()));
            assert_eq!(rec.digest.as_deref(), Some(reply_digests[i].as_str()));
            assert!(rec.wants_image());
            // the envelope round-trips as a replayable request line
            assert!(json::parse(&rec.request_line()).is_ok());
        }
        // arrival offsets are monotone (read_trace sorts by arrival)
        assert!(records[0].offset_us <= records[1].offset_us);
        let _ = std::fs::remove_file(&path);
    }
}
