//! The persistent worker pool behind the engine's multi-core execution
//! layer (§Perf: parallel execution).
//!
//! [`ExecPool`] is a dependency-free `std::thread` + `Mutex`/`Condvar`
//! parallel-for: `lanes` compute lanes total, `lanes - 1` worker threads
//! spawned once at construction plus the calling thread, which always
//! participates as lane 0. [`ExecPool::run`]`(n, f)` invokes `f(lane, i)`
//! exactly once for every `i in 0..n`, distributing indices dynamically
//! over the lanes (a shared atomic cursor, so heterogeneous per-item cost
//! balances itself), and returns only after every index has completed and
//! every worker has left the region.
//!
//! Guarantees the engine's determinism and zero-allocation stories rely
//! on:
//!
//! * **Exactly-once, unordered**: each index runs once, on some lane.
//!   Callers must make per-index work independent (disjoint output rows /
//!   slots) and *identical regardless of which lane runs it* — then
//!   results are bit-identical for any lane count, which is how the engine
//!   keeps `--workers N` out of the numerics.
//! * **Allocation-free dispatch**: after construction, `run` touches no
//!   heap — the job descriptor is two raw pointers published under the
//!   mutex, workers park on a `Condvar`, and the closure is borrowed, not
//!   boxed. The steady-state pin lives in `rust/tests/par_zero_alloc.rs`.
//! * **Quiesced return**: `run` waits until all workers have exited the
//!   region (not merely until all items completed) before returning, so
//!   the borrowed closure and everything it captures are provably
//!   unobserved afterwards — this is what makes lending stack references
//!   to the workers sound.
//!
//! A pool built with `workers <= 1` spawns nothing and runs inline on the
//! caller; the engine's default is this serial pool, so single-worker
//! behaviour is byte-for-byte the pre-pool code path.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Per-run load report: how the last [`ExecPool::run`] spread its items.
/// Feeds the engine's `worker_occupancy` / `parallel_efficiency` gauges.
#[derive(Debug, Clone, Copy)]
pub struct RunStats {
    /// Total compute lanes (caller + workers).
    pub lanes: usize,
    /// Lanes that processed at least one item this run.
    pub active_lanes: usize,
    /// Items processed by the busiest lane.
    pub max_lane_items: usize,
    /// Items processed in total (= the `n` passed to `run`).
    pub items: usize,
}

impl RunStats {
    /// Occupancy in [0, 1]: fraction of lanes that did any work.
    pub fn occupancy(&self) -> f64 {
        if self.lanes == 0 {
            0.0
        } else {
            self.active_lanes as f64 / self.lanes as f64
        }
    }

    /// Load-balance efficiency in (0, 1]: 1.0 when every lane processed
    /// the same item count; `items / (lanes * max_lane_items)` otherwise
    /// (the busiest lane bounds the region's wall-clock).
    pub fn efficiency(&self) -> f64 {
        if self.items == 0 || self.max_lane_items == 0 {
            1.0
        } else {
            self.items as f64 / (self.lanes * self.max_lane_items) as f64
        }
    }
}

/// Type-erased job descriptor published to the workers. The `data`
/// pointer borrows the caller's closure for the duration of one `run`;
/// soundness comes from `run`'s quiesce-before-return contract.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize, usize),
    n: usize,
}

// Safety: `data` points at an `F: Sync` closure that outlives the region
// (workers quiesce before `run` returns), and `call` only ever invokes it
// through a shared reference.
unsafe impl Send for Job {}

unsafe fn call_thunk<F: Fn(usize, usize) + Sync>(data: *const (), lane: usize, idx: usize) {
    (*(data as *const F))(lane, idx)
}

/// Condvar-protected pool state. The atomics (cursor/remaining/lane
/// counters) live outside the mutex so the per-item fast path never takes
/// the lock; the mutex guards only job publication and quiescing.
struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new epoch (or shutdown).
    go: Condvar,
    /// The caller waits here for remaining == 0 && active == 0.
    done: Condvar,
    /// Next item index to claim (monotone within a region; reset under
    /// the state lock at publish, so a parked worker can never observe a
    /// fresh cursor with a stale job).
    cursor: AtomicUsize,
    /// Items not yet completed in the current region.
    remaining: AtomicUsize,
    /// Set when a closure panicked on any lane; `run` re-panics after
    /// quiescing so the failure is not silently swallowed.
    panicked: AtomicBool,
    /// Items processed per lane this region (gauge fodder).
    lane_items: Vec<AtomicUsize>,
}

struct PoolState {
    /// Region counter; a bump (with `job` set) is the start signal.
    epoch: u64,
    job: Option<Job>,
    /// Workers currently inside the region's claim loop. `run` returns
    /// only once this is back to 0 — the quiesce contract.
    active: usize,
    shutdown: bool,
}

/// The worker pool. See the module docs for the execution contract.
pub struct ExecPool {
    /// None = serial pool: no threads, `run` loops inline on the caller.
    shared: Option<Arc<Shared>>,
    lanes: usize,
    handles: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for ExecPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecPool").field("lanes", &self.lanes).finish()
    }
}

/// Default lane count for `--workers 0`/unset: what the OS reports as
/// available parallelism (1 when unknown).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1)
}

impl ExecPool {
    /// A pool with `workers` total compute lanes: the calling thread plus
    /// `workers - 1` spawned threads. `workers <= 1` builds the serial
    /// pool (no threads at all).
    pub fn new(workers: usize) -> ExecPool {
        let lanes = workers.max(1);
        if lanes == 1 {
            return ExecPool::serial();
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                active: 0,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
            cursor: AtomicUsize::new(0),
            remaining: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            lane_items: (0..lanes).map(|_| AtomicUsize::new(0)).collect(),
        });
        let handles = (1..lanes)
            .map(|lane| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("agd-exec-{lane}"))
                    .spawn(move || worker_main(&shared, lane))
                    .expect("spawn exec worker")
            })
            .collect();
        ExecPool {
            shared: Some(shared),
            lanes,
            handles,
        }
    }

    /// The no-thread pool: `run` executes inline on the caller (lane 0).
    pub fn serial() -> ExecPool {
        ExecPool {
            shared: None,
            lanes: 1,
            handles: Vec::new(),
        }
    }

    /// Total compute lanes (1 for the serial pool).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Run `f(lane, i)` exactly once for every `i in 0..n`, in parallel
    /// across the lanes, and return once all items are done and the
    /// workers have quiesced. `lane` is in `0..lanes()` and distinct per
    /// concurrently-running invocation — callers key per-lane scratch off
    /// it. Panics (after quiescing) if `f` panicked on any lane.
    pub fn run<F: Fn(usize, usize) + Sync>(&self, n: usize, f: F) -> RunStats {
        let lanes = self.lanes;
        let serial = |count: usize| {
            for i in 0..count {
                f(0, i);
            }
            // a deliberately-serial region reports itself as one lane so
            // the occupancy/efficiency gauges read 1.0, not 1/pool-size
            RunStats {
                lanes: 1,
                active_lanes: usize::from(count > 0),
                max_lane_items: count,
                items: count,
            }
        };
        let Some(shared) = &self.shared else {
            return serial(n);
        };
        if n <= 1 {
            // dispatch latency would dwarf a single item's work
            return serial(n);
        }

        // publish: counters reset *before* the epoch bump, all under the
        // state lock, so a waking worker always pairs the new epoch with
        // the new job/counters
        {
            let mut st = shared.state.lock().expect("exec pool state");
            shared.cursor.store(0, Ordering::SeqCst);
            shared.remaining.store(n, Ordering::SeqCst);
            for li in &shared.lane_items {
                li.store(0, Ordering::SeqCst);
            }
            st.job = Some(Job {
                data: &f as *const F as *const (),
                call: call_thunk::<F>,
                n,
            });
            st.epoch = st.epoch.wrapping_add(1);
            shared.go.notify_all();
        }

        // the caller is lane 0
        claim_loop(shared, 0, n, &f);

        // quiesce: all items done AND no worker still inside the region —
        // only then is it sound to let `f` (stack-borrowed) die
        {
            let mut st = shared.state.lock().expect("exec pool state");
            while shared.remaining.load(Ordering::SeqCst) != 0 || st.active != 0 {
                st = shared.done.wait(st).expect("exec pool done wait");
            }
            st.job = None;
        }
        if shared.panicked.swap(false, Ordering::SeqCst) {
            panic!("ExecPool::run: a parallel region panicked on a worker lane");
        }

        let mut active_lanes = 0usize;
        let mut max_lane = 0usize;
        for li in &shared.lane_items {
            let c = li.load(Ordering::SeqCst);
            if c > 0 {
                active_lanes += 1;
            }
            max_lane = max_lane.max(c);
        }
        RunStats {
            lanes,
            active_lanes,
            max_lane_items: max_lane,
            items: n,
        }
    }
}

/// Claim items off the shared cursor until the region is exhausted.
/// Panics in `call` are caught and recorded so `remaining` always reaches
/// zero — a panicking item must never deadlock the pool.
fn claim_loop(shared: &Shared, lane: usize, n: usize, call: impl Fn(usize, usize)) {
    loop {
        let i = shared.cursor.fetch_add(1, Ordering::SeqCst);
        if i >= n {
            return;
        }
        if catch_unwind(AssertUnwindSafe(|| call(lane, i))).is_err() {
            shared.panicked.store(true, Ordering::SeqCst);
        }
        shared.lane_items[lane].fetch_add(1, Ordering::SeqCst);
        if shared.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            // last item: wake the caller under the lock so the wakeup
            // cannot race its predicate check
            let _st = shared.state.lock().expect("exec pool state");
            shared.done.notify_all();
        }
    }
}

fn worker_main(shared: &Shared, lane: usize) {
    let mut seen_epoch = 0u64;
    loop {
        // wait for a new region (or shutdown), entering it under the lock
        let job = {
            let mut st = shared.state.lock().expect("exec pool state");
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    seen_epoch = st.epoch;
                    if let Some(job) = st.job {
                        st.active += 1;
                        break job;
                    }
                    // region already finished before we woke: resync only
                }
                st = shared.go.wait(st).expect("exec pool go wait");
            }
        };
        claim_loop(shared, lane, job.n, |lane, i| unsafe {
            (job.call)(job.data, lane, i)
        });
        let mut st = shared.state.lock().expect("exec pool state");
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            let mut st = shared.state.lock().expect("exec pool state");
            st.shutdown = true;
            shared.go.notify_all();
            drop(st);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ExecPool::serial();
        assert_eq!(pool.lanes(), 1);
        let mut out = vec![0usize; 8];
        {
            let cell = crate::exec::shard::SliceShards::new(&mut out);
            let stats = pool.run(8, |lane, i| {
                assert_eq!(lane, 0);
                // Safety: each index visited exactly once
                *unsafe { cell.slot(i) } = i * 3;
            });
            assert_eq!(stats.items, 8);
            assert_eq!(stats.lanes, 1);
            assert_eq!(stats.active_lanes, 1);
        }
        assert_eq!(out, vec![0, 3, 6, 9, 12, 15, 18, 21]);
    }

    #[test]
    fn parallel_pool_visits_every_index_exactly_once() {
        let pool = ExecPool::new(4);
        assert_eq!(pool.lanes(), 4);
        // run many regions back-to-back to shake out publish/quiesce races
        for round in 0..200usize {
            let n = 1 + (round % 37);
            let visits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let stats = pool.run(n, |lane, i| {
                assert!(lane < 4);
                visits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(stats.items, n);
            for (i, v) in visits.iter().enumerate() {
                assert_eq!(v.load(Ordering::SeqCst), 1, "round {round} index {i}");
            }
        }
    }

    #[test]
    fn results_are_identical_for_any_lane_count() {
        let work = |pool: &ExecPool, n: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; n];
            let rows = crate::exec::shard::SliceShards::new(&mut out);
            pool.run(n, |_lane, i| {
                // per-index math independent of lane/order
                let mut acc = 0.0f32;
                for k in 0..64 {
                    acc += ((i * 31 + k) as f32).sin();
                }
                *unsafe { rows.slot(i) } = acc;
            });
            out
        };
        let serial = work(&ExecPool::serial(), 33);
        for lanes in [2, 3, 4, 8] {
            let pool = ExecPool::new(lanes);
            assert_eq!(work(&pool, 33), serial, "lanes {lanes}");
        }
    }

    #[test]
    fn stats_report_load_spread() {
        let pool = ExecPool::new(2);
        let stats = pool.run(64, |_lane, _i| {
            std::hint::black_box((0..500).sum::<u64>());
        });
        assert_eq!(stats.items, 64);
        assert!(stats.active_lanes >= 1 && stats.active_lanes <= 2);
        assert!(stats.max_lane_items <= 64);
        assert!(stats.occupancy() > 0.0 && stats.occupancy() <= 1.0);
        assert!(stats.efficiency() > 0.0 && stats.efficiency() <= 1.0);
        // empty regions are free and report cleanly
        let empty = pool.run(0, |_, _| unreachable!("no items"));
        assert_eq!(empty.items, 0);
        assert_eq!(empty.efficiency(), 1.0);
    }

    #[test]
    fn worker_panic_propagates_after_quiescing() {
        let pool = ExecPool::new(3);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, |_lane, i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic in a region must propagate to the caller");
        // the pool survives a panicked region
        let ok = pool.run(8, |_lane, _i| {});
        assert_eq!(ok.items, 8);
    }

    #[test]
    fn zero_worker_request_degrades_to_serial() {
        let pool = ExecPool::new(0);
        assert_eq!(pool.lanes(), 1);
        assert_eq!(pool.run(3, |_, _| {}).items, 3);
        assert!(default_workers() >= 1);
    }
}
