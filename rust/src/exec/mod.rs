//! Multi-core execution layer (§Perf: parallel execution).
//!
//! The engine's two hot loops are embarrassingly parallel *across* rows
//! and slots: every packed batch row of a
//! [`denoise_into`](crate::Backend::denoise_into) call is an independent
//! evaluation, and every completed step's combine+gamma+solver update
//! touches only its own request's buffers. This module supplies the
//! dependency-free machinery that shards them over the machine's cores:
//!
//! * [`ExecPool`] ([`pool`]) — a persistent `std::thread` + `Condvar`
//!   worker pool, spawned once at engine construction
//!   (`agd serve --workers N`, default = available parallelism), with an
//!   allocation-free `run(n, f)` parallel-for.
//! * [`RowShards`] / [`SliceShards`] ([`shard`]) — disjoint-access views
//!   that let the region closure write its own output row / per-lane
//!   scratch without locks.
//!
//! # The sharding rule
//!
//! Parallelism is strictly *across* rows and slots — the float-op order
//! *within* a row/slot is exactly the serial code's — so completions are
//! bit-identical for every `--workers` value (pinned by
//! `rust/tests/sched_integration.rs`). Anything that is not a pure
//! per-row computation (scheduler pops, [`BufPool`](crate::BufPool)
//! take/put, telemetry, PJRT execution) stays on the engine thread; see
//! `coordinator::engine`'s "§Perf: buffer ownership & parallel
//! execution" notes.
//!
//! # The not-`Send` boundary
//!
//! The PJRT client wraps thread-affine host state, so
//! [`PjrtBackend`](crate::runtime::PjrtBackend) never runs on a worker:
//! it keeps the default serial `denoise_into_par` (which just calls its
//! single-threaded `denoise_into`) and executes on the engine thread.
//! Only host-math backends (the GMM oracle) and the engine's own
//! post-eval phase shard onto the pool.

pub mod pool;
pub mod shard;

pub use pool::{default_workers, ExecPool, RunStats};
pub use shard::{RowShards, SliceShards};
