//! Disjoint-access views for parallel regions.
//!
//! [`ExecPool::run`](crate::exec::ExecPool::run) invokes the region
//! closure through a shared reference from several threads at once, so
//! anything the closure must *mutate* needs a view that hands each index
//! its own disjoint piece. Two shapes cover the engine:
//!
//! * [`RowShards`] — a packed row-major `rows × stride` `f32` buffer
//!   (a [`BatchOut`](crate::backend::BatchOut)'s data); index `i` owns
//!   row `i`.
//! * [`SliceShards`] — any `&mut [T]`; index `i` owns element `i` (used
//!   for per-lane scratch tables and per-ready-slot state).
//!
//! # Safety contract
//!
//! Both types hand out `&mut` aliases through `&self`, which is sound
//! only under the pool's execution contract: **every index is claimed by
//! exactly one lane per region**, so no two live `&mut`s ever point at
//! the same row/slot. The unsafe accessors are `unsafe fn`s to keep that
//! obligation visible at every call site; callers must only pass indices
//! they received from the pool (or otherwise own exclusively), and must
//! not hold a returned reference across items. `T: Send` (and `f32` rows)
//! is required because the references cross threads.

use std::marker::PhantomData;

/// Disjoint mutable rows of a packed row-major `f32` buffer.
pub struct RowShards<'a> {
    ptr: *mut f32,
    stride: usize,
    rows: usize,
    _borrow: PhantomData<&'a mut [f32]>,
}

// Safety: see the module docs — each row is accessed by exactly one lane,
// and f32 is Send.
unsafe impl Sync for RowShards<'_> {}
unsafe impl Send for RowShards<'_> {}

impl<'a> RowShards<'a> {
    /// View `data` (length `rows * stride`) as `rows` disjoint rows.
    pub fn new(data: &'a mut [f32], stride: usize) -> RowShards<'a> {
        assert!(stride > 0, "RowShards needs a positive stride");
        assert_eq!(data.len() % stride, 0, "buffer is not a whole number of rows");
        RowShards {
            ptr: data.as_mut_ptr(),
            stride,
            rows: data.len() / stride,
            _borrow: PhantomData,
        }
    }

    /// Number of rows in the view.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mutable row `i`.
    ///
    /// # Safety
    /// `i` must be in range and claimed by exactly one lane for the
    /// duration of the region (the pool's exactly-once contract); the
    /// returned slice must not outlive the item's processing.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row(&self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows, "row index out of range");
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.stride), self.stride)
    }
}

/// Disjoint mutable elements of a slice: index `i` owns `slice[i]`.
pub struct SliceShards<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: PhantomData<&'a mut [T]>,
}

// Safety: see the module docs — disjoint per-index access, `T: Send`
// because the `&mut T` handed out crosses threads.
unsafe impl<T: Send> Sync for SliceShards<'_, T> {}
unsafe impl<T: Send> Send for SliceShards<'_, T> {}

impl<'a, T> SliceShards<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SliceShards<'a, T> {
        SliceShards {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _borrow: PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable element `i`.
    ///
    /// # Safety
    /// Same contract as [`RowShards::row`]: exactly one lane touches `i`
    /// per region, and the reference does not outlive the item.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slot(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len, "slot index out of range");
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_shards_split_a_packed_buffer() {
        let mut buf = vec![0.0f32; 12];
        {
            let rows = RowShards::new(&mut buf, 4);
            assert_eq!(rows.rows(), 3);
            for i in 0..3 {
                // Safety: unit test visits each row once
                let r = unsafe { rows.row(i) };
                assert_eq!(r.len(), 4);
                r.fill(i as f32 + 1.0);
            }
        }
        assert_eq!(
            buf,
            vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0, 3.0, 3.0, 3.0, 3.0]
        );
    }

    #[test]
    #[should_panic(expected = "whole number of rows")]
    fn ragged_buffer_is_rejected() {
        let mut buf = vec![0.0f32; 10];
        RowShards::new(&mut buf, 4);
    }

    #[test]
    fn slice_shards_split_elements() {
        let mut v = vec![0usize; 5];
        {
            let slots = SliceShards::new(&mut v);
            assert_eq!(slots.len(), 5);
            assert!(!slots.is_empty());
            for i in 0..5 {
                // Safety: unit test visits each slot once
                *unsafe { slots.slot(i) } = i * i;
            }
        }
        assert_eq!(v, vec![0, 1, 4, 9, 16]);
    }
}
