//! The prompt vocabulary — Rust mirror of `python/compile/data.py`.
//!
//! Prompts are compositional: (shape, color, position, size) with a 4-slot
//! token encoding where 0 is the null token of each slot. The OUI-substitute
//! evaluation sets are deterministic samples from this space; negative
//! prompts are partial token vectors (e.g. "not red" → only the color slot
//! set). The vocab lists are also exported in `manifest.json` and checked at
//! backend load so the two sides cannot drift.

use crate::util::rng::Rng;

pub const SHAPES: [&str; 4] = ["circle", "square", "triangle", "cross"];
pub const COLORS: [&str; 5] = ["red", "green", "blue", "yellow", "white"];
pub const POSITIONS: [&str; 5] = [
    "center",
    "top-left",
    "top-right",
    "bottom-left",
    "bottom-right",
];
pub const SIZES: [&str; 2] = ["small", "large"];
pub const NUM_SLOTS: usize = 4;

/// A fully-specified prompt (0-based attribute indices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Prompt {
    pub shape: usize,
    pub color: usize,
    pub position: usize,
    pub size: usize,
}

impl Prompt {
    /// 1-based token encoding (0 reserved for null in every slot).
    pub fn tokens(&self) -> Vec<i32> {
        vec![
            self.shape as i32 + 1,
            self.color as i32 + 1,
            self.position as i32 + 1,
            self.size as i32 + 1,
        ]
    }

    pub fn text(&self) -> String {
        format!(
            "a {} {} {} at the {}",
            SIZES[self.size], COLORS[self.color], SHAPES[self.shape],
            POSITIONS[self.position]
        )
    }

    /// Total prompt space size (4 * 5 * 5 * 2 = 200).
    pub fn space_size() -> usize {
        SHAPES.len() * COLORS.len() * POSITIONS.len() * SIZES.len()
    }

    /// The i-th prompt in the canonical enumeration (itertools.product
    /// order, matching python's ALL_PROMPTS).
    pub fn nth(i: usize) -> Prompt {
        assert!(i < Self::space_size());
        let per_shape = COLORS.len() * POSITIONS.len() * SIZES.len();
        let per_color = POSITIONS.len() * SIZES.len();
        Prompt {
            shape: i / per_shape,
            color: (i % per_shape) / per_color,
            position: (i % per_color) / SIZES.len(),
            size: i % SIZES.len(),
        }
    }

    /// Parse "a large red circle at the top-left" (the `text()` format) or a
    /// compact "red circle" subset (missing attributes default to 0).
    pub fn parse(text: &str) -> Option<Prompt> {
        let mut p = Prompt {
            shape: 0,
            color: 0,
            position: 0,
            size: 0,
        };
        let lower = text.to_lowercase();
        for tok in lower
            .split(|c: char| c.is_whitespace() || c == ',')
            .filter(|t| !t.is_empty())
        {
            if let Some(i) = SHAPES.iter().position(|s| *s == tok) {
                p.shape = i;
            } else if let Some(i) = COLORS.iter().position(|s| *s == tok) {
                p.color = i;
            } else if let Some(i) = POSITIONS.iter().position(|s| *s == tok) {
                p.position = i;
            } else if let Some(i) = SIZES.iter().position(|s| *s == tok) {
                p.size = i;
            }
        }
        Some(p)
    }
}

/// A negative prompt: suppress one attribute value (e.g. a color).
/// Token encoding sets only that slot, mirroring data.py's instruction style.
pub fn negative_tokens(slot: usize, value_1based: i32) -> Vec<i32> {
    assert!(slot < NUM_SLOTS);
    let mut t = vec![0i32; NUM_SLOTS];
    t[slot] = value_1based;
    t
}

/// Deterministic OUI-substitute evaluation set: `n` prompts sampled without
/// replacement cycling through the space, shuffled by `seed`.
pub fn eval_set(n: usize, seed: u64) -> Vec<Prompt> {
    let mut order: Vec<usize> = (0..Prompt::space_size()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut order);
    (0..n).map(|i| Prompt::nth(order[i % order.len()])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_size_is_200() {
        assert_eq!(Prompt::space_size(), 200);
    }

    #[test]
    fn nth_enumerates_all_distinct() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..Prompt::space_size() {
            let p = Prompt::nth(i);
            assert!(seen.insert(p.tokens()));
        }
    }

    #[test]
    fn tokens_are_one_based() {
        let p = Prompt::nth(0);
        assert_eq!(p.tokens(), vec![1, 1, 1, 1]);
        let last = Prompt::nth(199);
        assert_eq!(last.tokens(), vec![4, 5, 5, 2]);
    }

    #[test]
    fn text_and_parse_roundtrip() {
        for i in (0..200).step_by(17) {
            let p = Prompt::nth(i);
            let q = Prompt::parse(&p.text()).unwrap();
            assert_eq!(p, q, "{}", p.text());
        }
    }

    #[test]
    fn negative_tokens_single_slot() {
        let t = negative_tokens(1, 3); // "not blue"
        assert_eq!(t, vec![0, 3, 0, 0]);
    }

    #[test]
    fn eval_set_deterministic_and_covering() {
        let a = eval_set(50, 7);
        let b = eval_set(50, 7);
        assert_eq!(a, b);
        let c = eval_set(50, 8);
        assert_ne!(a, c);
        // first 200 draws cover the whole space exactly once
        let full = eval_set(200, 7);
        let uniq: std::collections::HashSet<_> =
            full.iter().map(|p| p.tokens()).collect();
        assert_eq!(uniq.len(), 200);
    }
}
