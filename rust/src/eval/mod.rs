//! Evaluation harnesses shared by the figure/table benches.

pub mod annotators;
pub mod harness;
pub mod probe;
pub mod scene_org;
