//! Attribute probes: verify semantics of generated images without a learned
//! classifier. Used by the negative-prompt experiment (Fig. 7/11): a
//! negative color prompt must *suppress* that color in the output, and AG
//! must match CFG's suppression.

/// Mean RGB over the brightest region (the rendered shape) of an image in
/// [-1, 1]. The shape is found as the pixels in the top 40% of the image's
/// luma *range* — robust to the shape occupying only a few percent of the
/// pixels (a percentile threshold collapses onto the background there).
pub fn shape_color(img: &[f32], width: usize, height: usize) -> [f64; 3] {
    let luma: Vec<f32> = crate::quality::luma(img);
    let lo = luma.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let hi = luma.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let thresh = lo + 0.6 * (hi - lo);
    let mut acc = [0.0f64; 3];
    let mut n = 0usize;
    for i in 0..width * height {
        if luma[i] as f64 >= thresh {
            for c in 0..3 {
                acc[c] += img[i * 3 + c] as f64;
            }
            n += 1;
        }
    }
    if n > 0 {
        for a in &mut acc {
            *a /= n as f64;
        }
    }
    acc
}

/// Strength of color channel `channel` relative to the others in the shape
/// region; higher = more of that color.
pub fn color_dominance(img: &[f32], width: usize, height: usize, channel: usize) -> f64 {
    let c = shape_color(img, width, height);
    let others: f64 = (0..3).filter(|&i| i != channel).map(|i| c[i]).sum::<f64>() / 2.0;
    c[channel] - others
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solid_shape(rgb: [f32; 3]) -> Vec<f32> {
        // dark background with a bright 6x6 square of the given color
        let mut img = vec![-0.8f32; 16 * 16 * 3];
        for y in 5..11 {
            for x in 5..11 {
                for c in 0..3 {
                    img[(y * 16 + x) * 3 + c] = rgb[c];
                }
            }
        }
        img
    }

    #[test]
    fn detects_red_shape() {
        let img = solid_shape([0.9, -0.5, -0.5]);
        let c = shape_color(&img, 16, 16);
        assert!(c[0] > 0.5, "{c:?}");
        assert!(c[1] < 0.0 && c[2] < 0.0, "{c:?}");
        assert!(color_dominance(&img, 16, 16, 0) > 1.0);
    }

    #[test]
    fn dominance_is_comparative() {
        let red = solid_shape([0.9, -0.5, -0.5]);
        let green = solid_shape([-0.5, 0.9, -0.5]);
        assert!(color_dominance(&red, 16, 16, 0) > color_dominance(&green, 16, 16, 0));
        assert!(color_dominance(&green, 16, 16, 1) > color_dominance(&red, 16, 16, 1));
    }

    #[test]
    fn white_shape_has_no_dominant_channel() {
        let img = solid_shape([0.9, 0.9, 0.9]);
        for c in 0..3 {
            assert!(color_dominance(&img, 16, 16, c).abs() < 0.1);
        }
    }
}
