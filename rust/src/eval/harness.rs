//! Shared experiment harness for the figure/table benches: run a policy
//! over an evaluation set with paper-protocol seeding (same seed sequence
//! for every policy), and aggregate quality/NFE/latency.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::engine::Engine;
use crate::coordinator::policy::PolicyRef;
use crate::coordinator::request::{Completion, Request};
use crate::prompts::Prompt;
use crate::quality::ssim::ssim_rgb;
use crate::stats;

/// One policy evaluated over the full prompt set.
#[derive(Debug)]
pub struct PolicyRun {
    pub name: String,
    pub completions: Vec<Completion>,
    pub wall: Duration,
    pub mean_occupancy: f64,
}

impl PolicyRun {
    pub fn total_nfes(&self) -> usize {
        self.completions.iter().map(|c| c.nfes).sum()
    }

    pub fn mean_nfes(&self) -> f64 {
        self.total_nfes() as f64 / self.completions.len() as f64
    }

    pub fn nfe_std(&self) -> f64 {
        let v: Vec<f64> = self.completions.iter().map(|c| c.nfes as f64).collect();
        stats::std_dev(&v)
    }

    pub fn images(&self) -> Vec<&[f32]> {
        self.completions.iter().map(|c| c.image.as_slice()).collect()
    }
}

/// Evaluation-run options.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub model: String,
    pub steps: usize,
    pub seed_base: u64,
    pub record_trajectory: bool,
    pub record_iterates: bool,
    pub neg_tokens: Option<Vec<i32>>,
}

impl RunSpec {
    pub fn new(model: &str, steps: usize) -> RunSpec {
        RunSpec {
            model: model.to_owned(),
            steps,
            seed_base: 1000,
            record_trajectory: false,
            record_iterates: false,
            neg_tokens: None,
        }
    }
}

/// Run one policy over the prompt set. Request i uses seed `seed_base + i`
/// regardless of policy — the paper's "same seed sequence for both models".
pub fn run_policy<B: Backend>(
    engine: &mut Engine<B>,
    prompts: &[Prompt],
    spec: &RunSpec,
    policy: PolicyRef,
) -> Result<PolicyRun> {
    let batches_before = engine.batches();
    let items_before = engine.items();
    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut r = Request::new(
                i as u64,
                &spec.model,
                p.tokens(),
                spec.seed_base + i as u64,
                spec.steps,
                policy.clone(),
            );
            r.record_trajectory = spec.record_trajectory;
            r.record_iterates = spec.record_iterates;
            r.neg_tokens = spec.neg_tokens.clone();
            r
        })
        .collect();
    let started = Instant::now();
    let completions = engine.run(reqs)?;
    let wall = started.elapsed();
    let batches = engine.batches() - batches_before;
    let items = engine.items() - items_before;
    Ok(PolicyRun {
        name: policy.name(),
        completions,
        wall,
        mean_occupancy: if batches == 0 {
            0.0
        } else {
            items as f64 / batches as f64
        },
    })
}

/// Pairwise SSIM of two runs (same prompt order), 16x16 RGB latents.
pub fn ssim_series(a: &PolicyRun, b: &PolicyRun, img: usize) -> Vec<f64> {
    a.completions
        .iter()
        .zip(&b.completions)
        .map(|(x, y)| ssim_rgb(&x.image, &y.image, img, img))
        .collect()
}

/// mean ± std of a series.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    (stats::mean(xs), stats::std_dev(xs))
}

/// Print an aligned table: `widths` derived from headers.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: Vec<String>| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", line(headers.iter().map(|h| h.to_string()).collect()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    for row in rows {
        println!("{}", line(row.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::sim::gmm::Gmm;

    #[test]
    fn run_policy_uses_shared_seeds() {
        use crate::coordinator::policy::{ag, cfg};
        let ps = crate::prompts::eval_set(4, 0);
        let spec = RunSpec::new("gmm", 8);
        let mut e1 = Engine::new(GmmBackend::new(Gmm::axes(8, 6, 3.0, 0.05))).unwrap();
        let a = run_policy(&mut e1, &ps, &spec, cfg(2.0)).unwrap();
        let mut e2 = Engine::new(GmmBackend::new(Gmm::axes(8, 6, 3.0, 0.05))).unwrap();
        let b = run_policy(&mut e2, &ps, &spec, ag(2.0, 2.0)).unwrap();
        // unreachable threshold → identical trajectories per prompt
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.image, y.image);
        }
        assert!(a.mean_nfes() >= b.mean_nfes() - 1e-9);
    }

    #[test]
    fn table_printer_does_not_panic() {
        print_table(
            &["policy", "NFEs"],
            &[vec!["cfg".into(), "40".into()], vec!["ag".into(), "29.6".into()]],
        );
    }
}
