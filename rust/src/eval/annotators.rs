//! Simulated human-evaluation panel (Table 1 / Figs. 6, 10, 12, 13).
//!
//! The paper's protocol: 5 trained annotators per prompt pair vote for the
//! more visually appealing image (no tie option); majority voting + a
//! two-sided Wilcoxon signed-rank test on the vote differences.
//!
//! Humans are unavailable here (DESIGN.md §3), so each annotator is a noisy
//! binary judge whose preference is a logistic readout of a perceptual
//! quality-difference proxy. The proxy follows the paper's own observation
//! (Fig. 6): the images are near-identical, and residual preference is
//! driven by *high-frequency* detail differences whose benefit has random
//! sign per pair — "higher frequencies, which can be for better or worse".

use crate::quality::high_freq_energy;
use crate::stats::wilcoxon::{signed_rank, WilcoxonResult};
use crate::util::rng::Rng;

pub const PANEL: usize = 5;

/// Result of one pairwise comparison by the panel.
#[derive(Debug, Clone, Copy)]
pub struct PairVote {
    /// votes for image A (0..=5); votes for B = PANEL - votes_a
    pub votes_a: usize,
    /// votes_a - votes_b ∈ {-5, -3, -1, 1, 3, 5}
    pub diff: i32,
}

/// Panel configuration.
#[derive(Debug, Clone)]
pub struct Panel {
    /// how strongly the quality proxy drives preference (logistic slope)
    pub sensitivity: f64,
    /// per-annotator noise scale
    pub noise: f64,
}

impl Default for Panel {
    fn default() -> Panel {
        Panel {
            sensitivity: 6.0,
            noise: 1.0,
        }
    }
}

impl Panel {
    /// Judge one pair of RGB images in [-1, 1].
    ///
    /// The perceived quality difference combines (i) the high-frequency
    /// energy difference with a per-pair random sign of benefit and (ii)
    /// per-annotator logistic noise.
    pub fn judge_pair(
        &self,
        img_a: &[f32],
        img_b: &[f32],
        width: usize,
        height: usize,
        rng: &mut Rng,
    ) -> PairVote {
        let hf_a = high_freq_energy(img_a, width, height);
        let hf_b = high_freq_energy(img_b, width, height);
        // relative high-frequency difference, bounded
        let rel = ((hf_a - hf_b) / (hf_a + hf_b).max(1e-9)).clamp(-1.0, 1.0);
        // per-pair sign: extra detail helps some scenes, hurts others
        let benefit = if rng.uniform() < 0.5 { 1.0 } else { -1.0 };
        let q = self.sensitivity * rel * benefit;
        let mut votes_a = 0;
        for _ in 0..PANEL {
            let z = q + self.noise * rng.normal();
            let p_a = 1.0 / (1.0 + (-z).exp());
            if rng.uniform() < p_a {
                votes_a += 1;
            }
        }
        PairVote {
            votes_a,
            diff: 2 * votes_a as i32 - PANEL as i32,
        }
    }
}

/// Aggregate panel outcome over an evaluation set.
#[derive(Debug, Clone)]
pub struct PanelOutcome {
    pub wins_a: usize,
    pub wins_b: usize,
    pub diffs: Vec<f64>,
    pub wilcoxon: WilcoxonResult,
    pub mean_diff: f64,
    pub sd_diff: f64,
}

/// Run the full study: one pair per prompt, majority voting, Wilcoxon.
pub fn run_study(
    pairs: &[(Vec<f32>, Vec<f32>)],
    width: usize,
    height: usize,
    panel: &Panel,
    seed: u64,
) -> PanelOutcome {
    let mut rng = Rng::new(seed);
    let mut wins_a = 0;
    let mut wins_b = 0;
    let mut diffs = Vec::with_capacity(pairs.len());
    for (a, b) in pairs {
        let v = panel.judge_pair(a, b, width, height, &mut rng);
        if v.diff > 0 {
            wins_a += 1;
        } else {
            wins_b += 1;
        }
        diffs.push(v.diff as f64);
    }
    let wilcoxon = signed_rank(&diffs);
    PanelOutcome {
        wins_a,
        wins_b,
        mean_diff: crate::stats::mean(&diffs),
        sd_diff: crate::stats::std_dev(&diffs),
        wilcoxon,
        diffs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noisy_img(seed: u64, level: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..16 * 16 * 3)
            .map(|_| 0.2 + level * rng.normal() as f32)
            .collect()
    }

    #[test]
    fn votes_have_no_ties() {
        let panel = Panel::default();
        let mut rng = Rng::new(0);
        let a = noisy_img(1, 0.1);
        let b = noisy_img(2, 0.1);
        for _ in 0..50 {
            let v = panel.judge_pair(&a, &b, 16, 16, &mut rng);
            assert!(v.diff % 2 != 0, "diff must be odd: {}", v.diff);
            assert!(v.votes_a <= PANEL);
        }
    }

    #[test]
    fn identical_images_split_evenly() {
        let panel = Panel::default();
        let a = noisy_img(3, 0.1);
        let pairs: Vec<_> = (0..400).map(|_| (a.clone(), a.clone())).collect();
        let out = run_study(&pairs, 16, 16, &panel, 7);
        // identical inputs → pure coin-flip panel → near-even split, p > 0.05
        let frac = out.wins_a as f64 / pairs.len() as f64;
        assert!((frac - 0.5).abs() < 0.08, "frac={frac}");
        assert!(out.wilcoxon.p_value > 0.05, "p={}", out.wilcoxon.p_value);
    }

    #[test]
    fn random_benefit_sign_keeps_sharper_images_at_parity() {
        // A consistently sharper than B, but benefit sign is random per pair
        // → still ~50/50 overall (the paper's draw outcome).
        let panel = Panel::default();
        let pairs: Vec<_> = (0..400)
            .map(|i| (noisy_img(i, 0.5), noisy_img(1000 + i, 0.1)))
            .collect();
        let out = run_study(&pairs, 16, 16, &panel, 11);
        let frac = out.wins_a as f64 / pairs.len() as f64;
        assert!((frac - 0.5).abs() < 0.1, "frac={frac}");
    }

    #[test]
    fn diff_distribution_is_bounded() {
        let panel = Panel::default();
        let pairs: Vec<_> = (0..100)
            .map(|i| (noisy_img(i, 0.3), noisy_img(i + 500, 0.3)))
            .collect();
        let out = run_study(&pairs, 16, 16, &panel, 3);
        assert!(out.diffs.iter().all(|d| d.abs() <= 5.0));
        assert_eq!(out.wins_a + out.wins_b, 100);
    }
}
