//! Fig. 17 analysis: "the denoising process displays scene organization
//! even in early iterations".
//!
//! The paper decodes the per-step iterates and shows that point-wise
//! *differences* between consecutive decoded iterates reveal scene structure
//! long before the iterates themselves look like anything. The numeric
//! version here: per step, the magnitude of the iterate delta and the
//! Pearson correlation of (a) the iterate and (b) the delta with the *final*
//! image. High delta-correlation at early steps = early scene organization.

/// Pearson correlation of two equal-length buffers.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        let dx = x as f64 - ma;
        let dy = y as f64 - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-12)
}

/// Per-step row of the Fig. 17 report.
#[derive(Debug, Clone, Copy)]
pub struct SceneOrgRow {
    pub step: usize,
    /// RMS of the delta between consecutive x0 iterates
    pub delta_rms: f64,
    /// correlation of the raw iterate with the final image
    pub iterate_corr: f64,
    /// correlation of the delta with the final image
    pub delta_corr: f64,
}

/// Analyze a sequence of per-step data predictions (x0 iterates).
pub fn analyze(iterates: &[Vec<f32>]) -> Vec<SceneOrgRow> {
    assert!(iterates.len() >= 2);
    let fin = iterates.last().unwrap();
    let mut rows = Vec::new();
    for step in 1..iterates.len() {
        let prev = &iterates[step - 1];
        let cur = &iterates[step];
        let delta: Vec<f32> = cur.iter().zip(prev).map(|(&a, &b)| a - b).collect();
        let rms = (delta.iter().map(|&d| (d as f64).powi(2)).sum::<f64>()
            / delta.len() as f64)
            .sqrt();
        rows.push(SceneOrgRow {
            step,
            delta_rms: rms,
            iterate_corr: pearson(cur, fin),
            delta_corr: pearson(&delta, fin),
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pearson_identities() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b: Vec<f32> = a.iter().map(|v| 2.0 * v + 1.0).collect();
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c: Vec<f32> = a.iter().map(|v| -v).collect();
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn converging_iterates_show_structure() {
        // synthetic diffusion toward a target: x0_k = target + noise/k.
        let mut rng = Rng::new(0);
        let target: Vec<f32> = rng.normal_vec(256);
        let iterates: Vec<Vec<f32>> = (1..=10)
            .map(|k| {
                let mut rk = Rng::new(k as u64);
                target
                    .iter()
                    .map(|&t| t + rk.normal() as f32 / k as f32)
                    .collect()
            })
            .collect();
        let rows = analyze(&iterates);
        // iterate correlation with final image must increase over time
        assert!(rows.last().unwrap().iterate_corr > rows[0].iterate_corr);
        // all deltas point toward structure (positive correlation impossible
        // to guarantee per-step, but late deltas shrink)
        assert!(rows.last().unwrap().delta_rms < rows[0].delta_rms);
    }

    #[test]
    fn rows_cover_all_transitions() {
        let iterates: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 8]).collect();
        let rows = analyze(&iterates);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].step, 1);
        assert!((rows[0].delta_rms - 1.0).abs() < 1e-9);
    }
}
