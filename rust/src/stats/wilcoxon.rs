//! Two-sided Wilcoxon signed-rank test with tie handling — the statistical
//! test the paper's human evaluation reports (Table 1: "two-sided Wilcoxon
//! Signed-Rank Test, p = 0.603").
//!
//! Uses the normal approximation with tie- and zero-corrections, which is the
//! standard procedure for n ≳ 20 (the paper's n is 1000 prompts).

use super::normal_cdf;

/// Result of the test.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WilcoxonResult {
    /// W+ — sum of ranks of positive differences (the reported statistic).
    pub w_plus: f64,
    /// W- — sum of ranks of negative differences.
    pub w_minus: f64,
    /// Number of non-zero differences actually ranked.
    pub n_used: usize,
    /// Two-sided p-value (normal approximation, continuity-corrected).
    pub p_value: f64,
    /// z statistic.
    pub z: f64,
}

/// Paired test: `diffs[i] = a[i] - b[i]`. Zero differences are dropped
/// (Wilcoxon's original procedure); ties among |diffs| get average ranks.
pub fn signed_rank(diffs: &[f64]) -> WilcoxonResult {
    // (|d|, sign)
    let mut items: Vec<(f64, f64)> = diffs
        .iter()
        .filter(|d| **d != 0.0)
        .map(|&d| (d.abs(), d.signum()))
        .collect();
    let n = items.len();
    if n == 0 {
        return WilcoxonResult {
            w_plus: 0.0,
            w_minus: 0.0,
            n_used: 0,
            p_value: 1.0,
            z: 0.0,
        };
    }
    items.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    // average ranks for tied |d|; accumulate tie correction term Σ(t³ - t)
    let mut w_plus = 0.0;
    let mut tie_correction = 0.0;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j < n && items[j].0 == items[i].0 {
            j += 1;
        }
        let t = (j - i) as f64;
        // ranks are 1-based: positions i..j → average rank
        let avg_rank = (i + 1 + j) as f64 / 2.0;
        for item in &items[i..j] {
            if item.1 > 0.0 {
                w_plus += avg_rank;
            }
        }
        if t > 1.0 {
            tie_correction += t * t * t - t;
        }
        i = j;
    }
    let nf = n as f64;
    let total = nf * (nf + 1.0) / 2.0;
    let w_minus = total - w_plus;

    let mean = total / 2.0;
    let var = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 24.0 - tie_correction / 48.0;
    let w = w_plus.min(w_minus);
    // continuity correction: 0.5 toward the mean
    let z = if var > 0.0 {
        (w - mean + 0.5) / var.sqrt()
    } else {
        0.0
    };
    let p = (2.0 * normal_cdf(z)).min(1.0);
    WilcoxonResult {
        w_plus,
        w_minus,
        n_used: n,
        p_value: p,
        z,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_differences_not_significant() {
        // perfectly symmetric → W+ == W-, p == 1-ish
        let diffs: Vec<f64> = (1..=20).flat_map(|i| [i as f64, -(i as f64)]).collect();
        let r = signed_rank(&diffs);
        assert_eq!(r.w_plus, r.w_minus);
        assert!(r.p_value > 0.9, "p={}", r.p_value);
    }

    #[test]
    fn strongly_one_sided_is_significant() {
        let diffs: Vec<f64> = (1..=30).map(|i| i as f64).collect();
        let r = signed_rank(&diffs);
        assert_eq!(r.w_minus, 0.0);
        assert!(r.p_value < 1e-4, "p={}", r.p_value);
    }

    #[test]
    fn zeros_are_dropped() {
        let diffs = [0.0, 0.0, 1.0, -1.0, 2.0, -2.0];
        let r = signed_rank(&diffs);
        assert_eq!(r.n_used, 4);
    }

    #[test]
    fn reference_example() {
        // classic worked example (Wilcoxon 1945-style):
        // diffs with known W+ = 40, W- = 5, n = 9
        let diffs = [-2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0, -3.0];
        let r = signed_rank(&diffs);
        // |d| sorted: 2,3,4,6,8,10,12,14,16 → ranks 1..9
        // negatives: |2|→rank1, |3|→rank2 → W- = 3
        assert_eq!(r.w_minus, 3.0);
        assert_eq!(r.w_plus, 42.0);
    }

    #[test]
    fn ties_get_average_ranks() {
        let diffs = [1.0, 1.0, -1.0, 2.0];
        let r = signed_rank(&diffs);
        // |d|: 1,1,1 (ranks avg 2.0) and 2 (rank 4)
        assert!((r.w_plus - (2.0 + 2.0 + 4.0)).abs() < 1e-12);
        assert!((r.w_minus - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_all_zero() {
        assert_eq!(signed_rank(&[]).p_value, 1.0);
        assert_eq!(signed_rank(&[0.0, 0.0]).n_used, 0);
    }

    #[test]
    fn near_even_votes_match_paper_regime() {
        // Simulate the paper's outcome: 1000 vote differences, symmetric-ish.
        let mut rng = crate::util::rng::Rng::new(42);
        let diffs: Vec<f64> = (0..1000)
            .map(|_| {
                // votes in {-5,-3,-1,1,3,5}: 5 annotators, no ties allowed
                let k = rng.below(6);
                [-5.0, -3.0, -1.0, 1.0, 3.0, 5.0][k]
            })
            .collect();
        let r = signed_rank(&diffs);
        assert!(r.p_value > 0.05, "symmetric votes must not be significant");
    }
}
