//! Fixed-bin histograms: latency distributions (metrics) and the annotator
//! vote-difference distribution (Fig. 10).

/// Histogram over uniform bins spanning [lo, hi); out-of-range samples clamp
/// into the edge bins so nothing is silently dropped.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(bins > 0 && hi > lo);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64)
            .floor()
            .clamp(0.0, (bins - 1) as f64) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// Fraction of mass in bin i.
    pub fn frac(&self, i: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.counts[i] as f64 / self.total as f64
        }
    }

    /// Render an ASCII bar chart (used by the figure benches to print the
    /// same series the paper plots).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width + max as usize - 1) / max as usize);
            out.push_str(&format!(
                "{:>8.2} | {:<width$} {}\n",
                self.bin_center(i),
                bar,
                c,
                width = width
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        h.add(0.5); // bin 0
        h.add(9.9); // bin 4
        h.add(-3.0); // clamps to bin 0
        h.add(42.0); // clamps to bin 4
        assert_eq!(h.counts, vec![2, 0, 0, 0, 2]);
        assert_eq!(h.total, 4);
    }

    #[test]
    fn centers() {
        let h = Histogram::new(0.0, 10.0, 5);
        assert_eq!(h.bin_center(0), 1.0);
        assert_eq!(h.bin_center(4), 9.0);
    }

    #[test]
    fn fractions_sum_to_one() {
        let mut h = Histogram::new(-1.0, 1.0, 8);
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..1000 {
            h.add(rng.range(-1.0, 1.0));
        }
        let sum: f64 = (0..8).map(|i| h.frac(i)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ascii_renders_every_bin() {
        let mut h = Histogram::new(0.0, 4.0, 4);
        h.add(0.5);
        h.add(1.5);
        let art = h.ascii(10);
        assert_eq!(art.lines().count(), 4);
    }
}
