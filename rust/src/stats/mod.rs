//! Statistics substrate: descriptive stats, confidence intervals, the
//! Wilcoxon signed-rank test (Table 1's significance test), and histograms
//! (Fig. 10's vote distribution).

pub mod hist;
pub mod wilcoxon;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Normal-approximation confidence interval for the mean: returns
/// `(lo, hi)` at the given z (1.96 → 95%, 2.576 → 99%).
pub fn mean_ci(xs: &[f64], z: f64) -> (f64, f64) {
    let m = mean(xs);
    if xs.len() < 2 {
        return (m, m);
    }
    let half = z * std_dev(xs) / (xs.len() as f64).sqrt();
    (m - half, m + half)
}

/// z-value for a 99% CI (Fig. 4 uses 99% bands).
pub const Z_99: f64 = 2.576;
/// z-value for a 95% CI.
pub const Z_95: f64 = 1.96;

/// Percentile via linear interpolation on a *sorted* slice; p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Convenience: percentile of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Standard normal CDF via the Abramowitz-Stegun erf approximation
/// (max abs error ~1.5e-7, ample for p-value reporting).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Simple percentile bootstrap for the mean: returns (lo, hi) of the
/// `level` (e.g. 0.95) interval with `iters` resamples.
pub fn bootstrap_mean_ci(
    xs: &[f64],
    level: f64,
    iters: usize,
    rng: &mut crate::util::rng::Rng,
) -> (f64, f64) {
    assert!(!xs.is_empty());
    let mut means = Vec::with_capacity(iters);
    for _ in 0..iters {
        let mut s = 0.0;
        for _ in 0..xs.len() {
            s += xs[rng.below(xs.len())];
        }
        means.push(s / xs.len() as f64);
    }
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = (1.0 - level) / 2.0;
    (
        percentile_sorted(&means, alpha * 100.0),
        percentile_sorted(&means, (1.0 - alpha) * 100.0),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // sample std of this classic set is ~2.138
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        let (alo, ahi) = mean_ci(&a, Z_95);
        let (blo, bhi) = mean_ci(&b, Z_95);
        assert!(bhi - blo < ahi - alo);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn normal_cdf_reference_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
        assert!((normal_cdf(3.0) - 0.99865).abs() < 1e-4);
    }

    #[test]
    fn bootstrap_contains_true_mean() {
        let mut rng = crate::util::rng::Rng::new(0);
        let xs: Vec<f64> = (0..200).map(|_| rng.normal() + 3.0).collect();
        let (lo, hi) = bootstrap_mean_ci(&xs, 0.95, 500, &mut rng);
        assert!(lo < 3.0 && 3.0 < hi, "({lo}, {hi})");
    }
}
