//! Deterministic RNG substrate: xoshiro256++ + Box-Muller normals.
//!
//! The coordinator owns all request-path randomness (initial noise x_T,
//! simulated-annotator noise, workload arrivals); seeds are part of the
//! request so trajectories are exactly reproducible across runs and across
//! policies — the property every AG-vs-CFG comparison in the paper relies on
//! ("same seed sequence for both models").

/// xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-request RNGs from a base seed).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style widening multiply for unbiased bounded ints.
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box-Muller (polar form avoided for determinism).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u1 in (0,1] to keep ln() finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill a fresh Vec with standard normals (f32).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Exponential with rate `lambda` (Poisson-process inter-arrivals).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Gumbel(0, 1) sample (for the NAS search's concrete relaxation).
    pub fn gumbel(&mut self) -> f64 {
        let u = (1.0 - self.uniform()).max(f64::MIN_POSITIVE);
        -(-u.ln()).ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gumbel_mean_is_euler_mascheroni() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gumbel()).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
