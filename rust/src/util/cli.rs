//! Tiny CLI argument parser (substrate — no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional arguments;
//! used by the `agd` binary, the examples, and every bench harness (benches
//! receive their args after cargo's `--` separator).

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `--key value`, `--key=value`,
    /// `--flag` (when the next token is another option or absent).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // bare `--`: everything after is positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_owned(), v.to_owned());
                } else {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(body.to_owned(), v);
                        }
                        _ => out.flags.push(body.to_owned()),
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0], and a leading
    /// `--bench` that cargo-bench passes to harness=false targets).
    pub fn from_env() -> Args {
        let items: Vec<String> = std::env::args()
            .skip(1)
            .filter(|a| a != "--bench")
            .collect();
        Args::parse(items)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name}: expected integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name}: expected integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name}: expected float, got `{v}`")))
            .unwrap_or(default)
    }

    /// `f64` narrowed to the f32 guidance scales the policy API uses.
    pub fn f32(&self, name: &str, default: f32) -> f32 {
        self.f64(name, default as f64) as f32
    }

    /// Option constrained to a fixed value set (`--placement`,
    /// `--scheduler`, …): returns `default` when absent, or an error
    /// naming the valid choices — a typo'd enum flag should fail at
    /// startup with the menu, not deep inside a parse.
    pub fn choice<'a>(
        &'a self,
        name: &str,
        default: &'a str,
        options: &[&str],
    ) -> Result<&'a str, String> {
        let v = self.get_or(name, default);
        if options.contains(&v) {
            Ok(v)
        } else {
            Err(format!(
                "--{name}: unknown value `{v}` (expected {})",
                options.join("|")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_owned))
    }

    #[test]
    fn key_value_forms() {
        let a = args("--model dit_b --steps=20 run");
        assert_eq!(a.get("model"), Some("dit_b"));
        assert_eq!(a.usize("steps", 0), 20);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn flags_without_values() {
        let a = args("--verbose --out x --dry-run");
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("out"), Some("x"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = args("--quick --n 5");
        assert!(a.flag("quick"));
        assert_eq!(a.usize("n", 0), 5);
    }

    #[test]
    fn double_dash_positional() {
        let a = args("--a 1 -- --b 2");
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.positional, vec!["--b", "2"]);
    }

    #[test]
    fn defaults() {
        let a = args("");
        assert_eq!(a.usize("n", 7), 7);
        assert_eq!(a.f64("x", 0.5), 0.5);
        assert_eq!(a.f32("y", 1.5), 1.5);
        assert_eq!(a.get_or("m", "d"), "d");
    }

    #[test]
    #[should_panic(expected = "expected integer")]
    fn bad_integer_panics() {
        args("--n abc").usize("n", 0);
    }

    #[test]
    fn choice_validates_against_the_menu() {
        let opts = ["least-loaded", "round-robin", "client-hash"];
        let a = args("--placement round-robin");
        assert_eq!(a.choice("placement", "least-loaded", &opts), Ok("round-robin"));
        // absent → default (the default itself is trusted)
        assert_eq!(args("").choice("placement", "least-loaded", &opts), Ok("least-loaded"));
        // a typo fails with the full menu
        let err = args("--placement sticky").choice("placement", "least-loaded", &opts).unwrap_err();
        assert!(err.contains("sticky") && err.contains("round-robin"), "{err}");
    }
}
