//! §Robustness: one formatting path for fleet lifecycle log lines.
//!
//! Shard deaths, salvage summaries and supervisor respawns are the log
//! lines an operator greps first during an incident, so they share one
//! helper instead of N ad-hoc `log::error!` call sites: every line gets
//! the same `[+<ms>ms <component>]` prefix, where `<ms>` is a monotonic
//! offset from the first event the process ever logged. Monotonic
//! (not wall-clock) on purpose — the offsets order a crash/salvage/
//! respawn cascade unambiguously even when the system clock steps, and
//! two lines with the same offset are provably concurrent.

use std::sync::OnceLock;
use std::time::Instant;

/// The process-wide event epoch: stamped lazily by the first
/// [`log_event`] call, so offset 0 is always the first lifecycle event,
/// not process start (which no one correlates logs against).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Milliseconds since the event epoch (monotonic, saturating).
pub fn event_ms() -> u64 {
    epoch().elapsed().as_millis() as u64
}

/// Emit one lifecycle event line through the `log` facade:
/// `[+<ms>ms <component>] <message>`.
///
/// `component` names the emitter (`shard-3`, `supervisor`, `listener`);
/// the message should state what happened and the numbers that matter
/// (jobs refused, jobs salvaged, backoff chosen) — it is the artifact
/// guaranteed to survive a death even when nothing scrapes metrics again.
pub fn log_event(level: log::Level, component: &str, message: &str) {
    log::log!(level, "[+{}ms {component}] {message}", event_ms());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_clock_is_monotonic() {
        let a = event_ms();
        let b = event_ms();
        assert!(b >= a, "{b} < {a}");
        // and the helper itself never panics on any component/message
        log_event(log::Level::Info, "test", "hello");
        log_event(log::Level::Error, "shard-0", "fatal: x (2 refused, 1 salvaged)");
    }
}
