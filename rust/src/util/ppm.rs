//! PPM/PGM image writer (substrate) — dumps generated samples and
//! win/lose pairs (Figs. 6/12/13) without an image-codec dependency.

use std::io::Write;
use std::path::Path;

/// Write an RGB image stored as `[-1, 1]` floats in HWC order to binary PPM.
pub fn write_ppm(path: &Path, pixels: &[f32], width: usize, height: usize) -> std::io::Result<()> {
    assert_eq!(pixels.len(), width * height * 3, "pixel buffer size mismatch");
    let mut buf = Vec::with_capacity(width * height * 3 + 32);
    write!(buf, "P6\n{width} {height}\n255\n")?;
    buf.extend(pixels.iter().map(|&v| to_u8(v)));
    std::fs::write(path, buf)
}

/// Horizontally concatenate images (same size) into one PPM — side-by-side
/// comparison panels.
pub fn write_ppm_row(
    path: &Path,
    images: &[&[f32]],
    width: usize,
    height: usize,
) -> std::io::Result<()> {
    let n = images.len();
    assert!(n > 0);
    for img in images {
        assert_eq!(img.len(), width * height * 3);
    }
    let mut row = vec![0f32; width * n * height * 3];
    for (i, img) in images.iter().enumerate() {
        for y in 0..height {
            let src = &img[y * width * 3..(y + 1) * width * 3];
            let dst_off = (y * width * n + i * width) * 3;
            row[dst_off..dst_off + width * 3].copy_from_slice(src);
        }
    }
    write_ppm(path, &row, width * n, height)
}

fn to_u8(v: f32) -> u8 {
    (((v + 1.0) * 0.5).clamp(0.0, 1.0) * 255.0).round() as u8
}

/// Nearest-neighbour upscale (makes 16x16 samples viewable).
pub fn upscale(pixels: &[f32], width: usize, height: usize, factor: usize) -> Vec<f32> {
    let mut out = vec![0f32; width * factor * height * factor * 3];
    let ow = width * factor;
    for y in 0..height * factor {
        for x in 0..ow {
            let sy = y / factor;
            let sx = x / factor;
            for c in 0..3 {
                out[(y * ow + x) * 3 + c] = pixels[(sy * width + sx) * 3 + c];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_mapping() {
        assert_eq!(to_u8(-1.0), 0);
        assert_eq!(to_u8(1.0), 255);
        assert_eq!(to_u8(0.0), 128);
        assert_eq!(to_u8(5.0), 255); // clamped
        assert_eq!(to_u8(-5.0), 0);
    }

    #[test]
    fn writes_valid_header() {
        let dir = std::env::temp_dir().join("agd_ppm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ppm");
        let img = vec![0.0f32; 4 * 2 * 3];
        write_ppm(&path, &img, 4, 2).unwrap();
        let data = std::fs::read(&path).unwrap();
        assert!(data.starts_with(b"P6\n4 2\n255\n"));
        assert_eq!(data.len(), 11 + 4 * 2 * 3);
    }

    #[test]
    fn row_concat_layout() {
        let a = vec![1.0f32; 2 * 2 * 3];   // white
        let b = vec![-1.0f32; 2 * 2 * 3];  // black
        let dir = std::env::temp_dir().join("agd_ppm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("row.ppm");
        write_ppm_row(&path, &[&a, &b], 2, 2).unwrap();
        let data = std::fs::read(&path).unwrap();
        // header "P6\n4 2\n255\n" then row: 2 white px, 2 black px
        let body = &data[11..];
        assert_eq!(&body[0..6], &[255, 255, 255, 255, 255, 255]);
        assert_eq!(&body[6..12], &[0, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn upscale_doubles() {
        let img = vec![0.5f32; 2 * 2 * 3];
        let up = upscale(&img, 2, 2, 3);
        assert_eq!(up.len(), 6 * 6 * 3);
        assert!(up.iter().all(|&v| v == 0.5));
    }
}
