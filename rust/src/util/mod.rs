//! Infrastructure substrates: the offline vendor set has no serde / clap /
//! rand / criterion, so these are first-class implementations.

pub mod cli;
pub mod json;
pub mod logev;
pub mod ppm;
pub mod rng;
