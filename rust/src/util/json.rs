//! Minimal JSON parser/serializer (substrate — the offline vendor set has no
//! serde). Covers the full JSON grammar the repo needs: `manifest.json`
//! produced by `python/compile/aot.py` and the line protocol of
//! `server/mod.rs`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that panics with a useful message — for manifest
    /// fields whose absence is a build error, not a runtime condition.
    pub fn req(&self, key: &str) -> &Value {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of f64.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Value::as_f64).collect()
    }

    /// Convenience: array of strings.
    pub fn as_str_vec(&self) -> Option<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_owned))
            .collect()
    }
}

/// Parse a JSON document. Returns an error with byte position on failure.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: &'static str,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &'static str) -> ParseError {
        ParseError { pos: self.pos, msg }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err("unexpected character"))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("eof"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad \\u digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                // raw UTF-8 byte: re-decode from the source slice
                b => {
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        // multi-byte sequence: back up and take the full char
                        let start = self.pos - 1;
                        let rest = std::str::from_utf8(&self.bytes[start..])
                            .map_err(|_| self.err("invalid utf8"))?;
                        let c = rest.chars().next().unwrap();
                        self.pos = start + c.len_utf8();
                        s.push(c);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a [`Value`] to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&(*n as i64).to_string());
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for constructing values programmatically.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_owned(), v)).collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(text: &str) -> Value {
    Value::Str(text.to_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parses_unicode_escapes_and_utf8() {
        let v = parse(r#""é café""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café");
        let v = parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,true,null],"b":{"c":"d\"e"}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Value::Num(20.0)), "20");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
    }

    #[test]
    fn f64_vec_helper() {
        let v = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
    }
}
