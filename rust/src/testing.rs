//! `proptest-lite`: a tiny property-testing harness (the offline vendor set
//! has no proptest). Runs a property over many seeded random cases and, on
//! failure, reports the case seed so the exact input is reproducible with
//! `case_rng(seed)`.

use crate::util::rng::Rng;

/// Run `prop` over `cases` random cases derived from `base_seed`.
/// The property receives a per-case RNG; panic inside = failure.
pub fn forall(base_seed: u64, cases: usize, prop: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let seed = case_seed(base_seed, case);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case} (reproduce with case seed {seed:#x}): {msg}"
            );
        }
    }
}

/// The derived seed for one case (for reproducing failures in isolation).
pub fn case_seed(base_seed: u64, case: usize) -> u64 {
    base_seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(case as u64)
}

/// Helpers for building random test inputs.
pub mod gen {
    use crate::util::rng::Rng;

    pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32 * scale).collect()
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0);
        forall(1, 25, |_rng| {
            counter.set(counter.get() + 1);
        });
        assert_eq!(counter.get(), 25);
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn failing_property_reports_case() {
        forall(2, 50, |rng| {
            let v = rng.uniform();
            assert!(v < 0.9, "value {v} too large");
        });
    }

    #[test]
    fn case_seeds_are_distinct() {
        let a = case_seed(7, 0);
        let b = case_seed(7, 1);
        assert_ne!(a, b);
    }
}
