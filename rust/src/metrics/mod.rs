//! Serving metrics: latency recorder + throughput counters for the
//! end-to-end driver (`examples/serve_throughput.rs`) and the benches.

use std::time::{Duration, Instant};

use crate::stats;

/// Records per-request wall-clock latencies and derives percentiles.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples_ms: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_ms.push(d.as_secs_f64() * 1e3);
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples_ms)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        stats::percentile(&self.samples_ms, p)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p90={:.2}ms p99={:.2}ms",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(90.0),
            self.percentile(99.0)
        )
    }
}

/// Wall-clock throughput window.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    pub completed: usize,
    pub nfes: usize,
}

impl Throughput {
    pub fn start() -> Throughput {
        Throughput {
            start: Instant::now(),
            completed: 0,
            nfes: 0,
        }
    }

    pub fn observe(&mut self, nfes: usize) {
        self.completed += 1;
        self.nfes += nfes;
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn images_per_sec(&self) -> f64 {
        self.completed as f64 / self.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn nfes_per_sec(&self) -> f64 {
        self.nfes as f64 / self.elapsed().as_secs_f64().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record_ms(i as f64);
        }
        assert_eq!(r.count(), 100);
        assert!((r.percentile(50.0) - 50.5).abs() < 1.0);
        assert!(r.percentile(99.0) > 98.0);
        assert!((r.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_is_safe() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile(99.0), 0.0);
        assert_eq!(r.mean(), 0.0);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::start();
        t.observe(30);
        t.observe(40);
        assert_eq!(t.completed, 2);
        assert_eq!(t.nfes, 70);
        assert!(t.images_per_sec() > 0.0);
    }
}
