//! §Robustness: per-request solver-state checkpoints.
//!
//! A denoising trajectory under a deterministic guidance policy is a pure
//! function of (initial noise, step index, policy state): randomness enters
//! exactly once, at x_T. That makes a mid-flight request resumable from a
//! compact snapshot — the latents, the solver cursor (step index), the
//! policy's per-request state and the cumulative accounting — without any
//! RNG state, and with the byte-identical-output invariant intact: a
//! request killed mid-trajectory and resumed on a survivor completes with
//! exactly the bytes a fault-free run would have produced.
//!
//! Two pieces live here:
//!
//! * [`RequestCheckpoint`] — the snapshot itself, plus a versioned
//!   little-endian wire form ([`RequestCheckpoint::to_bytes`] /
//!   [`RequestCheckpoint::from_bytes`]) so a checkpoint can cross any
//!   boundary that can carry bytes. In-process salvage moves the struct
//!   itself (swap-don't-copy); the wire form is for durability layers and
//!   the round-trip tests.
//! * [`CheckpointStore`] — the engine's per-slot store. One preallocated
//!   checkpoint per admission slot, written in place after completed steps
//!   ([`CheckpointStore::begin_write`]) and handed out whole at salvage
//!   ([`CheckpointStore::take`]).
//!
//! # §Perf: staying off the allocation hot path
//!
//! Buffers are sized once, at admission ([`CheckpointStore::register`]):
//! latents reserve `flat_out`, the per-step histories reserve `steps`.
//! The per-step capture ([`crate::coordinator::request::RequestState::save_checkpoint`])
//! then runs `clear()` + `extend_from_slice` into that reserved capacity —
//! zero allocations in steady state, pinned by
//! `rust/tests/ckpt_zero_alloc.rs`. The only captures that allocate are the
//! ones that must retain per-step tensors (LINEARAG history, recorded
//! trajectories/iterates) — the same paths that already allocate per step
//! in the request state machine itself.

/// A resumable snapshot of one in-flight request, taken at a step boundary
/// (all of the step's evaluations combined, the solver advanced, the next
/// step not yet executed). The [`crate::coordinator::request::Request`]
/// itself — tokens, seed, policy, shapes — travels alongside the
/// checkpoint through the salvage path; this struct only carries what the
/// trajectory has *accumulated*.
#[derive(Debug, Clone, Default)]
pub struct RequestCheckpoint {
    /// id of the request this snapshot belongs to (stale-slot guard)
    pub id: u64,
    /// completed denoising steps — the rng-free solver cursor; resume
    /// re-enters the scheduler exactly here
    pub step: usize,
    /// cumulative model evaluations spent through `step`
    pub nfes: usize,
    /// cumulative guided (two-stream) steps through `step`
    pub cfg_steps: usize,
    /// [`crate::coordinator::policy::PolicyState`] — truncation flag
    pub truncated: bool,
    /// step at which the policy's truncation rule fired
    pub truncated_at: Option<usize>,
    /// guided-step counter from the policy state
    pub guided_steps: usize,
    /// current latents x_t
    pub x: Vec<f32>,
    /// last data prediction x0 (the solver's in-place companion buffer)
    pub x0_prev: Vec<f32>,
    /// canonical per-step gamma history (x0-cosine form)
    pub gammas: Vec<f64>,
    /// policy-private scratch values
    pub scratch: Vec<f64>,
    /// per-step gamma history (raw-eps cosine form)
    pub gammas_eps: Vec<f64>,
    /// retained conditional scores (LINEARAG / `record_trajectory`)
    pub hist_c: Vec<Vec<f32>>,
    /// retained unconditional / extrapolated scores
    pub hist_u: Vec<Vec<f32>>,
    /// per-step data predictions (`record_iterates`)
    pub iterates: Vec<Vec<f32>>,
}

/// Wire-format version byte; bump on any layout change so a stale blob
/// fails loudly instead of deserializing garbage.
const CKPT_VERSION: u8 = 1;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u64(out, v.len() as u64);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, v: &[f64]) {
    put_u64(out, v.len() as u64);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_nested(out: &mut Vec<u8>, v: &[Vec<f32>]) {
    put_u64(out, v.len() as u64);
    for row in v {
        put_f32s(out, row);
    }
}

/// Bounded little-endian reader over a checkpoint blob; every read is
/// length-checked so truncated input is an error, never a panic.
struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn u64(&mut self) -> Result<u64, String> {
        let end = self.at.checked_add(8).filter(|&e| e <= self.buf.len());
        let end = end.ok_or("checkpoint blob truncated")?;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.at..end]);
        self.at = end;
        Ok(u64::from_le_bytes(b))
    }

    fn len(&mut self, elem: usize) -> Result<usize, String> {
        let n = self.u64()? as usize;
        // cap by what the buffer could possibly hold, so a corrupt length
        // cannot drive a huge allocation before the bounds check trips
        if n.saturating_mul(elem) > self.buf.len() {
            return Err("checkpoint blob declares impossible length".into());
        }
        Ok(n)
    }

    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.len(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let end = self.at + 4;
            if end > self.buf.len() {
                return Err("checkpoint blob truncated".into());
            }
            let mut b = [0u8; 4];
            b.copy_from_slice(&self.buf[self.at..end]);
            self.at = end;
            v.push(f32::from_le_bytes(b));
        }
        Ok(v)
    }

    fn f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.len(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f64::from_bits(self.u64()?));
        }
        Ok(v)
    }

    fn nested(&mut self) -> Result<Vec<Vec<f32>>, String> {
        let n = self.len(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32s()?);
        }
        Ok(v)
    }
}

impl RequestCheckpoint {
    /// Drop all accumulated data but keep every buffer's capacity — the
    /// slot-reuse form of reset.
    pub fn clear(&mut self) {
        self.id = 0;
        self.step = 0;
        self.nfes = 0;
        self.cfg_steps = 0;
        self.truncated = false;
        self.truncated_at = None;
        self.guided_steps = 0;
        self.x.clear();
        self.x0_prev.clear();
        self.gammas.clear();
        self.scratch.clear();
        self.gammas_eps.clear();
        self.hist_c.clear();
        self.hist_u.clear();
        self.iterates.clear();
    }

    /// Reserve the capacities one request of this shape can ever need, so
    /// steady-state captures never grow a buffer (§Perf above).
    pub fn reserve(&mut self, flat_out: usize, steps: usize) {
        reserve_to(&mut self.x, flat_out);
        reserve_to(&mut self.x0_prev, flat_out);
        reserve_f64(&mut self.gammas, steps);
        reserve_f64(&mut self.scratch, steps);
        reserve_f64(&mut self.gammas_eps, steps);
    }

    /// Serialized size in bytes — the `checkpoint_bytes` histogram sample,
    /// computable without serializing.
    pub fn encoded_len(&self) -> usize {
        let scalars = 2 + 8 * 7; // magic+version, id/step/nfes/cfg/trunc_at/guided + flags word
        let f32v = |v: &Vec<f32>| 8 + 4 * v.len();
        let f64v = |v: &Vec<f64>| 8 + 8 * v.len();
        let nested = |v: &Vec<Vec<f32>>| 8 + v.iter().map(|r| 8 + 4 * r.len()).sum::<usize>();
        scalars
            + f32v(&self.x)
            + f32v(&self.x0_prev)
            + f64v(&self.gammas)
            + f64v(&self.scratch)
            + f64v(&self.gammas_eps)
            + nested(&self.hist_c)
            + nested(&self.hist_u)
            + nested(&self.iterates)
    }

    /// Versioned little-endian serialization (off the hot path — salvage
    /// moves the struct itself; this form is for durability and tests).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        out.push(b'C');
        out.push(CKPT_VERSION);
        put_u64(&mut out, self.id);
        put_u64(&mut out, self.step as u64);
        put_u64(&mut out, self.nfes as u64);
        put_u64(&mut out, self.cfg_steps as u64);
        // flags word: bit 0 = truncated, bit 1 = truncated_at present
        let flags =
            u64::from(self.truncated) | (u64::from(self.truncated_at.is_some()) << 1);
        put_u64(&mut out, flags);
        put_u64(&mut out, self.truncated_at.unwrap_or(0) as u64);
        put_u64(&mut out, self.guided_steps as u64);
        put_f32s(&mut out, &self.x);
        put_f32s(&mut out, &self.x0_prev);
        put_f64s(&mut out, &self.gammas);
        put_f64s(&mut out, &self.scratch);
        put_f64s(&mut out, &self.gammas_eps);
        put_nested(&mut out, &self.hist_c);
        put_nested(&mut out, &self.hist_u);
        put_nested(&mut out, &self.iterates);
        out
    }

    pub fn from_bytes(buf: &[u8]) -> Result<RequestCheckpoint, String> {
        if buf.len() < 2 || buf[0] != b'C' {
            return Err("not a checkpoint blob (bad magic)".into());
        }
        if buf[1] != CKPT_VERSION {
            return Err(format!(
                "checkpoint version {} unsupported (expected {CKPT_VERSION})",
                buf[1]
            ));
        }
        let mut r = Reader { buf, at: 2 };
        let id = r.u64()?;
        let step = r.u64()? as usize;
        let nfes = r.u64()? as usize;
        let cfg_steps = r.u64()? as usize;
        let flags = r.u64()?;
        let trunc_at_raw = r.u64()? as usize;
        let guided_steps = r.u64()? as usize;
        Ok(RequestCheckpoint {
            id,
            step,
            nfes,
            cfg_steps,
            truncated: flags & 1 != 0,
            truncated_at: (flags & 2 != 0).then_some(trunc_at_raw),
            guided_steps,
            x: r.f32s()?,
            x0_prev: r.f32s()?,
            gammas: r.f64s()?,
            scratch: r.f64s()?,
            gammas_eps: r.f64s()?,
            hist_c: r.nested()?,
            hist_u: r.nested()?,
            iterates: r.nested()?,
        })
    }
}

fn reserve_to(v: &mut Vec<f32>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

fn reserve_f64(v: &mut Vec<f64>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

/// The engine's per-slot checkpoint store. Slot indices are the engine's
/// admission slot indices, so slot reuse keeps the store at a constant
/// size; buffers registered once per admission are rewritten in place
/// every capture.
#[derive(Debug, Default)]
pub struct CheckpointStore {
    every: usize,
    slots: Vec<Slot>,
}

#[derive(Debug, Default)]
struct Slot {
    /// request id the stored checkpoint belongs to; `None` = no live
    /// checkpoint (never written, retired at completion, or taken)
    id: Option<u64>,
    ckpt: RequestCheckpoint,
}

impl CheckpointStore {
    /// Checkpoint cadence: write after every `every`-th completed step;
    /// 0 disables the store entirely (no registration, no captures —
    /// PR 8 behavior, byte for byte and allocation for allocation).
    pub fn set_every(&mut self, every: usize) {
        self.every = every;
    }

    pub fn enabled(&self) -> bool {
        self.every > 0
    }

    /// Whether a request that just completed its `step`-th boundary is due
    /// a capture.
    pub fn due(&self, step: usize) -> bool {
        self.every > 0 && step % self.every == 0
    }

    /// Admission hook: size slot `idx` for a request of this shape. All
    /// capacity growth happens here, off the steady-state pump.
    pub fn register(&mut self, idx: usize, flat_out: usize, steps: usize) {
        if !self.enabled() {
            return;
        }
        if self.slots.len() <= idx {
            self.slots.resize_with(idx + 1, Slot::default);
        }
        let slot = &mut self.slots[idx];
        slot.id = None;
        slot.ckpt.clear();
        slot.ckpt.reserve(flat_out, steps);
    }

    /// Start (or overwrite) slot `idx`'s checkpoint for request `id`,
    /// returning the buffer for the caller to fill in place.
    pub fn begin_write(&mut self, idx: usize, id: u64) -> &mut RequestCheckpoint {
        debug_assert!(idx < self.slots.len(), "checkpoint slot never registered");
        let slot = &mut self.slots[idx];
        slot.id = Some(id);
        &mut slot.ckpt
    }

    /// Completion/abandonment hook: the slot's checkpoint is stale; keep
    /// the buffers for the next occupant.
    pub fn retire(&mut self, idx: usize) {
        if let Some(slot) = self.slots.get_mut(idx) {
            slot.id = None;
        }
    }

    /// Salvage hook: move slot `idx`'s checkpoint out whole (the slot is
    /// left empty — the dying engine has no next occupant to serve).
    /// Returns `None` unless a live checkpoint for exactly `id` is stored.
    pub fn take(&mut self, idx: usize, id: u64) -> Option<RequestCheckpoint> {
        let slot = self.slots.get_mut(idx)?;
        if slot.id != Some(id) {
            return None;
        }
        slot.id = None;
        Some(std::mem::take(&mut slot.ckpt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RequestCheckpoint {
        RequestCheckpoint {
            id: 42,
            step: 3,
            nfes: 6,
            cfg_steps: 3,
            truncated: true,
            truncated_at: Some(2),
            guided_steps: 3,
            x: vec![0.25, -1.5, 3.75],
            x0_prev: vec![0.5, 0.125, -2.0],
            gammas: vec![0.9, f64::NAN, 0.99],
            scratch: vec![1.5],
            gammas_eps: vec![0.8, 0.81, 0.82],
            hist_c: vec![vec![1.0, 2.0, 3.0]],
            hist_u: vec![vec![4.0, 5.0, 6.0]],
            iterates: vec![vec![7.0, 8.0, 9.0], vec![1.0, 1.0, 1.0]],
        }
    }

    #[test]
    fn wire_round_trip_is_exact() {
        let ck = sample();
        let bytes = ck.to_bytes();
        assert_eq!(bytes.len(), ck.encoded_len());
        let back = RequestCheckpoint::from_bytes(&bytes).unwrap();
        // NaN gammas make derived equality useless; byte equality is the
        // actual invariant (resume consumes exactly these bits)
        assert_eq!(back.to_bytes(), bytes);
        assert_eq!(back.truncated_at, Some(2));
        assert!(back.gammas[1].is_nan());
    }

    #[test]
    fn wire_rejects_garbage_loudly() {
        assert!(RequestCheckpoint::from_bytes(b"").is_err());
        assert!(RequestCheckpoint::from_bytes(b"Xjunk").is_err());
        let mut bytes = sample().to_bytes();
        bytes[1] = 99; // future version
        assert!(RequestCheckpoint::from_bytes(&bytes)
            .unwrap_err()
            .contains("version"));
        let bytes = sample().to_bytes();
        assert!(RequestCheckpoint::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // corrupt a length word into something impossible
        let mut bytes = sample().to_bytes();
        bytes[2 + 8 * 7] = 0xFF;
        bytes[2 + 8 * 7 + 4] = 0xFF;
        assert!(RequestCheckpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn store_register_write_take_lifecycle() {
        let mut store = CheckpointStore::default();
        store.set_every(2);
        assert!(store.enabled());
        assert!(!store.due(1));
        assert!(store.due(2));
        store.register(5, 8, 10);
        // capture capacity is preallocated at registration
        let ck = store.begin_write(5, 7);
        assert!(ck.x.capacity() >= 8 && ck.gammas.capacity() >= 10);
        ck.id = 7;
        ck.step = 4;
        ck.x.extend_from_slice(&[1.0; 8]);
        // wrong id: stale-slot guard refuses
        assert!(store.take(5, 8).is_none());
        let taken = store.take(5, 7).expect("live checkpoint");
        assert_eq!(taken.step, 4);
        // taken means gone
        assert!(store.take(5, 7).is_none());
    }

    #[test]
    fn disabled_store_registers_nothing() {
        let mut store = CheckpointStore::default();
        assert!(!store.enabled());
        assert!(!store.due(4));
        store.register(3, 8, 10);
        assert!(store.slots.is_empty(), "off means off: no growth at all");
    }

    #[test]
    fn retire_keeps_buffers_for_the_next_occupant() {
        let mut store = CheckpointStore::default();
        store.set_every(1);
        store.register(0, 16, 4);
        let ck = store.begin_write(0, 1);
        ck.x.extend_from_slice(&[0.5; 16]);
        store.retire(0);
        assert!(store.take(0, 1).is_none(), "retired checkpoint is dead");
        // re-registration reuses the grown buffers
        store.register(0, 16, 4);
        let ck = store.begin_write(0, 2);
        assert!(ck.x.is_empty() && ck.x.capacity() >= 16);
    }
}
