//! A length-keyed pool of reusable `f32` buffers (§Perf: buffer ownership).
//!
//! The engine owns one [`BufPool`] and threads it through the whole
//! per-step path: score rows copied out of a batch, the combined epsilon of
//! every solver step, and any other fixed-length scratch the request state
//! machine needs. Buffers circulate — `take` hands out a recycled buffer of
//! the exact length when one is free, `put` returns it — so after a short
//! warmup a steady-state serving loop performs **zero heap allocations**
//! per pump (pinned by `rust/tests/zero_alloc.rs`).
//!
//! Contents of a taken buffer are unspecified: callers must fully overwrite
//! it (every consumer in the engine does a full `copy_from_slice` or a full
//! write pass). Free lists are capped per length class so a shifting
//! workload cannot grow the pool without bound.

use std::collections::HashMap;

/// Most free buffers retained per length class; returns beyond the cap are
/// dropped. High enough that any realistic batch×steps working set recycles
/// fully, low enough to bound memory when request shapes change.
const PER_LEN_CAP: usize = 1024;

/// Length-keyed free lists of `Vec<f32>` buffers. See the module docs for
/// the ownership story.
#[derive(Debug, Default)]
pub struct BufPool {
    free: HashMap<usize, Vec<Vec<f32>>>,
    allocs: u64,
    reuses: u64,
}

impl BufPool {
    pub fn new() -> BufPool {
        BufPool::default()
    }

    /// Take a buffer of exactly `len` elements. Contents are unspecified —
    /// the caller must fully overwrite them before reading.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        match self.free.get_mut(&len).and_then(Vec::pop) {
            Some(buf) => {
                self.reuses += 1;
                buf
            }
            None => {
                self.allocs += 1;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer for reuse, keyed by its current length.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let list = self.free.entry(buf.len()).or_default();
        if list.len() < PER_LEN_CAP {
            list.push(buf);
        }
    }

    /// Fresh allocations performed by `take` (misses).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// `take` calls served from the free lists (hits).
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    /// Buffers currently sitting in the free lists.
    pub fn pooled(&self) -> usize {
        self.free.values().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_take_recycles_by_length() {
        let mut p = BufPool::new();
        let a = p.take(8);
        let b = p.take(4);
        assert_eq!((a.len(), b.len()), (8, 4));
        assert_eq!(p.allocs(), 2);
        p.put(a);
        p.put(b);
        assert_eq!(p.pooled(), 2);
        let a2 = p.take(8);
        assert_eq!(a2.len(), 8);
        assert_eq!(p.reuses(), 1);
        assert_eq!(p.allocs(), 2, "the 8-length take must be a pool hit");
        // a length with no free buffer allocates
        let c = p.take(16);
        assert_eq!(c.len(), 16);
        assert_eq!(p.allocs(), 3);
    }

    #[test]
    fn zero_length_buffers_are_not_pooled() {
        let mut p = BufPool::new();
        p.put(Vec::new());
        assert_eq!(p.pooled(), 0);
    }

    #[test]
    fn free_lists_are_capped() {
        let mut p = BufPool::new();
        for _ in 0..(PER_LEN_CAP + 10) {
            p.put(vec![0.0; 4]);
        }
        assert_eq!(p.pooled(), PER_LEN_CAP);
    }
}
