//! A length-keyed pool of reusable `f32` buffers (§Perf: buffer ownership).
//!
//! The engine owns one [`BufPool`] and threads it through the whole
//! per-step path: score rows copied out of a batch, the combined epsilon of
//! every solver step, and any other fixed-length scratch the request state
//! machine needs. Buffers circulate — `take` hands out a recycled buffer of
//! the exact length when one is free, `put` returns it — so after a short
//! warmup a steady-state serving loop performs **zero heap allocations**
//! per pump (pinned by `rust/tests/zero_alloc.rs`).
//!
//! Contents of a taken buffer are unspecified: callers must fully overwrite
//! it (every consumer in the engine does a full `copy_from_slice` or a full
//! write pass). Free lists are capped per length class so a shifting
//! workload cannot grow the pool without bound.
//!
//! # Parallel step completion (§Perf: parallel execution)
//!
//! The pool is **single-owner**: only the engine thread touches it. When
//! step completions run on the worker pool, each parallel slot gets a
//! [`StepBufs`] — a spare buffer pre-staged by the engine plus a small
//! return queue — and the request state machine draws from/returns to it
//! through the [`BufSource`] trait instead of the pool directly. After
//! the parallel region the engine drains every `StepBufs` back into the
//! pool in slot order, so lend/return stays a single-threaded pool
//! conversation no matter how many workers completed steps.

use std::collections::HashMap;

/// Most free buffers retained per length class; returns beyond the cap are
/// dropped. High enough that any realistic batch×steps working set recycles
/// fully, low enough to bound memory when request shapes change.
const PER_LEN_CAP: usize = 1024;

/// Length-keyed free lists of `Vec<f32>` buffers. See the module docs for
/// the ownership story.
#[derive(Debug, Default)]
pub struct BufPool {
    free: HashMap<usize, Vec<Vec<f32>>>,
    allocs: u64,
    reuses: u64,
}

impl BufPool {
    pub fn new() -> BufPool {
        BufPool::default()
    }

    /// Take a buffer of exactly `len` elements. Contents are unspecified —
    /// the caller must fully overwrite them before reading.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        match self.free.get_mut(&len).and_then(Vec::pop) {
            Some(buf) => {
                self.reuses += 1;
                buf
            }
            None => {
                self.allocs += 1;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer for reuse, keyed by its current length.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let list = self.free.entry(buf.len()).or_default();
        if list.len() < PER_LEN_CAP {
            list.push(buf);
        }
    }

    /// Fresh allocations performed by `take` (misses).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// `take` calls served from the free lists (hits).
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    /// Buffers currently sitting in the free lists.
    pub fn pooled(&self) -> usize {
        self.free.values().map(Vec::len).sum()
    }
}

/// Where the per-step state machine draws and returns fixed-length score
/// buffers. Implemented by [`BufPool`] itself (the serial path) and by
/// [`StepBufs`] (the staged form a parallel step completion runs
/// against, so workers never touch the engine's single-owner pool).
pub trait BufSource {
    /// Take a buffer of exactly `len` elements; contents unspecified.
    fn take(&mut self, len: usize) -> Vec<f32>;
    /// Hand back a buffer the step is done with.
    fn put(&mut self, buf: Vec<f32>);
}

impl BufSource for BufPool {
    fn take(&mut self, len: usize) -> Vec<f32> {
        BufPool::take(self, len)
    }

    fn put(&mut self, buf: Vec<f32>) {
        BufPool::put(self, buf)
    }
}

/// Most buffers one step completion can return: the editing triple plus
/// the combined epsilon. [`StepBufs::new`] reserves this up front so the
/// return queue never reallocates on the hot path.
const MAX_STEP_RETURNS: usize = 4;

/// Per-slot buffer staging for a parallel step completion. The engine
/// pre-takes `spare` from the pool (when the slot's plan combines
/// streams), the worker-side state machine consumes it via
/// [`BufSource::take`] and queues its finished buffers via
/// [`BufSource::put`], and the engine drains `returned` back into the
/// pool afterwards — see the module docs.
#[derive(Debug, Default)]
pub struct StepBufs {
    /// The one buffer a combining plan may take mid-step.
    pub spare: Option<Vec<f32>>,
    /// Buffers the step finished with, awaiting the engine's pool drain.
    pub returned: Vec<Vec<f32>>,
}

impl StepBufs {
    pub fn new() -> StepBufs {
        StepBufs {
            spare: None,
            returned: Vec::with_capacity(MAX_STEP_RETURNS),
        }
    }

    /// Drop any leftover staging (the engine calls this after draining;
    /// capacity is retained).
    pub fn reset(&mut self) {
        self.spare = None;
        self.returned.clear();
    }
}

impl BufSource for StepBufs {
    fn take(&mut self, len: usize) -> Vec<f32> {
        let buf = self
            .spare
            .take()
            .expect("StepBufs: combining plan ran without a pre-staged spare buffer");
        debug_assert_eq!(buf.len(), len, "pre-staged spare has the wrong length");
        buf
    }

    fn put(&mut self, buf: Vec<f32>) {
        debug_assert!(
            self.returned.len() < MAX_STEP_RETURNS,
            "a step returned more buffers than any plan produces"
        );
        self.returned.push(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_take_recycles_by_length() {
        let mut p = BufPool::new();
        let a = p.take(8);
        let b = p.take(4);
        assert_eq!((a.len(), b.len()), (8, 4));
        assert_eq!(p.allocs(), 2);
        p.put(a);
        p.put(b);
        assert_eq!(p.pooled(), 2);
        let a2 = p.take(8);
        assert_eq!(a2.len(), 8);
        assert_eq!(p.reuses(), 1);
        assert_eq!(p.allocs(), 2, "the 8-length take must be a pool hit");
        // a length with no free buffer allocates
        let c = p.take(16);
        assert_eq!(c.len(), 16);
        assert_eq!(p.allocs(), 3);
    }

    #[test]
    fn zero_length_buffers_are_not_pooled() {
        let mut p = BufPool::new();
        p.put(Vec::new());
        assert_eq!(p.pooled(), 0);
    }

    #[test]
    fn free_lists_are_capped() {
        let mut p = BufPool::new();
        for _ in 0..(PER_LEN_CAP + 10) {
            p.put(vec![0.0; 4]);
        }
        assert_eq!(p.pooled(), PER_LEN_CAP);
    }

    #[test]
    fn step_bufs_stage_and_queue_without_touching_a_pool() {
        let mut pool = BufPool::new();
        let mut sb = StepBufs::new();
        sb.spare = Some(pool.take(8));
        // the state machine side: one take, several puts
        let eps = BufSource::take(&mut sb, 8);
        assert_eq!(eps.len(), 8);
        BufSource::put(&mut sb, vec![0.0; 8]);
        BufSource::put(&mut sb, vec![0.0; 8]);
        BufSource::put(&mut sb, eps);
        assert_eq!(sb.returned.len(), 3);
        assert!(sb.spare.is_none());
        // the engine side: drain everything back
        for buf in sb.returned.drain(..) {
            pool.put(buf);
        }
        assert_eq!(pool.pooled(), 3);
        sb.reset();
        assert!(sb.returned.is_empty());
    }

    /// Property-style pin for the parallel completion pattern: many
    /// interleaved lend/return rounds — pre-staged spares, per-slot
    /// return queues in arbitrary slot order, mixed length classes like a
    /// fleet of editing + standard models — must never lose a buffer,
    /// never hand the same allocation out twice, and keep the pool's
    /// conservation law `allocs == outstanding + pooled` (below the free
    /// list cap) through every round.
    #[test]
    fn interleaved_parallel_rounds_conserve_buffers() {
        use crate::util::rng::Rng;

        const LENS: [usize; 3] = [8, 16, 24];
        let mut pool = BufPool::new();
        let mut rng = Rng::new(0xB0F);
        // identity of every buffer currently lent out, by data pointer
        let mut outstanding: Vec<Vec<f32>> = Vec::new();
        let live_ptrs = |bufs: &[Vec<f32>]| -> Vec<usize> {
            bufs.iter().map(|b| b.as_ptr() as usize).collect()
        };

        // buffers that entered the pool from outside (emulated
        // delivered-slot buffers the pool never allocated) inflate
        // `pooled()` relative to `allocs()`; count them so the
        // conservation law stays exact
        let mut seeded = 0usize;

        for round in 0..400 {
            // phase 1: the engine pre-stages spares for a ready batch
            let slots = 1 + rng.below(12);
            let mut staged: Vec<StepBufs> = Vec::new();
            for s in 0..slots {
                let mut sb = StepBufs::new();
                let len = LENS[rng.below(LENS.len())];
                let buf = pool.take(len);
                let ptr = buf.as_ptr() as usize;
                assert!(
                    !live_ptrs(&outstanding).contains(&ptr),
                    "round {round} slot {s}: pool handed out a live buffer"
                );
                assert_eq!(buf.len(), len);
                sb.spare = Some(buf);
                // the worker side consumes the spare and queues returns
                // of assorted length classes
                let eps = BufSource::take(&mut sb, len);
                BufSource::put(&mut sb, eps);
                for _ in 0..rng.below(3) {
                    // emulate slot buffers previously delivered to the
                    // request (they entered from outside the pool)
                    BufSource::put(&mut sb, vec![0.0; LENS[rng.below(LENS.len())]]);
                    seeded += 1;
                }
                staged.push(sb);
            }
            // phase 2: slots complete in arbitrary order; the engine
            // drains them back in that order
            while !staged.is_empty() {
                let k = rng.below(staged.len());
                let mut sb = staged.swap_remove(k);
                if let Some(sp) = sb.spare.take() {
                    pool.put(sp);
                }
                for buf in sb.returned.drain(..) {
                    pool.put(buf);
                }
            }
            // some rounds keep buffers lent across rounds (recorded
            // histories), some give them back later
            if rng.below(3) == 0 {
                outstanding.push(pool.take(LENS[rng.below(LENS.len())]));
            } else if !outstanding.is_empty() && rng.below(2) == 0 {
                let k = rng.below(outstanding.len());
                pool.put(outstanding.swap_remove(k));
            }
            // conservation: nothing lost, nothing duplicated. Every take
            // was served by a fresh alloc, a recycled pool buffer, or a
            // seeded outside buffer, so (under the per-class cap)
            // allocs + seeded == live + free.
            assert_eq!(
                pool.allocs() as usize + seeded,
                outstanding.len() + pool.pooled(),
                "round {round}: pool lost or duplicated a buffer"
            );
            let ptrs = live_ptrs(&outstanding);
            let mut dedup = ptrs.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), ptrs.len(), "round {round}: duplicate live buffer");
        }
        assert!(pool.reuses() > 0, "the pattern must actually recycle");
    }
}
