//! Guidance policies — the paper's contribution surface.
//!
//! A policy maps `(step index, total steps, AG-truncation state)` to a
//! [`StepPlan`] describing which network evaluations the step needs and how
//! they are combined. The engine executes plans, feeds back the cosine
//! signal gamma_t (Eq. 7), and the policy's truncation rule decides when the
//! unconditional stream can be dropped.
//!
//! Implemented policies (paper reference in parens):
//!  * [`GuidancePolicy::Cfg`] — classic classifier-free guidance (Eq. 3).
//!  * [`GuidancePolicy::CondOnly`] — conditional-only; the cost model of a
//!    guidance-distilled network (the GD comparator in Fig. 1).
//!  * [`GuidancePolicy::Ag`] — Adaptive Guidance (§5): CFG until
//!    `gamma_t >= gamma_bar`, conditional afterwards.
//!  * [`GuidancePolicy::AgFixedPrefix`] — first `cfg_steps` guided, rest
//!    conditional (the "5 CFG + 15 cond" ablation of Fig. 8).
//!  * [`GuidancePolicy::AlternatingCfg`] — Fig. 8's naive baseline:
//!    alternate CFG/cond in the first half, cond in the second half.
//!  * [`GuidancePolicy::LinearAg`] — LINEARAG (§5.1, Eq. 11): alternate CFG
//!    and OLS-estimated CFG in the first half, OLS-estimated CFG after.
//!  * [`GuidancePolicy::Searched`] — an explicit per-step choice sequence, as
//!    produced by the NAS search (§4).
//!  * [`GuidancePolicy::Pix2Pix`] — image-editing guidance (Eq. 9) with
//!    optional AG truncation of the two auxiliary streams (App. B).

use std::sync::Arc;

use crate::ols::OlsCoeffs;

/// Per-step option chosen by a searched policy (§4.1's F_t).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepChoice {
    Uncond,
    Cond,
    Cfg { s: f32 },
}

/// What one denoising step must execute.
#[derive(Debug, Clone, PartialEq)]
pub enum StepPlan {
    /// Evaluate cond + uncond, combine with strength `s`, report gamma.
    Guided { s: f32 },
    /// Evaluate cond only.
    CondOnly,
    /// Evaluate uncond only (searched policies may select it).
    UncondOnly,
    /// Evaluate cond only; substitute the OLS estimate for eps_u (Eq. 10).
    LinearGuided { s: f32 },
    /// Editing triple-eval (Eq. 9): (c, I), (∅, I), (∅, ∅).
    EditGuided { s_text: f32, s_img: f32 },
    /// Editing after AG truncation: (c, I) only.
    EditCondOnly,
}

impl StepPlan {
    /// Network evaluations this plan costs.
    pub fn nfes(&self) -> usize {
        match self {
            StepPlan::Guided { .. } => 2,
            StepPlan::CondOnly | StepPlan::UncondOnly | StepPlan::LinearGuided { .. } => 1,
            StepPlan::EditGuided { .. } => 3,
            StepPlan::EditCondOnly => 1,
        }
    }
}

/// A guidance policy (see module docs).
#[derive(Debug, Clone)]
pub enum GuidancePolicy {
    Cfg { s: f32 },
    CondOnly,
    Ag { s: f32, gamma_bar: f64 },
    AgFixedPrefix { s: f32, cfg_steps: usize },
    AlternatingCfg { s: f32 },
    LinearAg { s: f32, coeffs: Arc<OlsCoeffs> },
    Searched { choices: Vec<StepChoice> },
    Pix2Pix {
        s_text: f32,
        s_img: f32,
        gamma_bar: Option<f64>,
        /// fixed guided-prefix length (App. B's protocol: 10 of 20 steps
        /// use the full Eq. 9 triple-eval, saving 33.3% of NFEs); `None`
        /// leaves truncation purely to `gamma_bar`
        full_prefix: Option<usize>,
    },
}

impl GuidancePolicy {
    /// The plan for step `step` of `total`, given whether AG has truncated.
    pub fn plan(&self, step: usize, total: usize, truncated: bool) -> StepPlan {
        match self {
            GuidancePolicy::Cfg { s } => StepPlan::Guided { s: *s },
            GuidancePolicy::CondOnly => StepPlan::CondOnly,
            GuidancePolicy::Ag { s, .. } => {
                if truncated {
                    StepPlan::CondOnly
                } else {
                    StepPlan::Guided { s: *s }
                }
            }
            GuidancePolicy::AgFixedPrefix { s, cfg_steps } => {
                if step < *cfg_steps {
                    StepPlan::Guided { s: *s }
                } else {
                    StepPlan::CondOnly
                }
            }
            GuidancePolicy::AlternatingCfg { s } => {
                if step < total / 2 && step % 2 == 0 {
                    StepPlan::Guided { s: *s }
                } else {
                    StepPlan::CondOnly
                }
            }
            GuidancePolicy::LinearAg { s, .. } => {
                // Eq. 11: true CFG on even steps of the first half, LR-CFG on
                // odd first-half steps and the entire second half.
                if step < total / 2 && step % 2 == 0 {
                    StepPlan::Guided { s: *s }
                } else {
                    StepPlan::LinearGuided { s: *s }
                }
            }
            GuidancePolicy::Searched { choices } => match choices
                .get(step)
                .copied()
                .unwrap_or(StepChoice::Cond)
            {
                StepChoice::Uncond => StepPlan::UncondOnly,
                StepChoice::Cond => StepPlan::CondOnly,
                StepChoice::Cfg { s } => StepPlan::Guided { s },
            },
            GuidancePolicy::Pix2Pix { s_text, s_img, full_prefix, .. } => {
                let past_prefix = full_prefix.map_or(false, |k| step >= k);
                if truncated || past_prefix {
                    StepPlan::EditCondOnly
                } else {
                    StepPlan::EditGuided {
                        s_text: *s_text,
                        s_img: *s_img,
                    }
                }
            }
        }
    }

    /// AG truncation rule: should subsequent steps drop the extra streams?
    /// Called by the engine after a guided step with the observed gamma.
    pub fn should_truncate(&self, gamma: f64) -> bool {
        match self {
            GuidancePolicy::Ag { gamma_bar, .. } => gamma >= *gamma_bar,
            GuidancePolicy::Pix2Pix {
                gamma_bar: Some(g), ..
            } => gamma >= *g,
            _ => false,
        }
    }

    /// Whether this policy consumes the OLS trajectory history.
    pub fn needs_history(&self) -> bool {
        matches!(self, GuidancePolicy::LinearAg { .. })
    }

    /// Upper bound on total NFEs for a request of `total` steps (exact for
    /// non-adaptive policies; AG's worst case is no truncation).
    pub fn max_nfes(&self, total: usize) -> usize {
        (0..total)
            .map(|i| self.plan(i, total, false).nfes())
            .sum()
    }

    /// Short display name for reports.
    pub fn name(&self) -> String {
        match self {
            GuidancePolicy::Cfg { s } => format!("cfg(s={s})"),
            GuidancePolicy::CondOnly => "cond-only".into(),
            GuidancePolicy::Ag { gamma_bar, .. } => format!("ag(ḡ={gamma_bar})"),
            GuidancePolicy::AgFixedPrefix { cfg_steps, .. } => {
                format!("ag-prefix({cfg_steps})")
            }
            GuidancePolicy::AlternatingCfg { .. } => "alternating".into(),
            GuidancePolicy::LinearAg { .. } => "linear-ag".into(),
            GuidancePolicy::Searched { .. } => "searched".into(),
            GuidancePolicy::Pix2Pix { gamma_bar, .. } => match gamma_bar {
                Some(g) => format!("pix2pix-ag(ḡ={g})"),
                None => "pix2pix".into(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_always_guided() {
        let p = GuidancePolicy::Cfg { s: 7.5 };
        for i in 0..20 {
            assert_eq!(p.plan(i, 20, false), StepPlan::Guided { s: 7.5 });
        }
        assert_eq!(p.max_nfes(20), 40);
        assert!(!p.should_truncate(1.0));
    }

    #[test]
    fn ag_switches_on_truncation_flag() {
        let p = GuidancePolicy::Ag {
            s: 7.5,
            gamma_bar: 0.99,
        };
        assert_eq!(p.plan(3, 20, false), StepPlan::Guided { s: 7.5 });
        assert_eq!(p.plan(3, 20, true), StepPlan::CondOnly);
        assert!(p.should_truncate(0.995));
        assert!(!p.should_truncate(0.98));
    }

    #[test]
    fn ag_prefix_counts() {
        let p = GuidancePolicy::AgFixedPrefix {
            s: 7.5,
            cfg_steps: 5,
        };
        let plans: Vec<_> = (0..20).map(|i| p.plan(i, 20, false)).collect();
        let guided = plans
            .iter()
            .filter(|pl| matches!(pl, StepPlan::Guided { .. }))
            .count();
        assert_eq!(guided, 5);
        assert_eq!(p.max_nfes(20), 25);
    }

    #[test]
    fn alternating_matches_fig8_description() {
        // first half: CFG on even steps; second half: all conditional.
        let p = GuidancePolicy::AlternatingCfg { s: 7.5 };
        let guided: Vec<usize> = (0..20)
            .filter(|&i| matches!(p.plan(i, 20, false), StepPlan::Guided { .. }))
            .collect();
        assert_eq!(guided, vec![0, 2, 4, 6, 8]);
        assert_eq!(p.max_nfes(20), 25);
    }

    #[test]
    fn linear_ag_matches_eq11() {
        let coeffs = Arc::new(OlsCoeffs {
            beta_c: vec![vec![]; 20],
            beta_u: vec![vec![]; 20],
        });
        let p = GuidancePolicy::LinearAg { s: 7.5, coeffs };
        // T=20: steps 0,2,4,6,8 true CFG; 1,3,5,7,9 LR; 10..19 LR
        for i in 0..20 {
            let plan = p.plan(i, 20, false);
            if i < 10 && i % 2 == 0 {
                assert_eq!(plan, StepPlan::Guided { s: 7.5 }, "step {i}");
            } else {
                assert_eq!(plan, StepPlan::LinearGuided { s: 7.5 }, "step {i}");
            }
        }
        // 5 guided * 2 + 15 LR * 1 = 25 NFEs (the paper's 75% guidance saving
        // relative to CFG's extra 20: only 5 extra evals remain)
        assert_eq!(p.max_nfes(20), 25);
        assert!(p.needs_history());
    }

    #[test]
    fn searched_policy_maps_choices() {
        let p = GuidancePolicy::Searched {
            choices: vec![
                StepChoice::Cfg { s: 7.5 },
                StepChoice::Cond,
                StepChoice::Uncond,
            ],
        };
        assert_eq!(p.plan(0, 3, false), StepPlan::Guided { s: 7.5 });
        assert_eq!(p.plan(1, 3, false), StepPlan::CondOnly);
        assert_eq!(p.plan(2, 3, false), StepPlan::UncondOnly);
        // out-of-range steps default to conditional
        assert_eq!(p.plan(7, 3, false), StepPlan::CondOnly);
        assert_eq!(p.max_nfes(3), 4);
    }

    #[test]
    fn pix2pix_truncation() {
        let p = GuidancePolicy::Pix2Pix {
            s_text: 7.5,
            s_img: 1.5,
            gamma_bar: Some(0.99),
            full_prefix: None,
        };
        assert_eq!(p.plan(0, 20, false).nfes(), 3);
        assert_eq!(p.plan(0, 20, true), StepPlan::EditCondOnly);
        assert!(p.should_truncate(0.995));
        // without a threshold it never truncates
        let p2 = GuidancePolicy::Pix2Pix {
            s_text: 7.5,
            s_img: 1.5,
            gamma_bar: None,
            full_prefix: None,
        };
        assert!(!p2.should_truncate(1.0));
        assert_eq!(p2.max_nfes(20), 60);
    }

    #[test]
    fn nfe_summary_matches_paper_fig1() {
        // Fig. 1's cost axis at T=20: CFG=40, GD-proxy=20, AG(no trunc)=40.
        assert_eq!(GuidancePolicy::Cfg { s: 7.5 }.max_nfes(20), 40);
        assert_eq!(GuidancePolicy::CondOnly.max_nfes(20), 20);
    }
}
