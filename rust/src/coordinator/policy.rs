//! The open guidance-policy API — the paper's contribution surface.
//!
//! A [`Policy`] decides, per denoising step, which network evaluations the
//! step needs and how they are combined ([`StepPlan`]). The engine executes
//! plans and feeds back a [`StepObservation`] (the cosine signal gamma_t of
//! Eq. 7 among other accounting); the policy reacts by updating its
//! per-request [`PolicyState`] — e.g. the AG truncation rule drops the
//! unconditional stream once gamma_t crosses the threshold.
//!
//! The API is *open*: policies are trait objects constructed by name through
//! [`crate::coordinator::spec::PolicyRegistry`], and new policies plug in
//! without touching the engine or the request state machine (see
//! [`crate::coordinator::ext`] for two follow-up-literature policies built
//! exactly that way).
//!
//! # Adding a policy
//!
//! 1. Define a struct with the policy's *configuration* (scales, thresholds).
//!    Per-request *state* does not live here — it lives in [`PolicyState`],
//!    which the engine owns per request.
//! 2. `impl Policy`: `plan` maps `(step, total, &state)` to a [`StepPlan`];
//!    `observe` (optional) updates the state from each completed step —
//!    set `state.truncated` to switch the remaining steps to a cheaper plan.
//!    `spec` reports the wire format so configs/benches can round-trip it.
//! 3. Register a builder under a wire name:
//!    `registry.register("my-policy", |spec| Ok(MyPolicy { .. }.into_ref()))`.
//!    The server line protocol, the CLI, and the benches all construct
//!    policies through the registry, so the new name is immediately
//!    reachable everywhere.
//!
//! Built-in policies (paper reference in parens):
//!  * [`Cfg`] — classic classifier-free guidance (Eq. 3).
//!  * [`CondOnly`] — conditional-only; the cost model of a
//!    guidance-distilled network (the GD comparator in Fig. 1).
//!  * [`Ag`] — Adaptive Guidance (§5): CFG until `gamma_t >= gamma_bar`,
//!    conditional afterwards.
//!  * [`AgFixedPrefix`] — first `cfg_steps` guided, rest conditional (the
//!    "5 CFG + 15 cond" ablation of Fig. 8).
//!  * [`AlternatingCfg`] — Fig. 8's naive baseline: alternate CFG/cond in
//!    the guided half, cond in the rest.
//!  * [`LinearAg`] — LINEARAG (§5.1, Eq. 11): alternate CFG and
//!    OLS-estimated CFG in the guided half, OLS-estimated CFG after.
//!  * [`Searched`] — an explicit per-step choice sequence, as produced by
//!    the NAS search (§4).
//!  * [`Pix2Pix`] — image-editing guidance (Eq. 9) with optional AG
//!    truncation of the two auxiliary streams (App. B).
//!
//! Plugin policies from the follow-up literature live in
//! [`crate::coordinator::ext`]: [`crate::coordinator::ext::CompressedCfg`]
//! and [`crate::coordinator::ext::AdaptiveScale`].

use std::fmt;
use std::sync::Arc;

use crate::coordinator::spec::PolicySpec;
use crate::ols::OlsCoeffs;
use crate::util::json;

/// Per-step option chosen by a searched policy (§4.1's F_t).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepChoice {
    Uncond,
    Cond,
    Cfg { s: f32 },
}

/// What one denoising step must execute.
#[derive(Debug, Clone, PartialEq)]
pub enum StepPlan {
    /// Evaluate cond + uncond, combine with strength `s`, report gamma.
    Guided { s: f32 },
    /// Evaluate cond only.
    CondOnly,
    /// Evaluate uncond only (searched policies may select it).
    UncondOnly,
    /// Evaluate cond only; substitute the OLS estimate for eps_u (Eq. 10).
    /// The plan carries the estimator so the request state machine needs no
    /// knowledge of the policy that emitted it.
    LinearGuided { s: f32, coeffs: Arc<OlsCoeffs> },
    /// Editing triple-eval (Eq. 9): (c, I), (∅, I), (∅, ∅).
    EditGuided { s_text: f32, s_img: f32 },
    /// Editing after AG truncation: (c, I) only.
    EditCondOnly,
}

impl StepPlan {
    /// Network evaluations this plan costs.
    pub fn nfes(&self) -> usize {
        match self {
            StepPlan::Guided { .. } => 2,
            StepPlan::CondOnly | StepPlan::UncondOnly | StepPlan::LinearGuided { .. } => 1,
            StepPlan::EditGuided { .. } => 3,
            StepPlan::EditCondOnly => 1,
        }
    }

    /// Whether the plan evaluates a guidance pair/triple (counts as a "CFG
    /// step" in the paper's accounting).
    pub fn guided(&self) -> bool {
        matches!(self, StepPlan::Guided { .. } | StepPlan::EditGuided { .. })
    }
}

/// Per-request adaptive state, owned by the request state machine and
/// threaded through [`Policy::plan`] / mutated by [`Policy::observe`].
///
/// The common fields cover the built-in policies (truncation flag + step,
/// guided-step counter, observed gamma history); `scratch` is free-form
/// numeric storage for policies with richer state.
#[derive(Debug, Clone, Default)]
pub struct PolicyState {
    /// The policy switched to its cheap phase (AG's truncation rule).
    pub truncated: bool,
    /// Step at which truncation fired (effective from the next step).
    pub truncated_at: Option<usize>,
    /// Guided (pair/triple) steps executed so far.
    pub guided_steps: usize,
    /// Per-step gamma history (Eq. 7 on the x0 predictions), maintained by
    /// the request state machine: one entry per completed step, NaN for
    /// steps without both streams.
    pub gammas: Vec<f64>,
    /// Policy-defined scratch space (e.g. running estimates).
    pub scratch: Vec<f64>,
}

impl PolicyState {
    pub fn new() -> PolicyState {
        PolicyState::default()
    }

    /// Most recent finite gamma observation, if any.
    pub fn last_gamma(&self) -> Option<f64> {
        self.gammas.iter().rev().copied().find(|g| g.is_finite())
    }
}

/// What the engine reports back to the policy after a completed step.
#[derive(Debug, Clone)]
pub struct StepObservation {
    /// The step that just completed (0-based).
    pub step: usize,
    /// Total steps of the request.
    pub total: usize,
    /// Eq. 7's cosine on the x0 data predictions (NaN for single-stream
    /// steps) — the AG convergence signal.
    pub gamma: f64,
    /// Eq. 7's cosine on the raw eps predictions.
    pub gamma_eps: f64,
    /// Network evaluations the step consumed.
    pub nfes: usize,
    /// Whether the step executed a guidance pair/triple.
    pub guided: bool,
}

/// A guidance policy (see module docs). Implementations are shared,
/// immutable configuration; all per-request state lives in [`PolicyState`].
pub trait Policy: fmt::Debug + Send + Sync {
    /// Short display name for reports.
    fn name(&self) -> String;

    /// The plan for step `step` of `total`, given the request's state.
    fn plan(&self, step: usize, total: usize, state: &PolicyState) -> StepPlan;

    /// React to a completed step (default: stateless). Called once per step
    /// with the gamma signal; adaptive policies update `state` here — the
    /// engine never interprets thresholds itself.
    fn observe(&self, _state: &mut PolicyState, _obs: &StepObservation) {}

    /// Whether this policy consumes the OLS trajectory history. Contract:
    /// a `true` here obliges `plan` to emit a history-feeding plan
    /// ([`StepPlan::Guided`] or [`StepPlan::LinearGuided`]) on *every*
    /// step — single-stream plans record nothing, and a later
    /// `LinearGuided` step would find the history short and panic inside
    /// the estimator.
    fn needs_history(&self) -> bool {
        false
    }

    /// Check that this policy can serve a request of `total` steps (e.g.
    /// that a learned coefficient table covers them). Front-ends call this
    /// before admitting a request so a bad combination is an error reply,
    /// not an engine panic. The default accepts everything.
    fn validate(&self, _total: usize) -> Result<(), String> {
        Ok(())
    }

    /// Upper bound on total NFEs for a request of `total` steps: the plan
    /// sequence under a fresh (never-truncating) state. Exact for
    /// non-adaptive policies; AG's worst case is no truncation.
    fn max_nfes(&self, total: usize) -> usize {
        let state = PolicyState::new();
        (0..total).map(|i| self.plan(i, total, &state).nfes()).sum()
    }

    /// The wire/config form of this policy (fully explicit parameters), so
    /// any constructed policy can be serialized and rebuilt by the registry.
    fn spec(&self) -> PolicySpec;

    /// The wire kind alone (`spec().kind`) — the per-request telemetry
    /// label, taken on every admission. The default derives it from
    /// [`Self::spec`]; policies whose spec carries heavyweight parameters
    /// (e.g. LINEARAG's coefficient matrix) override it to skip the
    /// serialization.
    fn kind(&self) -> String {
        self.spec().kind
    }

    /// Box into the shared handle the engine consumes.
    fn into_ref(self) -> PolicyRef
    where
        Self: Sized + 'static,
    {
        Arc::new(self)
    }
}

/// Shared policy handle: cheap to clone into every request.
pub type PolicyRef = Arc<dyn Policy>;

/// Rounding rule for "half-split" policies ([`AlternatingCfg`],
/// [`LinearAg`]): the guided phase covers the *first* ⌈total/2⌉ steps. For
/// odd `total` the extra step goes to the guided half — guidance matters
/// most early in the trajectory (Fig. 4's rising gamma_t), so the split
/// biases toward it rather than silently shrinking it as `total / 2` did.
pub fn guided_half(total: usize) -> usize {
    total - total / 2
}

// ---------------------------------------------------------------------------
// Built-in policies
// ---------------------------------------------------------------------------

/// Classic classifier-free guidance (Eq. 3): every step evaluates both
/// streams.
#[derive(Debug, Clone, PartialEq)]
pub struct Cfg {
    pub s: f32,
}

impl Policy for Cfg {
    fn name(&self) -> String {
        format!("cfg(s={})", self.s)
    }

    fn plan(&self, _step: usize, _total: usize, _state: &PolicyState) -> StepPlan {
        StepPlan::Guided { s: self.s }
    }

    fn spec(&self) -> PolicySpec {
        PolicySpec::new("cfg").with("s", json::num(self.s as f64))
    }
}

/// Conditional-only sampling: the cost model of a guidance-distilled
/// network (the GD comparator of Fig. 1).
#[derive(Debug, Clone, PartialEq)]
pub struct CondOnly;

impl Policy for CondOnly {
    fn name(&self) -> String {
        "cond-only".into()
    }

    fn plan(&self, _step: usize, _total: usize, _state: &PolicyState) -> StepPlan {
        StepPlan::CondOnly
    }

    fn spec(&self) -> PolicySpec {
        PolicySpec::new("cond")
    }
}

/// Adaptive Guidance (§5): CFG until `gamma_t >= gamma_bar`, conditional
/// afterwards.
#[derive(Debug, Clone, PartialEq)]
pub struct Ag {
    pub s: f32,
    pub gamma_bar: f64,
}

impl Policy for Ag {
    fn name(&self) -> String {
        format!("ag(ḡ={})", self.gamma_bar)
    }

    fn plan(&self, _step: usize, _total: usize, state: &PolicyState) -> StepPlan {
        if state.truncated {
            StepPlan::CondOnly
        } else {
            StepPlan::Guided { s: self.s }
        }
    }

    fn observe(&self, state: &mut PolicyState, obs: &StepObservation) {
        // NaN gamma (single-stream step) never crosses the threshold.
        if !state.truncated && obs.gamma >= self.gamma_bar {
            state.truncated = true;
            state.truncated_at = Some(obs.step);
        }
    }

    fn spec(&self) -> PolicySpec {
        PolicySpec::new("ag")
            .with("s", json::num(self.s as f64))
            .with("gamma_bar", json::num(self.gamma_bar))
    }
}

/// Fixed guided prefix: first `cfg_steps` guided, rest conditional (the
/// "5 CFG + 15 cond" ablation of Fig. 8).
#[derive(Debug, Clone, PartialEq)]
pub struct AgFixedPrefix {
    pub s: f32,
    pub cfg_steps: usize,
}

impl Policy for AgFixedPrefix {
    fn name(&self) -> String {
        format!("ag-prefix({})", self.cfg_steps)
    }

    fn plan(&self, step: usize, _total: usize, _state: &PolicyState) -> StepPlan {
        if step < self.cfg_steps {
            StepPlan::Guided { s: self.s }
        } else {
            StepPlan::CondOnly
        }
    }

    fn spec(&self) -> PolicySpec {
        PolicySpec::new("ag-prefix")
            .with("s", json::num(self.s as f64))
            .with("cfg_steps", json::num(self.cfg_steps as f64))
    }
}

/// Fig. 8's naive baseline: alternate CFG/cond in the guided half
/// ([`guided_half`]), conditional in the rest.
#[derive(Debug, Clone, PartialEq)]
pub struct AlternatingCfg {
    pub s: f32,
}

impl Policy for AlternatingCfg {
    fn name(&self) -> String {
        "alternating".into()
    }

    fn plan(&self, step: usize, total: usize, _state: &PolicyState) -> StepPlan {
        if step < guided_half(total) && step % 2 == 0 {
            StepPlan::Guided { s: self.s }
        } else {
            StepPlan::CondOnly
        }
    }

    fn spec(&self) -> PolicySpec {
        PolicySpec::new("alternating").with("s", json::num(self.s as f64))
    }
}

/// LINEARAG (§5.1, Eq. 11): true CFG on even steps of the guided half,
/// OLS-estimated CFG on odd guided-half steps and the entire rest.
#[derive(Debug, Clone)]
pub struct LinearAg {
    pub s: f32,
    pub coeffs: Arc<OlsCoeffs>,
}

impl Policy for LinearAg {
    fn name(&self) -> String {
        "linear-ag".into()
    }

    fn plan(&self, step: usize, total: usize, _state: &PolicyState) -> StepPlan {
        if step < guided_half(total) && step % 2 == 0 {
            StepPlan::Guided { s: self.s }
        } else {
            StepPlan::LinearGuided {
                s: self.s,
                coeffs: self.coeffs.clone(),
            }
        }
    }

    fn needs_history(&self) -> bool {
        true
    }

    fn validate(&self, total: usize) -> Result<(), String> {
        if self.coeffs.steps() < total {
            return Err(format!(
                "linear-ag coefficients cover {} steps but the request has {total}",
                self.coeffs.steps()
            ));
        }
        Ok(())
    }

    fn spec(&self) -> PolicySpec {
        PolicySpec::new("linear-ag")
            .with("s", json::num(self.s as f64))
            .with("coeffs", self.coeffs.to_json())
    }

    fn kind(&self) -> String {
        // spec() serializes the whole coefficient matrix — far too heavy
        // for a per-admission label
        "linear-ag".into()
    }
}

/// An explicit per-step choice sequence, as produced by the NAS search
/// (§4). Out-of-range steps default to conditional.
#[derive(Debug, Clone, PartialEq)]
pub struct Searched {
    pub choices: Vec<StepChoice>,
}

impl Policy for Searched {
    fn name(&self) -> String {
        "searched".into()
    }

    fn plan(&self, step: usize, _total: usize, _state: &PolicyState) -> StepPlan {
        match self.choices.get(step).copied().unwrap_or(StepChoice::Cond) {
            StepChoice::Uncond => StepPlan::UncondOnly,
            StepChoice::Cond => StepPlan::CondOnly,
            StepChoice::Cfg { s } => StepPlan::Guided { s },
        }
    }

    fn spec(&self) -> PolicySpec {
        let choices = self
            .choices
            .iter()
            .map(|c| match c {
                StepChoice::Uncond => json::s("uncond"),
                StepChoice::Cond => json::s("cond"),
                StepChoice::Cfg { s } => json::obj(vec![("cfg", json::num(*s as f64))]),
            })
            .collect();
        PolicySpec::new("searched").with("choices", json::arr(choices))
    }

    fn kind(&self) -> String {
        "searched".into()
    }
}

/// Image-editing guidance (Eq. 9) with optional AG truncation of the two
/// auxiliary streams (App. B).
#[derive(Debug, Clone, PartialEq)]
pub struct Pix2Pix {
    pub s_text: f32,
    pub s_img: f32,
    pub gamma_bar: Option<f64>,
    /// fixed guided-prefix length (App. B's protocol: 10 of 20 steps use
    /// the full Eq. 9 triple-eval, saving 33.3% of NFEs); `None` leaves
    /// truncation purely to `gamma_bar`
    pub full_prefix: Option<usize>,
}

impl Policy for Pix2Pix {
    fn name(&self) -> String {
        match self.gamma_bar {
            Some(g) => format!("pix2pix-ag(ḡ={g})"),
            None => "pix2pix".into(),
        }
    }

    fn plan(&self, step: usize, _total: usize, state: &PolicyState) -> StepPlan {
        let past_prefix = self.full_prefix.map_or(false, |k| step >= k);
        if state.truncated || past_prefix {
            StepPlan::EditCondOnly
        } else {
            StepPlan::EditGuided {
                s_text: self.s_text,
                s_img: self.s_img,
            }
        }
    }

    fn observe(&self, state: &mut PolicyState, obs: &StepObservation) {
        if let Some(g) = self.gamma_bar {
            if !state.truncated && obs.gamma >= g {
                state.truncated = true;
                state.truncated_at = Some(obs.step);
            }
        }
    }

    fn spec(&self) -> PolicySpec {
        let mut spec = PolicySpec::new("pix2pix")
            .with("s_text", json::num(self.s_text as f64))
            .with("s_img", json::num(self.s_img as f64));
        if let Some(g) = self.gamma_bar {
            spec = spec.with("gamma_bar", json::num(g));
        }
        if let Some(k) = self.full_prefix {
            spec = spec.with("full_prefix", json::num(k as f64));
        }
        spec
    }
}

// ---------------------------------------------------------------------------
// Constructor helpers: the short form used by benches, examples and tests.
// ---------------------------------------------------------------------------

pub fn cfg(s: f32) -> PolicyRef {
    Cfg { s }.into_ref()
}

pub fn cond_only() -> PolicyRef {
    CondOnly.into_ref()
}

pub fn ag(s: f32, gamma_bar: f64) -> PolicyRef {
    Ag { s, gamma_bar }.into_ref()
}

pub fn ag_prefix(s: f32, cfg_steps: usize) -> PolicyRef {
    AgFixedPrefix { s, cfg_steps }.into_ref()
}

pub fn alternating(s: f32) -> PolicyRef {
    AlternatingCfg { s }.into_ref()
}

pub fn linear_ag(s: f32, coeffs: Arc<OlsCoeffs>) -> PolicyRef {
    LinearAg { s, coeffs }.into_ref()
}

pub fn searched(choices: Vec<StepChoice>) -> PolicyRef {
    Searched { choices }.into_ref()
}

pub fn pix2pix(
    s_text: f32,
    s_img: f32,
    gamma_bar: Option<f64>,
    full_prefix: Option<usize>,
) -> PolicyRef {
    Pix2Pix {
        s_text,
        s_img,
        gamma_bar,
        full_prefix,
    }
    .into_ref()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh() -> PolicyState {
        PolicyState::new()
    }

    /// Drive `observe` the way the engine does for a guided step.
    fn observe_gamma(p: &dyn Policy, state: &mut PolicyState, step: usize, gamma: f64) {
        state.gammas.push(gamma);
        p.observe(
            state,
            &StepObservation {
                step,
                total: 20,
                gamma,
                gamma_eps: gamma,
                nfes: 2,
                guided: true,
            },
        );
    }

    #[test]
    fn cfg_always_guided() {
        let p = Cfg { s: 7.5 };
        let st = fresh();
        for i in 0..20 {
            assert_eq!(p.plan(i, 20, &st), StepPlan::Guided { s: 7.5 });
        }
        assert_eq!(p.max_nfes(20), 40);
    }

    #[test]
    fn ag_truncates_through_observe() {
        let p = Ag {
            s: 7.5,
            gamma_bar: 0.99,
        };
        let mut st = fresh();
        assert_eq!(p.plan(3, 20, &st), StepPlan::Guided { s: 7.5 });
        observe_gamma(&p, &mut st, 3, 0.98);
        assert!(!st.truncated, "below threshold must not truncate");
        observe_gamma(&p, &mut st, 4, 0.995);
        assert!(st.truncated);
        assert_eq!(st.truncated_at, Some(4));
        assert_eq!(p.plan(5, 20, &st), StepPlan::CondOnly);
        // NaN gamma (single-stream step) never truncates
        let mut st2 = fresh();
        observe_gamma(&p, &mut st2, 0, f64::NAN);
        assert!(!st2.truncated);
    }

    #[test]
    fn ag_prefix_counts() {
        let p = AgFixedPrefix {
            s: 7.5,
            cfg_steps: 5,
        };
        let st = fresh();
        let guided = (0..20)
            .filter(|&i| p.plan(i, 20, &st).guided())
            .count();
        assert_eq!(guided, 5);
        assert_eq!(p.max_nfes(20), 25);
    }

    #[test]
    fn alternating_matches_fig8_description() {
        // guided half: CFG on even steps; rest: all conditional.
        let p = AlternatingCfg { s: 7.5 };
        let st = fresh();
        let guided: Vec<usize> = (0..20)
            .filter(|&i| p.plan(i, 20, &st).guided())
            .collect();
        assert_eq!(guided, vec![0, 2, 4, 6, 8]);
        assert_eq!(p.max_nfes(20), 25);
    }

    #[test]
    fn guided_half_rounds_up_for_odd_totals() {
        // the shared rounding rule: the guided phase gets the extra step.
        assert_eq!(guided_half(20), 10);
        assert_eq!(guided_half(5), 3);
        assert_eq!(guided_half(7), 4);
        assert_eq!(guided_half(1), 1);
        assert_eq!(guided_half(0), 0);

        // T=5: guided phase covers steps 0..3, CFG on its even steps 0, 2.
        let p = AlternatingCfg { s: 2.0 };
        let st = fresh();
        let guided: Vec<usize> = (0..5).filter(|&i| p.plan(i, 5, &st).guided()).collect();
        assert_eq!(guided, vec![0, 2]);
        assert_eq!(p.max_nfes(5), 7);

        // LinearAg shares the same rule: T=5 → CFG at 0, 2; LR elsewhere.
        let lin = LinearAg {
            s: 2.0,
            coeffs: Arc::new(OlsCoeffs::identity(5)),
        };
        let guided: Vec<usize> = (0..5).filter(|&i| lin.plan(i, 5, &st).guided()).collect();
        assert_eq!(guided, vec![0, 2]);
        assert_eq!(lin.max_nfes(5), 7);
    }

    #[test]
    fn linear_ag_matches_eq11() {
        let coeffs = Arc::new(OlsCoeffs {
            beta_c: vec![vec![]; 20],
            beta_u: vec![vec![]; 20],
        });
        let p = LinearAg { s: 7.5, coeffs };
        let st = fresh();
        // T=20: steps 0,2,4,6,8 true CFG; 1,3,5,7,9 LR; 10..19 LR
        for i in 0..20 {
            let plan = p.plan(i, 20, &st);
            if i < 10 && i % 2 == 0 {
                assert_eq!(plan, StepPlan::Guided { s: 7.5 }, "step {i}");
            } else {
                assert!(
                    matches!(plan, StepPlan::LinearGuided { s, .. } if s == 7.5),
                    "step {i}"
                );
            }
        }
        // 5 guided * 2 + 15 LR * 1 = 25 NFEs (the paper's 75% guidance saving
        // relative to CFG's extra 20: only 5 extra evals remain)
        assert_eq!(p.max_nfes(20), 25);
        assert!(p.needs_history());
        // the coefficient table must cover the request's step count
        assert!(p.validate(20).is_ok());
        assert!(p.validate(21).is_err());
    }

    #[test]
    fn searched_policy_maps_choices() {
        let p = Searched {
            choices: vec![
                StepChoice::Cfg { s: 7.5 },
                StepChoice::Cond,
                StepChoice::Uncond,
            ],
        };
        let st = fresh();
        assert_eq!(p.plan(0, 3, &st), StepPlan::Guided { s: 7.5 });
        assert_eq!(p.plan(1, 3, &st), StepPlan::CondOnly);
        assert_eq!(p.plan(2, 3, &st), StepPlan::UncondOnly);
        // out-of-range steps default to conditional
        assert_eq!(p.plan(7, 3, &st), StepPlan::CondOnly);
        assert_eq!(p.max_nfes(3), 4);
    }

    #[test]
    fn pix2pix_truncation() {
        let p = Pix2Pix {
            s_text: 7.5,
            s_img: 1.5,
            gamma_bar: Some(0.99),
            full_prefix: None,
        };
        let mut st = fresh();
        assert_eq!(p.plan(0, 20, &st).nfes(), 3);
        observe_gamma(&p, &mut st, 0, 0.995);
        assert!(st.truncated);
        assert_eq!(p.plan(1, 20, &st), StepPlan::EditCondOnly);
        // without a threshold it never truncates
        let p2 = Pix2Pix {
            s_text: 7.5,
            s_img: 1.5,
            gamma_bar: None,
            full_prefix: None,
        };
        let mut st2 = fresh();
        observe_gamma(&p2, &mut st2, 0, 1.0);
        assert!(!st2.truncated);
        assert_eq!(p2.max_nfes(20), 60);
        // a fixed prefix caps the triple-eval phase
        let p3 = Pix2Pix {
            s_text: 7.5,
            s_img: 1.5,
            gamma_bar: None,
            full_prefix: Some(10),
        };
        assert_eq!(p3.max_nfes(20), 40);
    }

    #[test]
    fn nfe_summary_matches_paper_fig1() {
        // Fig. 1's cost axis at T=20: CFG=40, GD-proxy=20, AG(no trunc)=40.
        assert_eq!(Cfg { s: 7.5 }.max_nfes(20), 40);
        assert_eq!(CondOnly.max_nfes(20), 20);
        assert_eq!(Ag { s: 7.5, gamma_bar: 0.99 }.max_nfes(20), 40);
    }

    #[test]
    fn last_gamma_skips_single_stream_steps() {
        let mut st = fresh();
        assert_eq!(st.last_gamma(), None);
        st.gammas.push(0.9);
        st.gammas.push(f64::NAN);
        assert_eq!(st.last_gamma(), Some(0.9));
    }
}
