//! Schedule math: the Rust mirror of `python/compile/diffusion.py`.
//!
//! The cosine VP schedule and the DPM-Solver++(2M) coefficient folding must
//! agree bit-for-bit in structure (f64 math, same formulas) with the python
//! side that lowered the solver kernel; `runtime` integration tests pin this
//! module against the parity table exported in `manifest.json`.

/// Schedule constants — keep in sync with diffusion.py.
pub const COSINE_S: f64 = 0.008;
pub const T_MAX: f64 = 0.98;
pub const T_MIN: f64 = 0.02;

/// Cosine cumulative signal level, normalized so `alpha_bar(0) = 1`.
pub fn alpha_bar(t: f64) -> f64 {
    let f = |u: f64| ((u + COSINE_S) / (1.0 + COSINE_S) * std::f64::consts::FRAC_PI_2)
        .cos()
        .powi(2);
    f(t) / f(0.0)
}

/// VP `(alpha_t, sigma_t)` with `alpha^2 + sigma^2 = 1`.
pub fn alpha_sigma(t: f64) -> (f64, f64) {
    let ab = alpha_bar(t);
    (ab.sqrt(), (1.0 - ab).sqrt())
}

/// Half log-SNR `lambda_t = log(alpha_t / sigma_t)`.
pub fn lambda(t: f64) -> f64 {
    let (a, s) = alpha_sigma(t);
    (a / s).ln()
}

/// The `i`-th point of the [`timesteps`] grid without materializing the
/// table — the per-eval hot path asks for one point at a time, and the
/// closed form is bit-identical to indexing the table.
pub fn timestep(i: usize, num_steps: usize) -> f64 {
    assert!(num_steps >= 1 && i <= num_steps);
    T_MAX + (T_MIN - T_MAX) * i as f64 / num_steps as f64
}

/// Uniform time grid from `T_MAX` down to `T_MIN`, `num_steps + 1` points.
pub fn timesteps(num_steps: usize) -> Vec<f64> {
    (0..=num_steps).map(|i| timestep(i, num_steps)).collect()
}

/// The five folded DPM++(2M) coefficients for one step (see
/// `kernels/dpmpp.py` for the consuming kernel and `ref.dpmpp_step` for the
/// algebra): `[k_x, k_eps, k_prev, j_x, j_eps]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepCoefs {
    pub k_x: f64,
    pub k_eps: f64,
    pub k_prev: f64,
    pub j_x: f64,
    pub j_eps: f64,
}

impl StepCoefs {
    pub fn as_array(&self) -> [f64; 5] {
        [self.k_x, self.k_eps, self.k_prev, self.j_x, self.j_eps]
    }
}

/// Fold the update from `t_s` to `t_t` (previous solver point `t_r`, `None`
/// → Euler first step) into affine coefficients.
pub fn fold_coefs(t_s: f64, t_t: f64, t_r: Option<f64>) -> StepCoefs {
    let (a_s, s_s) = alpha_sigma(t_s);
    let (a_t, s_t) = alpha_sigma(t_t);
    let h = lambda(t_t) - lambda(t_s);
    let e = a_t * (1.0 - (-h).exp());
    let (big_a, big_b) = match t_r {
        None => (1.0, 0.0),
        Some(tr) => {
            let r0 = (lambda(t_s) - lambda(tr)) / h;
            (1.0 + 1.0 / (2.0 * r0), -1.0 / (2.0 * r0))
        }
    };
    let j_x = 1.0 / a_s;
    let j_eps = -s_s / a_s;
    StepCoefs {
        k_x: s_t / s_s + e * big_a * j_x,
        k_eps: e * big_a * j_eps,
        k_prev: e * big_b,
        j_x,
        j_eps,
    }
}

/// Full coefficient table for a trajectory of `num_steps` updates.
pub fn coef_table(num_steps: usize) -> Vec<StepCoefs> {
    let ts = timesteps(num_steps);
    (0..num_steps)
        .map(|i| {
            let t_r = if i > 0 { Some(ts[i - 1]) } else { None };
            fold_coefs(ts[i], ts[i + 1], t_r)
        })
        .collect()
}

/// Host-side solver update (f32, matching the device kernel's arithmetic):
/// returns `(x_next, x0)`. Allocating wrapper over [`apply_step_into`].
pub fn apply_step(x: &[f32], eps: &[f32], x0_prev: &[f32], c: &StepCoefs) -> (Vec<f32>, Vec<f32>) {
    let mut x_next = vec![0.0f32; x.len()];
    let mut x0 = vec![0.0f32; x.len()];
    apply_step_into(x, eps, x0_prev, c, &mut x_next, &mut x0);
    (x_next, x0)
}

/// Solver update into caller-provided output buffers (no allocation).
pub fn apply_step_into(
    x: &[f32],
    eps: &[f32],
    x0_prev: &[f32],
    c: &StepCoefs,
    x_next: &mut [f32],
    x0: &mut [f32],
) {
    debug_assert_eq!(x.len(), eps.len());
    debug_assert_eq!(x.len(), x0_prev.len());
    debug_assert_eq!(x.len(), x_next.len());
    debug_assert_eq!(x.len(), x0.len());
    let (kx, ke, kp, jx, je) = (
        c.k_x as f32,
        c.k_eps as f32,
        c.k_prev as f32,
        c.j_x as f32,
        c.j_eps as f32,
    );
    for i in 0..x.len() {
        x_next[i] = kx * x[i] + ke * eps[i] + kp * x0_prev[i];
        x0[i] = jx * x[i] + je * eps[i];
    }
}

/// Fully in-place solver update: advances `x` to `x_next` and `x0_prev` to
/// the fresh data prediction in their own storage (each element is read
/// before it is written, so no scratch is needed). Bit-identical to
/// [`apply_step`] — the engine's zero-allocation step path.
pub fn apply_step_in_place(x: &mut [f32], eps: &[f32], x0_prev: &mut [f32], c: &StepCoefs) {
    debug_assert_eq!(x.len(), eps.len());
    debug_assert_eq!(x.len(), x0_prev.len());
    let (kx, ke, kp, jx, je) = (
        c.k_x as f32,
        c.k_eps as f32,
        c.k_prev as f32,
        c.j_x as f32,
        c.j_eps as f32,
    );
    for i in 0..x.len() {
        let x_next = kx * x[i] + ke * eps[i] + kp * x0_prev[i];
        let x0 = jx * x[i] + je * eps[i];
        x[i] = x_next;
        x0_prev[i] = x0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vp_identity() {
        for i in 0..=32 {
            let t = i as f64 / 32.0;
            let (a, s) = alpha_sigma(t);
            assert!((a * a + s * s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn alpha_bar_boundaries() {
        assert!((alpha_bar(0.0) - 1.0).abs() < 1e-12);
        assert!(alpha_bar(1.0) < 1e-3);
        // monotone decreasing
        let mut prev = 1.0;
        for i in 1..=64 {
            let v = alpha_bar(i as f64 / 64.0);
            assert!(v <= prev + 1e-15);
            prev = v;
        }
    }

    #[test]
    fn timesteps_grid() {
        let ts = timesteps(20);
        assert_eq!(ts.len(), 21);
        assert_eq!(ts[0], T_MAX);
        assert!((ts[20] - T_MIN).abs() < 1e-12);
        assert!(ts.windows(2).all(|w| w[1] < w[0]));
    }

    #[test]
    fn euler_step_has_no_prev() {
        let ts = timesteps(20);
        let c = fold_coefs(ts[0], ts[1], None);
        assert_eq!(c.k_prev, 0.0);
    }

    #[test]
    fn x0_row_is_data_prediction() {
        let t = 0.6;
        let (a, s) = alpha_sigma(t);
        let c = fold_coefs(t, 0.55, Some(0.65));
        assert!((c.j_x - 1.0 / a).abs() < 1e-12);
        assert!((c.j_eps + s / a).abs() < 1e-12);
    }

    #[test]
    fn apply_step_matches_formula() {
        let c = StepCoefs {
            k_x: 0.9,
            k_eps: -0.1,
            k_prev: 0.05,
            j_x: 1.2,
            j_eps: -0.7,
        };
        let (xn, x0) = apply_step(&[1.0, 2.0], &[0.5, -0.5], &[4.0, 0.0], &c);
        assert!((xn[0] - (0.9 - 0.05 + 0.2)).abs() < 1e-6);
        assert!((x0[1] - (2.4 + 0.35)).abs() < 1e-6);
    }

    /// Same analytic-model convergence test as python's
    /// test_dpmpp_matches_fine_euler_on_analytic_model, proving the Rust
    /// mirror integrates the same ODE to the same accuracy.
    #[test]
    fn solver_tracks_analytic_ode() {
        let run = |steps: usize| -> Vec<f32> {
            let ts = timesteps(steps);
            let mut rng = crate::util::rng::Rng::new(7);
            let mut x = rng.normal_vec(48);
            let mut x0_prev = vec![0.0f32; 48];
            for i in 0..steps {
                let (_, s) = alpha_sigma(ts[i]);
                let eps: Vec<f32> = x.iter().map(|&v| v * s as f32).collect();
                let t_r = if i > 0 { Some(ts[i - 1]) } else { None };
                let c = fold_coefs(ts[i], ts[i + 1], t_r);
                let (xn, x0) = apply_step(&x, &eps, &x0_prev, &c);
                x = xn;
                x0_prev = x0;
            }
            x
        };
        let coarse = run(20);
        let fine = run(400);
        let max_ref = fine.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let err = coarse
            .iter()
            .zip(&fine)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(err / max_ref < 1e-2, "rel err {}", err / max_ref);
    }

    #[test]
    fn timestep_point_matches_table() {
        for steps in [1usize, 7, 20, 50] {
            let ts = timesteps(steps);
            for (i, &t) in ts.iter().enumerate() {
                assert_eq!(t, timestep(i, steps), "steps {steps} point {i}");
            }
        }
    }

    #[test]
    fn in_place_variants_match_apply_step_bitwise() {
        let mut rng = crate::util::rng::Rng::new(42);
        let x = rng.normal_vec(64);
        let eps = rng.normal_vec(64);
        let x0_prev = rng.normal_vec(64);
        let c = fold_coefs(0.6, 0.55, Some(0.65));
        let (xn, x0) = apply_step(&x, &eps, &x0_prev, &c);

        let mut xn2 = vec![0.0f32; 64];
        let mut x02 = vec![0.0f32; 64];
        apply_step_into(&x, &eps, &x0_prev, &c, &mut xn2, &mut x02);
        assert_eq!(xn, xn2);
        assert_eq!(x0, x02);

        let mut x_ip = x.clone();
        let mut x0p_ip = x0_prev.clone();
        apply_step_in_place(&mut x_ip, &eps, &mut x0p_ip, &c);
        assert_eq!(xn, x_ip, "in-place x_next diverged");
        assert_eq!(x0, x0p_ip, "in-place x0 diverged");
    }

    #[test]
    fn coef_table_matches_fold() {
        let table = coef_table(20);
        assert_eq!(table.len(), 20);
        let ts = timesteps(20);
        assert_eq!(table[5], fold_coefs(ts[5], ts[6], Some(ts[4])));
    }
}
