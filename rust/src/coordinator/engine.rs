//! The serving engine: continuation batching over NFE work items.
//!
//! The paper's policies make per-step NFE counts *dynamic* (AG drops the
//! unconditional stream mid-request), so a fixed lock-step batcher wastes
//! slots. This engine treats every network evaluation as a fungible work
//! item — an (x, t, tokens) triple — and packs items from *different
//! requests at different steps* into fixed-batch executions, exactly the
//! continuation-batching idea of Orca/vLLM applied to diffusion guidance.
//!
//! Single-threaded and deterministic: `submit()` adds requests (possible at
//! any time, enabling open-loop arrival processes), `pump()` executes one
//! batch and advances whatever completed, `run()` drains to completion.

use std::collections::VecDeque;

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::request::{Completion, Request, RequestState};
use crate::stats::hist::Histogram;

/// One pending network evaluation.
#[derive(Debug)]
struct WorkItem {
    state_idx: usize,
    slot: usize,
    model: String,
}

/// Batching statistics (§Perf: occupancy is the quantity to keep high).
#[derive(Debug)]
pub struct BatchStats {
    pub batches: usize,
    pub items: usize,
    /// batch-occupancy histogram: items per executed batch
    pub occupancy: Histogram,
}

impl BatchStats {
    fn new(max_bucket: usize) -> BatchStats {
        BatchStats {
            batches: 0,
            items: 0,
            occupancy: Histogram::new(0.5, max_bucket as f64 + 0.5, max_bucket),
        }
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.items as f64 / self.batches as f64
        }
    }
}

/// The engine. Generic over the backend so coordinator tests run on the
/// analytic GMM oracle and production runs on PJRT artifacts.
pub struct Engine<B: Backend> {
    pub backend: B,
    states: Vec<Option<RequestState>>,
    queue: VecDeque<WorkItem>,
    active: usize,
    pub stats: BatchStats,
}

impl<B: Backend> Engine<B> {
    /// Construct an engine over a backend. Fails (rather than panicking)
    /// when the backend reports no batch buckets — a misbuilt artifact set
    /// must surface as an error the server/CLI can report.
    pub fn new(backend: B) -> Result<Engine<B>> {
        let Some(&max_bucket) = backend.buckets().last() else {
            anyhow::bail!(
                "backend reports no batch buckets; cannot size batches \
                 (rebuild the artifacts or fix the backend's bucket list)"
            );
        };
        Ok(Engine {
            backend,
            states: Vec::new(),
            queue: VecDeque::new(),
            active: 0,
            stats: BatchStats::new(max_bucket),
        })
    }

    /// Number of requests still in flight.
    pub fn active(&self) -> usize {
        self.active
    }

    pub fn idle(&self) -> bool {
        self.active == 0
    }

    /// Admit a request; its first step's evals enter the work queue.
    pub fn submit(&mut self, req: Request) {
        let flat_out = self.backend.flat_out(&req.model);
        let state = RequestState::new(req, flat_out);
        let idx = self.states.len();
        self.enqueue_step(&state, idx);
        self.states.push(Some(state));
        self.active += 1;
    }

    fn enqueue_step(&mut self, state: &RequestState, idx: usize) {
        for (slot, _kind) in state.current_evals().iter().enumerate() {
            self.queue.push_back(WorkItem {
                state_idx: idx,
                slot,
                model: state.req.model.clone(),
            });
        }
    }

    /// Execute one batch of work items (same model, up to the largest
    /// bucket) and advance all requests whose step completed. Returns the
    /// completions this round produced.
    pub fn pump(&mut self) -> Result<Vec<Completion>> {
        let Some(front) = self.queue.front() else {
            return Ok(Vec::new());
        };
        let model = front.model.clone();
        let max_bucket = self.backend.max_batch(&model);

        // take up to max_bucket items for this model, preserving FIFO order
        // for the rest.
        let mut batch_items = Vec::new();
        let mut rest = VecDeque::new();
        while let Some(item) = self.queue.pop_front() {
            if item.model == model && batch_items.len() < max_bucket {
                batch_items.push(item);
            } else {
                rest.push_back(item);
            }
        }
        self.queue = rest;

        // build inputs
        let inputs: Vec<_> = batch_items
            .iter()
            .map(|it| {
                let st = self.states[it.state_idx].as_ref().unwrap();
                let kind = st.current_evals()[it.slot];
                st.eval_input(kind)
            })
            .collect();

        let outputs = self.backend.denoise(&model, &inputs)?;
        self.stats.batches += 1;
        self.stats.items += inputs.len();
        self.stats.occupancy.add(inputs.len() as f64);

        // deliver results; collect which states finished their step
        let mut ready = Vec::new();
        for (item, eps) in batch_items.into_iter().zip(outputs) {
            let st = self.states[item.state_idx].as_mut().unwrap();
            if st.deliver(item.slot, eps) {
                ready.push(item.state_idx);
            }
        }

        // advance completed steps (a state can appear once — all its slots
        // deliver before `deliver` returns true exactly once).
        let mut completions = Vec::new();
        for idx in ready {
            let st = self.states[idx].as_mut().unwrap();
            if let Some(done) = st.complete_step() {
                self.states[idx] = None;
                self.active -= 1;
                completions.push(done);
            } else {
                let st = self.states[idx].take().unwrap();
                self.enqueue_step(&st, idx);
                self.states[idx] = Some(st);
            }
        }
        Ok(completions)
    }

    /// Drain all submitted requests to completion.
    pub fn drain(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            let round = self.pump()?;
            out.extend(round);
        }
        // completions arrive in finish order; return in id order for
        // deterministic downstream comparisons.
        out.sort_by_key(|c| c.id);
        Ok(out)
    }

    /// Convenience: submit a batch of requests and drain.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<Vec<Completion>> {
        for r in requests {
            self.submit(r);
        }
        self.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, EvalInput, GmmBackend};
    use crate::coordinator::policy::{ag, cfg, cond_only, PolicyRef};
    use crate::sim::gmm::Gmm;

    fn engine() -> Engine<GmmBackend> {
        Engine::new(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05))).unwrap()
    }

    fn req(id: u64, comp: i32, policy: PolicyRef) -> Request {
        Request::new(id, "gmm", vec![comp, 0, 0, 0], 100 + id, 10, policy)
    }

    /// Same request but with a *shared* seed — policy-comparison tests need
    /// identical starting noise (the paper's same-seed-sequence protocol).
    fn req_seeded(id: u64, comp: i32, policy: PolicyRef) -> Request {
        Request::new(id, "gmm", vec![comp, 0, 0, 0], 777, 10, policy)
    }

    /// A backend with an empty bucket list (misbuilt artifacts).
    struct NoBucketBackend;

    impl Backend for NoBucketBackend {
        fn flat_in(&self, _: &str) -> usize {
            4
        }
        fn flat_out(&self, _: &str) -> usize {
            4
        }
        fn buckets(&self) -> &[usize] {
            &[]
        }
        fn denoise(&mut self, _: &str, _: &[EvalInput]) -> Result<Vec<Vec<f32>>> {
            Ok(Vec::new())
        }
        fn models(&self) -> Vec<String> {
            Vec::new()
        }
    }

    #[test]
    fn empty_bucket_list_is_an_error_not_a_panic() {
        let err = match Engine::new(NoBucketBackend) {
            Ok(_) => panic!("expected an error"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("bucket"), "{err}");
    }

    #[test]
    fn single_cfg_request_runs_to_completion() {
        let mut e = engine();
        let out = e.run(vec![req(0, 1, cfg(2.0))]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].nfes, 20);
        assert_eq!(out[0].cfg_steps, 10);
        assert_eq!(out[0].image.len(), 8);
    }

    #[test]
    fn ag_saves_nfes_on_the_analytic_model() {
        let mut e = engine();
        let out = e
            .run(vec![
                req_seeded(0, 1, cfg(2.0)),
                req_seeded(1, 1, ag(2.0, 0.995)),
            ])
            .unwrap();
        let cfg = &out[0];
        let ag = &out[1];
        assert!(ag.nfes < cfg.nfes, "AG {} vs CFG {}", ag.nfes, cfg.nfes);
        assert!(ag.truncated_at.is_some());
        // the trajectories share the guided prefix → same gammas up to
        // (and including) the truncation step.
        let k = ag.truncated_at.unwrap();
        for i in 0..=k {
            assert!((ag.gammas[i] - cfg.gammas[i]).abs() < 1e-9, "step {i}");
        }
    }

    #[test]
    fn ag_with_unreachable_threshold_replicates_cfg_exactly() {
        let mut e = engine();
        let out = e
            .run(vec![
                req_seeded(0, 2, cfg(2.0)),
                req_seeded(1, 2, ag(2.0, 1.01)),
            ])
            .unwrap();
        assert_eq!(out[0].image, out[1].image);
        assert_eq!(out[0].nfes, out[1].nfes);
    }

    #[test]
    fn batching_packs_items_across_requests() {
        let mut e = engine();
        let reqs: Vec<_> = (0..8)
            .map(|i| req(i, 1 + (i % 4) as i32, cfg(2.0)))
            .collect();
        let out = e.run(reqs).unwrap();
        assert_eq!(out.len(), 8);
        // 8 requests * 2 evals = 16 items per step → exactly one max-bucket
        // batch per step round.
        assert!(e.stats.mean_occupancy() > 15.9, "{}", e.stats.mean_occupancy());
        assert_eq!(e.stats.items, 8 * 10 * 2);
    }

    #[test]
    fn mixed_policy_traffic_fills_freed_slots() {
        // 8 AG requests that truncate quickly: total items must be well
        // below the CFG cost, and the batcher keeps packing the remaining
        // conditional items together (occupancy stays above 8 = #requests).
        let mut e = engine();
        let reqs: Vec<_> = (0..8)
            .map(|i| req(i, 1, ag(2.0, 0.99)))
            .collect();
        let out = e.run(reqs).unwrap();
        let total: usize = out.iter().map(|c| c.nfes).sum();
        assert!(total < 8 * 20, "AG saved nothing: {total}");
        assert_eq!(e.stats.items, total);
        assert!(e.stats.mean_occupancy() >= 8.0);
    }

    #[test]
    fn incremental_submission_between_pumps() {
        let mut e = engine();
        e.submit(req(0, 1, cfg(2.0)));
        let mut done = Vec::new();
        let mut pumped = 0;
        while !e.idle() {
            done.extend(e.pump().unwrap());
            pumped += 1;
            if pumped == 3 {
                e.submit(req(1, 2, cfg(2.0)));
            }
        }
        assert_eq!(done.len(), 2);
    }

    #[test]
    fn seeds_make_runs_reproducible() {
        let run = || {
            let mut e = engine();
            e.run(vec![req(0, 3, cfg(2.0))]).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a[0].image, b[0].image);
    }

    #[test]
    fn cond_only_is_half_the_cost_of_cfg() {
        let mut e = engine();
        let out = e
            .run(vec![req(0, 1, cfg(2.0)), req(1, 1, cond_only())])
            .unwrap();
        assert_eq!(out[0].nfes, 2 * out[1].nfes);
    }

    #[test]
    fn empty_run_is_fine() {
        let mut e = engine();
        assert!(e.run(vec![]).unwrap().is_empty());
        assert!(e.pump().unwrap().is_empty());
    }
}
