//! The serving engine: continuation batching over NFE work items.
//!
//! The paper's policies make per-step NFE counts *dynamic* (AG drops the
//! unconditional stream mid-request), so a fixed lock-step batcher wastes
//! slots. This engine treats every network evaluation as a fungible work
//! item — an (x, t, tokens) triple — and packs items from *different
//! requests at different steps* into fixed-batch executions, exactly the
//! continuation-batching idea of Orca/vLLM applied to diffusion guidance.
//!
//! Which items go into the next batch is owned by a pluggable
//! [`Scheduler`] ([`crate::sched`]): [`Fifo`] (the default) preserves
//! strict arrival order bit-for-bit, while `CostAware`/`Deadline`/
//! `FairShare` exploit the live per-request cost estimate
//! ([`crate::coordinator::request::RequestState::remaining_nfes`]) that
//! policy truncation keeps tightening. An [`Admission`] budget bounds the
//! queue (in-flight requests and queued NFEs) and a [`Telemetry`] registry
//! tracks occupancy, queue depth, per-policy NFE totals/savings,
//! per-request queue-wait vs execute time, and per-policy deadline misses.
//!
//! Single-threaded and deterministic: `submit()`/`try_submit()` add
//! requests (possible at any time, enabling open-loop arrival processes),
//! `pump()` executes one batch and advances whatever completed, `run()`
//! drains to completion. Scheduling reorders *work*, never *results*: a
//! request's completion is bit-identical under every scheduler.
//!
//! # §Perf: buffer ownership & parallel execution
//!
//! The per-step path is allocation-free at steady state (pinned by
//! `rust/tests/zero_alloc.rs` for the serial engine and
//! `rust/tests/par_zero_alloc.rs` for the sharded one). Ownership flows
//! one way:
//!
//! * the **engine** owns the reusable [`BatchBuf`]/[`BatchOut`] pair (one
//!   packed `batch × flat` buffer each, capacity retained across pumps),
//!   the scheduler's pop buffer, and the [`BufPool`];
//! * the **pool** lends fixed-length score buffers: `pump` copies each
//!   result row into a pooled buffer and hands it to the request;
//! * the **request state** holds those buffers only within a step —
//!   [`RequestState::complete_step`] fuses combine+gamma, advances the
//!   solver in place, and returns every non-recorded buffer to the pool.
//!
//! New policies/schedulers must not reintroduce per-step allocations:
//! request inputs are written via `fill_eval_input` (never cloned), hot
//! telemetry goes through pre-computed [`MetricKey`]s, and anything that
//! must outlive a step (history, completions) is the only thing allowed to
//! allocate.
//!
//! ## The row/slot sharding rule
//!
//! [`Engine::set_workers`] attaches an [`ExecPool`] and the two
//! embarrassingly parallel hot loops shard across it:
//!
//! 1. **Batch rows** — `pump` executes through
//!    [`Backend::denoise_into_par`], and a host-math backend (the GMM
//!    oracle) computes each packed row on a worker lane, writing its
//!    disjoint [`BatchOut`] row with a lane-local scratch.
//! 2. **Step completions** — every request whose step finished runs
//!    `complete_step` on a lane, against a pre-staged
//!    [`StepBufs`](crate::coordinator::bufpool::StepBufs): the engine
//!    thread takes the one spare buffer a combining plan needs *before*
//!    the region and drains every returned buffer back into the pool
//!    *after* it, so the [`BufPool`] stays single-owner.
//!
//! Parallelism is strictly *across* rows/slots: the float-op order within
//! a row or a request's step is byte-for-byte the serial code's, so
//! completions are bit-identical for every `--workers` value (pinned by
//! `rust/tests/sched_integration.rs`). Everything stateful — scheduler
//! pops, admission, pool take/put, telemetry, and the not-`Send` PJRT
//! client — stays on the engine thread; see the [`crate::exec`] docs for
//! the pool's own contract.
//!
//! # §Scale: engine fleet
//!
//! One engine is one *shard* of a fleet ([`crate::fleet`]): the serving
//! front-end runs N replicas, each on its own thread with its own backend
//! instance, scheduler, worker pool and buffer pool — the unit of scale-out
//! that preserves the one-thread-per-device PJRT boundary (the multi-client
//! story is one engine per device). The engine stays single-threaded and
//! oblivious to the fleet; the fleet-facing surface is just:
//!
//! * [`Engine::load`] — the [`EngineLoad`] snapshot (`active`,
//!   `queued_nfes`, `queue_depth`) the shard thread publishes after every
//!   message/pump, which the router's least-loaded placement reads;
//! * [`Engine::drain`] — run the queue to empty, the primitive behind the
//!   fleet's graceful `{"cmd": "drain"}` quiesce;
//! * [`Engine::telemetry`]/[`Engine::telemetry_mut`] — the per-shard
//!   registry the fleet merges under a `shard=` label
//!   ([`crate::sched::Telemetry::absorb`]).
//!
//! Because a request's output depends only on its own seed and policy —
//! batching packs work but never mixes math across rows — placement
//! changes *which* engine batches a request, never its bytes: completions
//! are identical for any shard count/placement (pinned by
//! `rust/tests/fleet_integration.rs`).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::backend::{Backend, BatchBuf, BatchOut};
use crate::chaos::fault::{classify, FaultClass, JitterBackoff};
use crate::coordinator::bufpool::{BufPool, StepBufs};
use crate::coordinator::checkpoint::{CheckpointStore, RequestCheckpoint};
use crate::coordinator::policy::PolicyState;
use crate::coordinator::request::{Completion, EvalKind, Request, RequestState};
use crate::exec::{ExecPool, SliceShards};
use crate::sched::{
    Admission, AdmitError, Fifo, MetricKey, RequestMeta, Scheduler, Telemetry, WorkItem,
    STAGE_HIST,
};
use crate::trace::{self, EvalSet, Stage, TraceRecorder};

/// Queue-wait / execute-time histograms: 0..10 s in 100 ms bins.
const LATENCY_HIST: (f64, f64, usize) = (0.0, 10_000.0, 100);

/// Retry-backoff histogram (`retry_backoff_ms`): 0..4 s in 50 ms bins.
const BACKOFF_HIST: (f64, f64, usize) = (0.0, 4_000.0, 80);

/// Checkpoint-size histogram (`checkpoint_bytes`): 0..64 KiB in 1 KiB
/// bins — sized for the serialized form of one [`RequestCheckpoint`].
const CKPT_HIST: (f64, f64, usize) = (0.0, 65_536.0, 64);

/// Default decorrelated-jitter base delay for transient-batch retries
/// (§Robustness; overridable via [`Engine::set_batch_retries`]).
pub const DEFAULT_RETRY_BASE_MS: u64 = 25;

/// Default retry-backoff delay cap.
pub const DEFAULT_RETRY_CAP_MS: u64 = 2_000;

/// Largest step count accepted through the validated front door
/// ([`Engine::try_submit`]); the unvalidated [`Engine::submit`] preload
/// path is not capped.
pub const MAX_STEPS: usize = 100_000;

/// Point-in-time load snapshot (§Scale: engine fleet). The shard thread
/// publishes this after every message/pump; the fleet router's
/// least-loaded placement and global admission read the published values.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineLoad {
    /// Requests in flight (queued or executing).
    pub active: usize,
    /// Total remaining-NFE estimate across in-flight requests — the
    /// honest unit of pending work.
    pub queued_nfes: usize,
    /// Work items pending in the scheduler.
    pub queue_depth: usize,
}

/// §Robustness: one re-placeable request pulled off a dying engine by
/// [`Engine::salvage_all`]. `checkpoint` is `None` for never-started
/// requests (restart from step 0) and the boxed mid-flight snapshot for
/// started ones; `cost` is the engine's live remaining-NFE estimate at
/// death — what the router should reserve for re-placement.
#[derive(Debug)]
pub struct Salvaged {
    pub req: Request,
    pub checkpoint: Option<Box<RequestCheckpoint>>,
    pub cost: usize,
}

/// One per-step progress sample for a request that opted in with
/// `progress: true` — the payload of the reactor's streaming
/// `{"event":"progress",..}` line, cut from the same guidance-decision
/// data the trace ring records. Buffered in a reusable engine-owned Vec
/// and drained by the shard loop after each pump
/// ([`Engine::drain_progress`]); requests that never opt in push nothing,
/// so the zero-allocation steady state is untouched.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProgressNote {
    /// engine-assigned request id
    pub id: u64,
    /// step that just completed (0-based)
    pub step: u32,
    /// total steps the request asked for
    pub of: u32,
    /// this step's guidance signal (Eq. 7 cosine; NaN when undefined)
    pub gamma: f32,
    /// NFEs spent so far
    pub nfes: u32,
}

/// Engine-side per-request bookkeeping: scheduling labels, the live
/// remaining-cost estimate, and queue-wait/execute timing.
#[derive(Debug)]
struct Meta {
    id: u64,
    client: Arc<str>,
    /// canonical policy kind — the `policy=` telemetry label
    policy: String,
    priority: i32,
    /// absolute deadline on the engine clock (ms since engine start),
    /// anchored from the request's arrival-relative `deadline_ms`
    deadline_ms: Option<u64>,
    /// current remaining-NFE estimate, kept in lock-step with deliveries
    cost: usize,
    /// worst-case total at admission (for the NFEs-saved counter)
    max_nfes: usize,
    submitted: Instant,
    first_exec: Option<Instant>,
    /// §Observability: interned policy id for guidance events
    policy_id: u16,
    /// §Observability: the request's own span timeline (`Some` iff the
    /// request opted in with `trace: true`); capacity reserved at
    /// admission, appended via [`trace::push_capped`] only — never grows
    /// inside `pump()`
    timeline: Option<Vec<trace::Event>>,
    /// the request opted into per-step progress streaming
    progress: bool,
    /// total steps (denominator of a progress line's `step k of T`)
    steps: u32,
}

/// §Observability: what a ready slot's step looked like *before*
/// `complete_step` replans it — the guidance event must record the plan
/// that actually executed.
#[derive(Debug, Clone, Copy)]
struct StepSnap {
    step: u32,
    evals: EvalSet,
}

impl Default for StepSnap {
    fn default() -> StepSnap {
        StepSnap {
            step: 0,
            evals: EvalSet::Cond,
        }
    }
}

/// The engine. Generic over the backend so coordinator tests run on the
/// analytic GMM oracle and production runs on PJRT artifacts.
pub struct Engine<B: Backend> {
    pub backend: B,
    sched: Box<dyn Scheduler>,
    admission: Admission,
    states: Vec<Option<RequestState>>,
    metas: Vec<Option<Meta>>,
    /// completed slots available for reuse, so a long-running server does
    /// not grow `states` monotonically
    free: Vec<usize>,
    active: usize,
    /// total remaining-NFE estimate across all in-flight requests
    queued_nfes: usize,
    batches: usize,
    items: usize,
    max_bucket: usize,
    /// clock origin for anchoring arrival-relative deadlines: EDF needs
    /// every deadline on ONE clock, and client clocks are not it
    epoch: Instant,
    telemetry: Telemetry,
    /// §Perf: the reusable per-pump buffers (see module docs)
    pool: BufPool,
    batch: BatchBuf,
    out: BatchOut,
    batch_items: Vec<WorkItem>,
    ready: Vec<usize>,
    /// §Perf: the worker pool the hot loops shard across (serial by
    /// default; [`Engine::set_workers`])
    exec: ExecPool,
    /// per-ready-slot buffer staging for parallel step completion
    /// (capacity grows to the high-water ready count, then stable)
    step_bufs: Vec<StepBufs>,
    /// per-ready-slot completion results from the parallel region
    ready_done: Vec<Option<Completion>>,
    /// §Observability: per-ready-slot (step, evals) snapshot taken before
    /// completion replans (capacity tracks `step_bufs`)
    step_snap: Vec<StepSnap>,
    /// §Observability: the span ring + policy table + trace clock
    /// (preallocated here so steady-state recording never allocates)
    tracer: TraceRecorder,
    /// §Scale: fleet shard id stamped onto exported span batches
    shard: usize,
    /// live requests per client id, for the per-client admission quota
    /// (`""` = anonymous)
    clients_in_flight: HashMap<Arc<str>, usize>,
    /// interned anonymous client id (avoids an Arc allocation per
    /// anonymous admission)
    anon_client: Arc<str>,
    /// pre-computed keys for the per-pump metrics (no label allocation on
    /// the hot path)
    k_batch_occupancy: MetricKey,
    k_active: MetricKey,
    k_queue_depth: MetricKey,
    k_queued_nfes: MetricKey,
    k_worker_lanes: MetricKey,
    k_worker_occupancy: MetricKey,
    k_parallel_efficiency: MetricKey,
    k_stage_batch: MetricKey,
    k_stage_denoise: MetricKey,
    k_stage_combine: MetricKey,
    k_batch_retries: MetricKey,
    k_retry_backoff: MetricKey,
    /// §Robustness: transient-batch-failure retry budget per pump (0 —
    /// the default — is the historical fail-on-first-error behaviour)
    max_batch_retries: usize,
    /// Seeded decorrelated-jitter pacing between retry attempts (the
    /// fleet seeds each shard with its index, so shards desynchronize
    /// while every run stays reproducible)
    backoff: JitterBackoff,
    /// §Robustness: per-slot mid-flight checkpoints (`--checkpoint-steps`;
    /// disabled by default — zero registrations, zero captures)
    ckpts: CheckpointStore,
    k_checkpoint_bytes: MetricKey,
    /// per-step progress samples for opted-in requests, buffered between
    /// [`Self::pump`] and [`Self::drain_progress`] (reused — the Vec is
    /// swapped out whole by the drain, so capacity cycles, and requests
    /// that never opt in keep this permanently empty)
    progress_notes: Vec<ProgressNote>,
    k_requests_canceled: MetricKey,
}

impl<B: Backend> Engine<B> {
    /// Construct an engine over a backend with the default [`Fifo`]
    /// scheduler and no admission budget. Fails (rather than panicking)
    /// when the backend reports no batch buckets — a misbuilt artifact set
    /// must surface as an error the server/CLI can report.
    pub fn new(backend: B) -> Result<Engine<B>> {
        Engine::with_scheduler(backend, Box::new(Fifo::default()), Admission::unlimited())
    }

    /// Construct with an explicit scheduling discipline and admission
    /// budget — the serving front-end's entry point
    /// (`agd serve --scheduler .. --max-queued-nfes ..`).
    pub fn with_scheduler(
        backend: B,
        sched: Box<dyn Scheduler>,
        admission: Admission,
    ) -> Result<Engine<B>> {
        let Some(&max_bucket) = backend.buckets().last() else {
            anyhow::bail!(
                "backend reports no batch buckets; cannot size batches \
                 (rebuild the artifacts or fix the backend's bucket list)"
            );
        };
        let mut telemetry = Telemetry::new();
        let k_batch_occupancy = telemetry.metric_key("batch_occupancy", &[]);
        let k_active = telemetry.metric_key("active_requests", &[]);
        let k_queue_depth = telemetry.metric_key("queue_depth", &[]);
        let k_queued_nfes = telemetry.metric_key("queued_nfes", &[]);
        let k_worker_lanes = telemetry.metric_key("worker_lanes", &[]);
        let k_worker_occupancy = telemetry.metric_key("worker_occupancy", &[]);
        let k_parallel_efficiency = telemetry.metric_key("parallel_efficiency", &[]);
        let k_stage_batch = telemetry.metric_key("stage_ms", &[("stage", "batch")]);
        let k_stage_denoise = telemetry.metric_key("stage_ms", &[("stage", "denoise")]);
        let k_stage_combine = telemetry.metric_key("stage_ms", &[("stage", "combine")]);
        let k_batch_retries =
            telemetry.metric_key("batch_retries_total", &[("class", "transient")]);
        let k_retry_backoff = telemetry.metric_key("retry_backoff_ms", &[]);
        let k_checkpoint_bytes = telemetry.metric_key("checkpoint_bytes", &[]);
        let k_requests_canceled = telemetry.metric_key("requests_canceled_total", &[]);
        Ok(Engine {
            backend,
            sched,
            admission,
            states: Vec::new(),
            metas: Vec::new(),
            free: Vec::new(),
            active: 0,
            queued_nfes: 0,
            batches: 0,
            items: 0,
            max_bucket,
            epoch: Instant::now(),
            telemetry,
            pool: BufPool::new(),
            batch: BatchBuf::default(),
            out: BatchOut::default(),
            batch_items: Vec::new(),
            ready: Vec::new(),
            exec: ExecPool::serial(),
            step_bufs: Vec::new(),
            ready_done: Vec::new(),
            step_snap: Vec::new(),
            tracer: TraceRecorder::new(trace::DEFAULT_SPAN_CAP),
            shard: 0,
            clients_in_flight: HashMap::new(),
            anon_client: Arc::from(""),
            k_batch_occupancy,
            k_active,
            k_queue_depth,
            k_queued_nfes,
            k_worker_lanes,
            k_worker_occupancy,
            k_parallel_efficiency,
            k_stage_batch,
            k_stage_denoise,
            k_stage_combine,
            k_batch_retries,
            k_retry_backoff,
            max_batch_retries: 0,
            backoff: JitterBackoff::new(DEFAULT_RETRY_BASE_MS, DEFAULT_RETRY_CAP_MS, 0),
            ckpts: CheckpointStore::default(),
            k_checkpoint_bytes,
            progress_notes: Vec::new(),
            k_requests_canceled,
        })
    }

    /// §Robustness: arm per-request solver-state checkpointing — a
    /// resumable snapshot after every `every`-th completed step
    /// (`agd serve --checkpoint-steps`). `0` (the default) disables the
    /// store entirely: no buffers are registered and `pump()` is byte- and
    /// allocation-identical to the un-checkpointed engine. Armed, the
    /// steady-state capture is still allocation-free — buffers are sized
    /// at admission and rewritten in place (pinned by
    /// `rust/tests/ckpt_zero_alloc.rs`).
    pub fn set_checkpoints(&mut self, every: usize) {
        self.ckpts.set_every(every);
    }

    /// §Robustness: retry transient batch failures up to `max` times per
    /// pump before escalating to a fatal pump error, pacing attempts with
    /// a seeded decorrelated-jitter backoff (`agd serve
    /// --max-batch-retries`). `0` restores the historical behaviour:
    /// every backend error is fatal on first sight. Only errors that
    /// classify as [`FaultClass::Transient`] (typed
    /// [`crate::chaos::BackendFault`]s today) are retried — an unknown
    /// error is fatal, so a real backend bug cannot spin here.
    pub fn set_batch_retries(&mut self, max: usize, base_ms: u64, cap_ms: u64, seed: u64) {
        self.max_batch_retries = max;
        self.backoff = JitterBackoff::new(base_ms, cap_ms, seed);
    }

    /// §Scale: stamp the fleet shard id onto exported span batches (the
    /// standalone engine is shard 0).
    pub fn set_shard(&mut self, shard: usize) {
        self.shard = shard;
    }

    /// §Observability: snapshot and clear the span ring, stamped with
    /// this engine's shard id. The dropped total is monotonic across
    /// drains.
    pub fn drain_spans(&mut self) -> trace::SpanBatch {
        let mut batch = self.tracer.drain();
        batch.shard = self.shard;
        batch
    }

    /// Span-ring events overwritten before being drained (monotonic).
    pub fn spans_dropped(&self) -> u64 {
        self.tracer.dropped()
    }

    /// Events currently waiting in the span ring.
    pub fn spans_pending(&self) -> usize {
        self.tracer.len()
    }

    /// Attach a worker pool with `workers` total compute lanes (§Perf:
    /// parallel execution; `agd serve --workers N`). `1` (the
    /// construction default) is the serial engine — no threads, the exact
    /// historical code path. Completions are bit-identical for every
    /// value; only throughput changes. Spawns the pool immediately, once.
    pub fn set_workers(&mut self, workers: usize) {
        if workers.max(1) != self.exec.lanes() {
            self.exec = ExecPool::new(workers);
        }
        let lanes = self.exec.lanes() as f64;
        self.telemetry.set_gauge_key(&self.k_worker_lanes, lanes);
    }

    /// Compute lanes the engine executes on (1 = serial).
    pub fn workers(&self) -> usize {
        self.exec.lanes()
    }

    /// Number of requests still in flight.
    pub fn active(&self) -> usize {
        self.active
    }

    pub fn idle(&self) -> bool {
        self.active == 0
    }

    /// Pending work items in the scheduler.
    pub fn queue_len(&self) -> usize {
        self.sched.len()
    }

    /// Total remaining-NFE estimate across in-flight requests — the
    /// quantity the admission budget bounds.
    pub fn queued_nfes(&self) -> usize {
        self.queued_nfes
    }

    /// Batches executed so far.
    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Work items executed so far.
    pub fn items(&self) -> usize {
        self.items
    }

    /// Mean items per executed batch (§Perf: the quantity to keep high).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.items as f64 / self.batches as f64
        }
    }

    /// Wire name of the active scheduling discipline.
    pub fn scheduler_name(&self) -> &'static str {
        self.sched.name()
    }

    /// The metrics registry.
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Mutable access to the metrics registry — for front-end-level
    /// counters that live outside the engine's own bookkeeping (e.g. the
    /// fleet's `deadline_shed_total`).
    pub fn telemetry_mut(&mut self) -> &mut Telemetry {
        &mut self.telemetry
    }

    /// Load snapshot for the fleet router (§Scale: engine fleet).
    pub fn load(&self) -> EngineLoad {
        EngineLoad {
            active: self.active,
            queued_nfes: self.queued_nfes,
            queue_depth: self.sched.len(),
        }
    }

    /// The engine's buffer pool (tests pin its recycling behaviour).
    pub fn pool(&self) -> &BufPool {
        &self.pool
    }

    /// Request slots ever allocated (tests pin the free-list reuse).
    pub fn state_slots(&self) -> usize {
        self.states.len()
    }

    /// One-line stats snapshot for the server's `{"cmd": "stats"}`:
    /// scheduler, live queue gauges, batch counters, and the full
    /// telemetry registry.
    pub fn stats_json(&self) -> crate::util::json::Value {
        use crate::util::json::{num, obj, s};
        obj(vec![
            ("scheduler", s(self.sched.name())),
            ("active", num(self.active as f64)),
            ("queue_depth", num(self.sched.len() as f64)),
            ("queued_nfes", num(self.queued_nfes as f64)),
            ("batches", num(self.batches as f64)),
            ("items", num(self.items as f64)),
            ("mean_occupancy", num(self.mean_occupancy())),
            ("spans_pending", num(self.tracer.len() as f64)),
            ("spans_dropped_total", num(self.tracer.dropped() as f64)),
            ("telemetry", self.telemetry.to_json()),
        ])
    }

    /// Malformed-request checks shared by the serving front door: a bad
    /// request must be refused here with a typed error — once admitted it
    /// would either trip a state-machine assert or poison a whole batch
    /// mid-pump (which the server treats as fatal). Shape coverage: every
    /// eval the policy plans under a fresh state (a superset of any
    /// later, truncated state's kinds for the built-in policies) must fit
    /// the model's flat input exactly.
    fn validate(&self, req: &Request) -> Result<(), AdmitError> {
        if req.steps == 0 {
            return Err(AdmitError::Invalid {
                reason: "steps must be at least 1",
            });
        }
        // bound the admission-path work (this plan scan and `max_nfes` are
        // both O(steps)) against absurd client-controlled step counts;
        // generous — the paper's protocols use 20..1000 steps
        if req.steps > MAX_STEPS {
            return Err(AdmitError::Invalid {
                reason: "steps exceeds the supported maximum",
            });
        }
        if req.tokens.is_empty() {
            return Err(AdmitError::Invalid {
                reason: "tokens must be non-empty (all-zero = unconditional)",
            });
        }
        if let Err(reason) = self.backend.validate_tokens(&req.model, &req.tokens) {
            return Err(AdmitError::Invalid { reason });
        }
        if let Some(neg) = &req.neg_tokens {
            if neg.len() != req.tokens.len() {
                return Err(AdmitError::Invalid {
                    reason: "neg_tokens width must match tokens width",
                });
            }
            if let Err(reason) = self.backend.validate_tokens(&req.model, neg) {
                return Err(AdmitError::Invalid { reason });
            }
        }
        let flat_out = self.backend.flat_out(&req.model);
        let flat_in = self.backend.flat_in(&req.model);
        if let Some(src) = &req.src_image {
            if src.len() != flat_out {
                return Err(AdmitError::Invalid {
                    reason: "src_image length must equal the model's flat output length",
                });
            }
        }
        if let Some(noise) = &req.init_noise {
            if noise.len() != flat_out {
                return Err(AdmitError::Invalid {
                    reason: "init_noise length must equal the model's flat output length",
                });
            }
        }
        let state = PolicyState::new();
        for step in 0..req.steps {
            let plan = req.policy.plan(step, req.steps, &state);
            for &kind in RequestState::evals_for(&plan) {
                let edit = req.src_image.is_some()
                    && matches!(
                        kind,
                        EvalKind::EditFull | EvalKind::EditImg | EvalKind::EditNull
                    );
                let need = if edit {
                    flat_out + req.src_image.as_ref().unwrap().len()
                } else {
                    flat_out
                };
                if need != flat_in {
                    return Err(AdmitError::Invalid {
                        reason: "policy/model shape mismatch: a planned eval's input \
                                 length does not match the model's flat input \
                                 (editing policies need an editing model and vice versa)",
                    });
                }
            }
        }
        Ok(())
    }

    /// Admit a request against the shape checks and the admission budget;
    /// on rejection the request is dropped and the caller replies
    /// `invalid_request`/`queue_full`. In-flight requests are never
    /// affected by a rejection.
    pub fn try_submit(&mut self, req: Request) -> Result<(), AdmitError> {
        if let Err(e) = self.validate(&req) {
            self.telemetry.inc("requests_rejected_total", &[], 1);
            return Err(e);
        }
        let cost = req.policy.max_nfes(req.steps);
        if let Err(e) = self.admission.check(self.active, self.queued_nfes, cost) {
            self.telemetry.inc("requests_rejected_total", &[], 1);
            return Err(e);
        }
        // per-client quota: one client cannot consume the whole global
        // budget (anonymous requests share the "" lane, like fair-share)
        let client = req
            .client_id
            .clone()
            .unwrap_or_else(|| self.anon_client.clone());
        let in_flight = self.clients_in_flight.get(&client).copied().unwrap_or(0);
        if let Err(e) = self.admission.check_client(&client, in_flight) {
            self.telemetry.inc("requests_rejected_total", &[], 1);
            let name: &str = &client;
            self.telemetry
                .inc("client_quota_rejected_total", &[("client", name)], 1);
            return Err(e);
        }
        self.submit_costed(req, cost);
        Ok(())
    }

    /// Admit a request unconditionally; its first step's evals enter the
    /// work queue. (Drain-mode benches pre-load entire workloads through
    /// this path on purpose; serving front-ends go through
    /// [`Self::try_submit`].)
    pub fn submit(&mut self, req: Request) {
        let cost = req.policy.max_nfes(req.steps);
        self.submit_costed(req, cost);
    }

    /// §Robustness: admit a salvaged mid-flight request from its
    /// checkpoint. Runs the same shape validation and admission/quota
    /// checks as [`Self::try_submit`] — a resumed request re-enters the
    /// queue like fresh work, except that its charged cost is the
    /// *remaining* NFE estimate at the checkpointed step, so cost-aware
    /// scheduling and the queued-NFE budget see the truth, not the
    /// original worst case.
    pub fn try_resume(&mut self, req: Request, ck: &RequestCheckpoint) -> Result<(), AdmitError> {
        if let Err(e) = self.validate(&req) {
            self.telemetry.inc("requests_rejected_total", &[], 1);
            return Err(e);
        }
        let flat_out = self.backend.flat_out(&req.model);
        if ck.step == 0
            || ck.step >= req.steps
            || ck.x.len() != flat_out
            || ck.x0_prev.len() != flat_out
        {
            self.telemetry.inc("requests_rejected_total", &[], 1);
            return Err(AdmitError::Invalid {
                reason: "checkpoint does not fit the request \
                         (step out of range or latent shape mismatch)",
            });
        }
        let max_nfes = req.policy.max_nfes(req.steps);
        let state = RequestState::resume(req, flat_out, ck);
        let cost = state.remaining_nfes();
        if let Err(e) = self.admission.check(self.active, self.queued_nfes, cost) {
            self.telemetry.inc("requests_rejected_total", &[], 1);
            return Err(e);
        }
        let client = state
            .req
            .client_id
            .clone()
            .unwrap_or_else(|| self.anon_client.clone());
        let in_flight = self.clients_in_flight.get(&client).copied().unwrap_or(0);
        if let Err(e) = self.admission.check_client(&client, in_flight) {
            self.telemetry.inc("requests_rejected_total", &[], 1);
            let name: &str = &client;
            self.telemetry
                .inc("client_quota_rejected_total", &[("client", name)], 1);
            return Err(e);
        }
        self.enroll(state, cost, max_nfes);
        Ok(())
    }

    /// Shared admission tail: the `cost` the caller checked/charged is the
    /// single value used for the queued-NFE accounting, so the admission
    /// budget and the bookkeeping cannot drift.
    fn submit_costed(&mut self, req: Request, cost: usize) {
        let flat_out = self.backend.flat_out(&req.model);
        let state = RequestState::new(req, flat_out);
        // `max_nfes` (plan cost over a fresh state) and the state machine's
        // own estimate agree for every StepPlan variant today; catch any
        // future divergence in tests rather than drifting silently
        debug_assert_eq!(cost, state.remaining_nfes());
        self.enroll(state, cost, cost);
    }

    /// Enrollment tail shared by fresh admissions and checkpoint resumes:
    /// slot assignment, meta/bookkeeping, first enqueue. `cost` is the
    /// live remaining-NFE estimate (equal to `max_nfes` for fresh work);
    /// `max_nfes` stays the request's own full worst case so the
    /// NFEs-saved ledger is placement-independent.
    fn enroll(&mut self, state: RequestState, cost: usize, max_nfes: usize) {
        let submitted = Instant::now();
        // anchor the arrival-relative deadline to the engine clock so EDF
        // compares like with like regardless of client clocks
        let arrival_ms = submitted.saturating_duration_since(self.epoch).as_millis() as u64;
        let policy = state.req.policy.kind();
        let policy_id = self.tracer.intern(&policy);
        // §Observability: pre-engine lifecycle spans. The front end stamps
        // *durations* on the request; start times are reconstructed
        // backwards from "now" on this recorder's clock, so a timeline is
        // monotonic even though admission/placement ran on another thread.
        let timeline = if state.req.trace {
            let now = self.tracer.now_us();
            let start_q = now.saturating_sub(state.req.span_queue_us);
            let start_p = start_q.saturating_sub(state.req.span_placement_us);
            let start_a = start_p.saturating_sub(state.req.span_admission_us);
            // 4 per-step events (batch/denoise/combine/guidance) + the 3
            // pre-engine spans + the final complete span, capped so a
            // MAX_STEPS request cannot reserve an absurd buffer
            let cap = (4 * state.req.steps + 4).min(trace::DEFAULT_SPAN_CAP);
            let mut tl = Vec::with_capacity(cap);
            for (stage, start_us, dur_us) in [
                (Stage::Admission, start_a, state.req.span_admission_us),
                (Stage::Placement, start_p, state.req.span_placement_us),
                (Stage::Queue, start_q, state.req.span_queue_us),
            ] {
                let ev = trace::Event::Span {
                    req: state.req.id,
                    stage,
                    start_us,
                    dur_us,
                };
                self.tracer.record(ev);
                trace::push_capped(&mut tl, ev);
            }
            Some(tl)
        } else {
            None
        };
        let meta = Meta {
            id: state.req.id,
            client: state
                .req
                .client_id
                .clone()
                .unwrap_or_else(|| self.anon_client.clone()),
            policy,
            priority: state.req.priority,
            deadline_ms: state
                .req
                .deadline_ms
                .map(|rel| rel.saturating_add(arrival_ms)),
            cost,
            max_nfes,
            submitted,
            first_exec: None,
            policy_id,
            timeline,
            progress: state.req.progress,
            steps: state.req.steps as u32,
        };
        // per-client live count for the admission quota; unwound when the
        // request completes
        *self
            .clients_in_flight
            .entry(meta.client.clone())
            .or_insert(0) += 1;
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.states.push(None);
                self.metas.push(None);
                self.states.len() - 1
            }
        };
        self.metas[idx] = Some(meta);
        // §Robustness: size this slot's checkpoint buffers now, off the
        // steady-state pump (no-op with checkpointing disabled)
        self.ckpts.register(idx, state.x.len(), state.req.steps);
        self.enqueue_step(&state, idx);
        self.states[idx] = Some(state);
        self.active += 1;
        self.queued_nfes += cost;
        self.telemetry.inc("requests_admitted_total", &[], 1);
        self.update_gauges();
    }

    fn enqueue_step(&mut self, state: &RequestState, idx: usize) {
        let meta = self.metas[idx].as_ref().expect("meta for live request");
        let rmeta = RequestMeta {
            id: meta.id,
            client: meta.client.clone(),
            priority: meta.priority,
            deadline_ms: meta.deadline_ms,
            remaining_nfes: meta.cost,
        };
        for (slot, _kind) in state.current_evals().iter().enumerate() {
            self.sched.push(
                WorkItem {
                    state_idx: idx,
                    slot,
                    model: state.req.model.clone(),
                },
                &rmeta,
            );
        }
    }

    /// Error-path rollback: hand the taken-but-unexecuted work items back
    /// to the scheduler. Nothing was delivered, so no other engine state
    /// needs unwinding; within a FairShare lane the re-pushed items land
    /// behind any untaken ones (an ordering wobble confined to the error
    /// path). A deterministic failure will surface again on the next
    /// pump — as an error, never as a hang or a leak.
    fn requeue_failed_batch(&mut self) {
        for it in self.batch_items.drain(..) {
            let meta = self.metas[it.state_idx].as_ref().expect("meta for queued item");
            let rmeta = RequestMeta {
                id: meta.id,
                client: meta.client.clone(),
                priority: meta.priority,
                deadline_ms: meta.deadline_ms,
                remaining_nfes: meta.cost,
            };
            self.sched.push(it, &rmeta);
        }
    }

    fn update_gauges(&mut self) {
        let (active, depth, nfes) = (
            self.active as f64,
            self.sched.len() as f64,
            self.queued_nfes as f64,
        );
        self.telemetry.set_gauge_key(&self.k_active, active);
        self.telemetry.set_gauge_key(&self.k_queue_depth, depth);
        self.telemetry.set_gauge_key(&self.k_queued_nfes, nfes);
    }

    fn observe_completion(&mut self, meta: &Meta, done: &Completion, at: Instant) {
        let policy = meta.policy.as_str();
        // label cardinality is bounded inside Telemetry (LABEL_VALUE_CAP),
        // so the raw client id is safe to pass through
        let client: &str = &meta.client;
        self.telemetry
            .inc("nfes_total", &[("policy", policy)], done.nfes as u64);
        self.telemetry.inc(
            "nfes_saved_total",
            &[("policy", policy)],
            meta.max_nfes.saturating_sub(done.nfes) as u64,
        );
        self.telemetry.inc(
            "requests_completed_total",
            &[("policy", policy), ("client", client)],
            1,
        );
        if let Some(deadline) = meta.deadline_ms {
            let done_ms = at.saturating_duration_since(self.epoch).as_millis() as u64;
            if done_ms > deadline {
                self.telemetry
                    .inc("deadline_missed_total", &[("policy", policy)], 1);
            }
        }
        if let Some(first) = meta.first_exec {
            let wait = first.saturating_duration_since(meta.submitted).as_secs_f64() * 1e3;
            let exec = at.saturating_duration_since(first).as_secs_f64() * 1e3;
            let (lo, hi, bins) = LATENCY_HIST;
            self.telemetry
                .observe("queue_wait_ms", &[("policy", policy)], wait, lo, hi, bins);
            self.telemetry
                .observe("execute_ms", &[("policy", policy)], exec, lo, hi, bins);
        }
    }

    /// §Observability: one combine span (traced requests only) plus the
    /// step's guidance-decision event (every request). Associated fn so
    /// callers can hold disjoint borrows of `metas` and `tracer`; all
    /// writes land in preallocated storage — no allocation.
    #[allow(clippy::too_many_arguments)]
    fn record_step_trace(
        tracer: &mut TraceRecorder,
        meta: &mut Meta,
        snap: StepSnap,
        combine_start: Instant,
        combine_end: Instant,
        gamma: f32,
        nfes: u32,
        truncated: bool,
        last: bool,
    ) {
        let start_us = tracer.us_since_epoch(combine_start);
        let end_us = tracer.us_since_epoch(combine_end);
        if let Some(tl) = meta.timeline.as_mut() {
            let ev = trace::Event::Span {
                req: meta.id,
                stage: Stage::Combine,
                start_us,
                dur_us: end_us.saturating_sub(start_us),
            };
            tracer.record(ev);
            trace::push_capped(tl, ev);
        }
        let ev = trace::Event::Guidance {
            req: meta.id,
            policy: meta.policy_id,
            at_us: end_us,
            step: snap.step,
            evals: snap.evals,
            gamma,
            nfes,
            baseline: 2 * (snap.step + 1),
            max_nfes: meta.max_nfes as u32,
            truncated,
            last,
        };
        tracer.record(ev);
        if let Some(tl) = meta.timeline.as_mut() {
            trace::push_capped(tl, ev);
        }
    }

    /// One pack-and-execute attempt over the current `batch_items`: fill
    /// the reused [`BatchBuf`], call the backend, validate output shape.
    /// Returns the denoise-start stamp (batch-assembly stage boundary)
    /// and the backend's parallel-run stats. On error the caller owns the
    /// rollback (`requeue_failed_batch`) — nothing was delivered.
    fn execute_batch(
        &mut self,
        model: &str,
        flat_in: usize,
        flat_out: usize,
    ) -> Result<(Instant, Option<crate::exec::RunStats>)> {
        // the token table is as wide as the widest request in the
        // batch; narrower rows zero-fill their tail
        // (`fill_eval_input`), matching the backends' all-zero =
        // unconditional convention
        let tok_width = self
            .batch_items
            .iter()
            .map(|it| {
                let st = self.states[it.state_idx].as_ref().expect("state for queued item");
                st.req.tokens.len()
            })
            .max()
            .unwrap_or(0);
        self.batch.reset(flat_in, tok_width);
        for it in &self.batch_items {
            let st = self.states[it.state_idx].as_ref().expect("state for queued item");
            let kind = st.current_evals()[it.slot];
            anyhow::ensure!(
                st.eval_input_len(kind) == flat_in,
                "request {} input length {} != flat_in {flat_in} for model {model}",
                st.req.id,
                st.eval_input_len(kind)
            );
            let (x_row, tok_row) = self.batch.push_row(st.current_t() as f32);
            st.fill_eval_input(kind, x_row, tok_row);
        }
        let denoise_start = Instant::now();
        let stats = self
            .backend
            .denoise_into_par(model, &self.batch, &mut self.out, &self.exec)?;
        anyhow::ensure!(
            self.out.len() == self.batch.len() && self.out.flat_out() == flat_out,
            "backend sized the output {}x{} for a {}x{flat_out} batch",
            self.out.len(),
            self.out.flat_out(),
            self.batch.len()
        );
        Ok((denoise_start, stats))
    }

    /// §Robustness: pull back every admitted request that has never had a
    /// batch item executed (`first_exec` unset) and release its engine
    /// slot, returning the original [`Request`]s. The fleet calls this
    /// when a shard dies: never-started requests restart from step 0 with
    /// the same init noise on a survivor, so their completions stay
    /// byte-identical — only truly mid-step work has to be shed with
    /// `shard_failed`. Queued work items are removed via
    /// [`Scheduler::revoke`], so the scheduler holds no orphans after.
    pub fn salvage_unstarted(&mut self) -> Vec<Request> {
        let mut salvaged = Vec::new();
        for idx in 0..self.metas.len() {
            let started = match self.metas[idx].as_ref() {
                Some(meta) => meta.first_exec.is_some(),
                None => continue,
            };
            if started {
                continue;
            }
            let meta = self.metas[idx].take().expect("meta checked above");
            let state = self.states[idx].take().expect("state for live request");
            self.sched.revoke(idx);
            self.active -= 1;
            self.queued_nfes = self.queued_nfes.saturating_sub(meta.cost);
            self.free.push(idx);
            if let Some(n) = self.clients_in_flight.get_mut(&meta.client) {
                if *n <= 1 {
                    self.clients_in_flight.remove(&meta.client);
                } else {
                    *n -= 1;
                }
            }
            salvaged.push(state.req);
        }
        if !salvaged.is_empty() {
            self.update_gauges();
        }
        salvaged
    }

    /// §Robustness: [`Self::salvage_unstarted`] grown for checkpointing —
    /// pull back *everything* re-placeable from a dying engine. Each
    /// salvaged entry is either a never-started request (restart from step
    /// 0, `checkpoint: None`) or a started request whose latest
    /// [`RequestCheckpoint`] is moved out of the store whole
    /// (swap-don't-copy — the dying engine has no further use for it).
    /// Started requests with no stored checkpoint remain, for the shard
    /// to refuse with `shard_failed` — with `--checkpoint-steps 1` that
    /// set is exactly the requests that never completed a step.
    pub fn salvage_all(&mut self) -> Vec<Salvaged> {
        let mut salvaged = Vec::new();
        for idx in 0..self.metas.len() {
            let (id, started) = match self.metas[idx].as_ref() {
                Some(meta) => (meta.id, meta.first_exec.is_some()),
                None => continue,
            };
            let checkpoint = if started {
                match self.ckpts.take(idx, id) {
                    Some(ck) => Some(Box::new(ck)),
                    // started but never checkpointed: too late to salvage
                    None => continue,
                }
            } else {
                self.ckpts.retire(idx);
                None
            };
            let meta = self.metas[idx].take().expect("meta checked above");
            let state = self.states[idx].take().expect("state for live request");
            self.sched.revoke(idx);
            self.active -= 1;
            self.queued_nfes = self.queued_nfes.saturating_sub(meta.cost);
            self.free.push(idx);
            if let Some(n) = self.clients_in_flight.get_mut(&meta.client) {
                if *n <= 1 {
                    self.clients_in_flight.remove(&meta.client);
                } else {
                    *n -= 1;
                }
            }
            salvaged.push(Salvaged {
                req: state.req,
                checkpoint,
                cost: meta.cost,
            });
        }
        if !salvaged.is_empty() {
            self.update_gauges();
        }
        salvaged
    }

    /// Wire-level cancellation: pull a live request back out of the engine
    /// by id, releasing its slot, its queued work items
    /// ([`Scheduler::revoke`]) and its admission/quota charges — the same
    /// teardown a salvage performs, applied to one request on purpose.
    /// Mid-flight requests cancel too (the shard loop only calls this
    /// between pumps, so no batch is executing): already-delivered partial
    /// buffers are dropped with the state. Returns `false` when the id is
    /// unknown — already completed, never admitted here, or a repeat
    /// cancel — so the caller can answer `unknown_id` instead of lying.
    pub fn cancel(&mut self, id: u64) -> bool {
        let mut found = None;
        for idx in 0..self.metas.len() {
            if let Some(meta) = self.metas[idx].as_ref() {
                if meta.id == id {
                    found = Some(idx);
                    break;
                }
            }
        }
        let Some(idx) = found else { return false };
        let meta = self.metas[idx].take().expect("meta checked above");
        self.states[idx] = None;
        self.sched.revoke(idx);
        self.active -= 1;
        self.queued_nfes = self.queued_nfes.saturating_sub(meta.cost);
        self.free.push(idx);
        // the slot's checkpoint (if any) is dead with the request
        self.ckpts.retire(idx);
        if let Some(n) = self.clients_in_flight.get_mut(&meta.client) {
            if *n <= 1 {
                self.clients_in_flight.remove(&meta.client);
            } else {
                *n -= 1;
            }
        }
        self.telemetry.inc_key(&self.k_requests_canceled, 1);
        self.update_gauges();
        true
    }

    /// Move the buffered per-step progress notes out (cheap Vec swap; the
    /// shard loop recycles the drained Vec's capacity by handing it back
    /// empty on the next call). Empty unless some in-flight request opted
    /// in with `progress: true`.
    pub fn drain_progress(&mut self, into: &mut Vec<ProgressNote>) {
        into.clear();
        std::mem::swap(&mut self.progress_notes, into);
    }

    /// Execute one batch of work items (same model, up to the largest
    /// bucket), as chosen by the scheduler, and advance all requests whose
    /// step completed. Returns the completions this round produced.
    ///
    /// §Perf: at steady state (no admissions, no completions in the round)
    /// this performs zero heap allocations — inputs pack into the reused
    /// [`BatchBuf`], outputs land in the reused [`BatchOut`], and per-slot
    /// result buffers cycle through the [`BufPool`].
    pub fn pump(&mut self) -> Result<Vec<Completion>> {
        let Some(model) = self.sched.peek_model() else {
            return Ok(Vec::new());
        };
        let max_bucket = self.backend.max_batch(&model);
        self.batch_items.clear();
        self.sched.take_batch(&model, max_bucket, &mut self.batch_items);
        // a scheduler that peeks a model but hands back nothing would spin
        // `drain` forever — surface the bug as an error instead
        anyhow::ensure!(
            !self.batch_items.is_empty(),
            "scheduler `{}` peeked model `{model}` but returned an empty batch",
            self.sched.name()
        );

        let flat_in = self.backend.flat_in(&model);
        let flat_out = self.backend.flat_out(&model);

        // pack + execute, fallibly: on any error the un-executed items go
        // back to the scheduler (`requeue_failed_batch`), so accounting
        // (`active`/`queued_nfes`/pending slots) stays consistent and the
        // engine remains usable. §Robustness: errors that classify as
        // transient (typed [`crate::chaos::BackendFault`]s) are retried up
        // to `max_batch_retries` times with seeded decorrelated-jitter
        // backoff — work rolls back through the scheduler between attempts
        // and is re-taken, so the retried batch is re-packed from live
        // state and the result is byte-identical to a fault-free run.
        // Anything else (or a spent budget) escalates to a fatal pump
        // error, exactly the historical behaviour.
        let mut attempts = 0usize;
        let (exec_start, denoise_start, mut exec_stats) = loop {
            // §Observability: batch-assembly stage = t0..denoise_start;
            // each retry re-stamps both so stage histograms measure the
            // attempt that actually produced output
            let t0 = Instant::now();
            match self.execute_batch(&model, flat_in, flat_out) {
                Ok((denoise_start, stats)) => {
                    if attempts > 0 {
                        self.backoff.reset();
                    }
                    break (t0, denoise_start, stats);
                }
                Err(e) => {
                    self.requeue_failed_batch();
                    if classify(&e) == FaultClass::Transient && attempts < self.max_batch_retries {
                        attempts += 1;
                        let ms = self.backoff.next_ms();
                        self.telemetry.inc_key(&self.k_batch_retries, 1);
                        let (lo, hi, bins) = BACKOFF_HIST;
                        self.telemetry
                            .observe_key(&self.k_retry_backoff, ms as f64, lo, hi, bins);
                        if ms > 0 {
                            std::thread::sleep(std::time::Duration::from_millis(ms));
                        }
                        // re-take: the scheduler may hand back a different
                        // (even larger) batch than the one that failed —
                        // retry is a fresh pump round, not a replay
                        self.batch_items.clear();
                        self.sched.take_batch(&model, max_bucket, &mut self.batch_items);
                        if self.batch_items.is_empty() {
                            self.telemetry.inc("pump_errors_total", &[], 1);
                            return Err(e);
                        }
                        continue;
                    }
                    self.telemetry.inc("pump_errors_total", &[], 1);
                    return Err(e);
                }
            }
        };

        let denoise_end = Instant::now();
        // queue-wait accounting: a request starts executing at its first
        // batched item. §Observability: slot 0 appears exactly once per
        // request per step, so it carries the step's batch/denoise spans
        // for traced requests (slot writes into preallocated storage).
        for it in &self.batch_items {
            let meta = self.metas[it.state_idx].as_mut().expect("meta for queued item");
            if meta.first_exec.is_none() {
                meta.first_exec = Some(exec_start);
            }
            if it.slot != 0 {
                continue;
            }
            if let Some(tl) = meta.timeline.as_mut() {
                let start_b = self.tracer.us_since_epoch(exec_start);
                let start_d = self.tracer.us_since_epoch(denoise_start);
                let end_d = self.tracer.us_since_epoch(denoise_end);
                for (stage, start_us, dur_us) in [
                    (Stage::Batch, start_b, start_d.saturating_sub(start_b)),
                    (Stage::Denoise, start_d, end_d.saturating_sub(start_d)),
                ] {
                    let ev = trace::Event::Span {
                        req: meta.id,
                        stage,
                        start_us,
                        dur_us,
                    };
                    self.tracer.record(ev);
                    trace::push_capped(tl, ev);
                }
            }
        }
        // stage-duration histograms, on the same clock as the spans
        let (lo, hi, bins) = STAGE_HIST;
        let batch_ms = denoise_start.saturating_duration_since(exec_start).as_secs_f64() * 1e3;
        let denoise_ms = denoise_end
            .saturating_duration_since(denoise_start)
            .as_secs_f64()
            * 1e3;
        self.telemetry
            .observe_key(&self.k_stage_batch, batch_ms, lo, hi, bins);
        self.telemetry
            .observe_key(&self.k_stage_denoise, denoise_ms, lo, hi, bins);
        self.batches += 1;
        self.items += self.batch.len();
        let occupancy = self.batch.len() as f64;
        self.telemetry.observe_key(
            &self.k_batch_occupancy,
            occupancy,
            0.5,
            self.max_bucket as f64 + 0.5,
            self.max_bucket,
        );

        // deliver results: copy each score row into a pooled buffer owned
        // by the request until its step completes
        let mut ready = std::mem::take(&mut self.ready);
        ready.clear();
        for (row, it) in self.batch_items.iter().enumerate() {
            let st = self.states[it.state_idx].as_mut().expect("state for queued item");
            let mut buf = self.pool.take(flat_out);
            buf.copy_from_slice(self.out.row(row));
            if st.deliver(it.slot, buf) {
                ready.push(it.state_idx);
            }
            let meta = self.metas[it.state_idx].as_mut().expect("meta for queued item");
            meta.cost = meta.cost.saturating_sub(1);
            self.queued_nfes = self.queued_nfes.saturating_sub(1);
        }

        // advance completed steps (a state can appear once — all its slots
        // deliver before `deliver` returns true exactly once). §Perf: the
        // per-slot combine+gamma+solver math shards across the worker
        // pool; everything stateful stays on this thread:
        //   phase A (engine thread): pre-stage each slot's StepBufs — the
        //     one pool buffer a combining plan takes mid-step;
        //   phase B (worker lanes): complete_step_buffered per slot —
        //     pure per-request math on disjoint states;
        //   phase C (engine thread): drain returned buffers into the
        //     single-owner pool and run scheduler/telemetry bookkeeping
        //     in ready order, exactly like the serial engine.
        let n_ready = ready.len();
        // §Observability: combine stage start (re-stamped after staging)
        let mut combine_start = exec_start;
        if n_ready > 0 {
            while self.step_bufs.len() < n_ready {
                self.step_bufs.push(StepBufs::new());
            }
            while self.ready_done.len() < n_ready {
                self.ready_done.push(None);
            }
            while self.step_snap.len() < n_ready {
                self.step_snap.push(StepSnap::default());
            }
            for (j, &idx) in ready.iter().enumerate() {
                let st = self.states[idx].as_ref().expect("state for ready request");
                // snapshot what this step executed — completion replans,
                // so the guidance event must read the plan *before* it
                self.step_snap[j] = StepSnap {
                    step: st.step as u32,
                    evals: EvalSet::of(st.current_plan()),
                };
                let sb = &mut self.step_bufs[j];
                sb.reset();
                if st.needs_combine_buf() {
                    sb.spare = Some(self.pool.take(flat_out));
                }
            }
            combine_start = Instant::now();
            let comp_stats = {
                let exec = &self.exec;
                let states = SliceShards::new(&mut self.states);
                let bufs = SliceShards::new(&mut self.step_bufs[..n_ready]);
                let dones = SliceShards::new(&mut self.ready_done[..n_ready]);
                let ready_idx: &[usize] = &ready;
                exec.run(n_ready, |_lane, j| {
                    // Safety: `ready` holds distinct state indices and the
                    // pool claims each j exactly once, so every state,
                    // StepBufs and done slot is touched by one lane only.
                    let idx = ready_idx[j];
                    let st = unsafe { states.slot(idx) }
                        .as_mut()
                        .expect("state for ready request");
                    let sb = unsafe { bufs.slot(j) };
                    let done = st.complete_step_buffered(sb);
                    *unsafe { dones.slot(j) } = done;
                })
            };
            // thread-affine backends execute serially (no denoise stats);
            // the completion region is then the pump's parallel phase
            if exec_stats.is_none() {
                exec_stats = Some(comp_stats);
            }
        }
        let mut completions = Vec::new();
        let done_at = Instant::now();
        if n_ready > 0 {
            let combine_ms = done_at
                .saturating_duration_since(combine_start)
                .as_secs_f64()
                * 1e3;
            self.telemetry
                .observe_key(&self.k_stage_combine, combine_ms, lo, hi, bins);
        }
        for (j, &idx) in ready.iter().enumerate() {
            let sb = &mut self.step_bufs[j];
            if let Some(spare) = sb.spare.take() {
                self.pool.put(spare);
            }
            for buf in sb.returned.drain(..) {
                self.pool.put(buf);
            }
            if let Some(mut done) = self.ready_done[j].take() {
                self.states[idx] = None;
                self.active -= 1;
                self.sched.forget(idx);
                self.free.push(idx);
                // §Robustness: the slot's checkpoint is stale the moment
                // the request completes (buffers stay for the next tenant)
                self.ckpts.retire(idx);
                let mut meta = self.metas[idx].take().expect("meta for completed request");
                self.queued_nfes = self.queued_nfes.saturating_sub(meta.cost);
                // unwind the per-client quota count
                match self.clients_in_flight.get_mut(&meta.client) {
                    Some(n) if *n > 1 => *n -= 1,
                    _ => {
                        self.clients_in_flight.remove(&meta.client);
                    }
                }
                // §Observability: combine span + the final guidance event
                let snap = self.step_snap[j];
                let gamma = done.gammas.last().copied().unwrap_or(f64::NAN) as f32;
                let truncated = done.truncated_at == Some(snap.step as usize);
                Self::record_step_trace(
                    &mut self.tracer,
                    &mut meta,
                    snap,
                    combine_start,
                    done_at,
                    gamma,
                    done.nfes as u32,
                    truncated,
                    true,
                );
                // the complete span closes the timeline, which serializes
                // here — at completion, off the steady-state path
                if let Some(mut tl) = meta.timeline.take() {
                    let start_us = self.tracer.us_since_epoch(done_at);
                    let ev = trace::Event::Span {
                        req: meta.id,
                        stage: Stage::Complete,
                        start_us,
                        dur_us: self.tracer.now_us().saturating_sub(start_us),
                    };
                    self.tracer.record(ev);
                    trace::push_capped(&mut tl, ev);
                    let rows: Vec<crate::util::json::Value> = tl
                        .iter()
                        .map(|ev| trace::event_to_json(ev, self.shard, self.tracer.policies()))
                        .collect();
                    done.timeline = Some(crate::util::json::Value::Arr(rows));
                }
                self.observe_completion(&meta, &done, done_at);
                completions.push(done);
            } else {
                let st = self.states[idx].take().unwrap();
                // §Observability: combine span + this step's guidance event
                let snap = self.step_snap[j];
                let gamma = st.policy_state.gammas.last().copied().unwrap_or(f64::NAN) as f32;
                let truncated = st.policy_state.truncated_at == Some(snap.step as usize);
                Self::record_step_trace(
                    &mut self.tracer,
                    self.metas[idx].as_mut().unwrap(),
                    snap,
                    combine_start,
                    done_at,
                    gamma,
                    st.nfes as u32,
                    truncated,
                    false,
                );
                // streaming progress for opted-in requests: same payload
                // as the guidance event, buffered for the shard loop to
                // drain. Requests that never opt in skip this entirely,
                // keeping the steady-state pump allocation-free.
                {
                    let meta = self.metas[idx].as_ref().unwrap();
                    if meta.progress {
                        self.progress_notes.push(ProgressNote {
                            id: meta.id,
                            step: snap.step,
                            of: meta.steps,
                            gamma,
                            nfes: st.nfes as u32,
                        });
                    }
                }
                // re-estimate before re-queueing: this is where a policy
                // truncation reaches the scheduler's cost signal
                let meta = self.metas[idx].as_mut().unwrap();
                let old_cost = meta.cost;
                let new_cost = st.remaining_nfes();
                meta.cost = new_cost;
                self.queued_nfes = self.queued_nfes.saturating_sub(old_cost) + new_cost;
                self.enqueue_step(&st, idx);
                // §Robustness: capture the step-boundary checkpoint while
                // the state is out of its slot — clear()+extend into the
                // buffers registered at admission, no allocation
                if self.ckpts.due(st.step) {
                    let ck = self.ckpts.begin_write(idx, st.req.id);
                    st.save_checkpoint(ck);
                    let bytes = ck.encoded_len() as f64;
                    let (lo, hi, bins) = CKPT_HIST;
                    self.telemetry
                        .observe_key(&self.k_checkpoint_bytes, bytes, lo, hi, bins);
                }
                self.states[idx] = Some(st);
            }
        }
        self.ready = ready;
        if let Some(stats) = exec_stats {
            // worker-load gauges: the denoise region when the backend
            // shards (the dominant phase), else the completion region
            self.telemetry
                .set_gauge_key(&self.k_worker_occupancy, stats.occupancy());
            self.telemetry
                .set_gauge_key(&self.k_parallel_efficiency, stats.efficiency());
        }
        self.update_gauges();
        Ok(completions)
    }

    /// Drain all submitted requests to completion.
    pub fn drain(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            let round = self.pump()?;
            out.extend(round);
        }
        // completions arrive in finish order; return in id order for
        // deterministic downstream comparisons.
        out.sort_by_key(|c| c.id);
        Ok(out)
    }

    /// Convenience: submit a batch of requests and drain.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<Vec<Completion>> {
        for r in requests {
            self.submit(r);
        }
        self.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, BatchBuf, BatchOut, GmmBackend};
    use crate::coordinator::policy::{ag, cfg, cond_only, PolicyRef};
    use crate::sched::SchedulerKind;
    use crate::sim::gmm::Gmm;

    fn engine() -> Engine<GmmBackend> {
        Engine::new(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05))).unwrap()
    }

    fn req(id: u64, comp: i32, policy: PolicyRef) -> Request {
        Request::new(id, "gmm", vec![comp, 0, 0, 0], 100 + id, 10, policy)
    }

    /// Same request but with a *shared* seed — policy-comparison tests need
    /// identical starting noise (the paper's same-seed-sequence protocol).
    fn req_seeded(id: u64, comp: i32, policy: PolicyRef) -> Request {
        Request::new(id, "gmm", vec![comp, 0, 0, 0], 777, 10, policy)
    }

    /// A backend with an empty bucket list (misbuilt artifacts).
    struct NoBucketBackend;

    impl Backend for NoBucketBackend {
        fn flat_in(&self, _: &str) -> usize {
            4
        }
        fn flat_out(&self, _: &str) -> usize {
            4
        }
        fn buckets(&self) -> &[usize] {
            &[]
        }
        fn denoise_into(&mut self, _: &str, _: &BatchBuf, _: &mut BatchOut) -> Result<()> {
            Ok(())
        }
        fn models(&self) -> Vec<String> {
            Vec::new()
        }
    }

    #[test]
    fn empty_bucket_list_is_an_error_not_a_panic() {
        let err = match Engine::new(NoBucketBackend) {
            Ok(_) => panic!("expected an error"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("bucket"), "{err}");
    }

    #[test]
    fn single_cfg_request_runs_to_completion() {
        let mut e = engine();
        let out = e.run(vec![req(0, 1, cfg(2.0))]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].nfes, 20);
        assert_eq!(out[0].cfg_steps, 10);
        assert_eq!(out[0].image.len(), 8);
        assert!(out[0].policy.starts_with("cfg("), "{}", out[0].policy);
    }

    #[test]
    fn ag_saves_nfes_on_the_analytic_model() {
        let mut e = engine();
        let out = e
            .run(vec![
                req_seeded(0, 1, cfg(2.0)),
                req_seeded(1, 1, ag(2.0, 0.995)),
            ])
            .unwrap();
        let cfg = &out[0];
        let ag = &out[1];
        assert!(ag.nfes < cfg.nfes, "AG {} vs CFG {}", ag.nfes, cfg.nfes);
        assert!(ag.truncated_at.is_some());
        // the trajectories share the guided prefix → same gammas up to
        // (and including) the truncation step.
        let k = ag.truncated_at.unwrap();
        for i in 0..=k {
            assert!((ag.gammas[i] - cfg.gammas[i]).abs() < 1e-9, "step {i}");
        }
    }

    #[test]
    fn ag_with_unreachable_threshold_replicates_cfg_exactly() {
        let mut e = engine();
        let out = e
            .run(vec![
                req_seeded(0, 2, cfg(2.0)),
                req_seeded(1, 2, ag(2.0, 1.01)),
            ])
            .unwrap();
        assert_eq!(out[0].image, out[1].image);
        assert_eq!(out[0].nfes, out[1].nfes);
    }

    #[test]
    fn batching_packs_items_across_requests() {
        let mut e = engine();
        let reqs: Vec<_> = (0..8)
            .map(|i| req(i, 1 + (i % 4) as i32, cfg(2.0)))
            .collect();
        let out = e.run(reqs).unwrap();
        assert_eq!(out.len(), 8);
        // 8 requests * 2 evals = 16 items per step → exactly one max-bucket
        // batch per step round.
        assert!(e.mean_occupancy() > 15.9, "{}", e.mean_occupancy());
        assert_eq!(e.items(), 8 * 10 * 2);
    }

    #[test]
    fn mixed_policy_traffic_fills_freed_slots() {
        // 8 AG requests that truncate quickly: total items must be well
        // below the CFG cost, and the batcher keeps packing the remaining
        // conditional items together (occupancy stays above 8 = #requests).
        let mut e = engine();
        let reqs: Vec<_> = (0..8)
            .map(|i| req(i, 1, ag(2.0, 0.99)))
            .collect();
        let out = e.run(reqs).unwrap();
        let total: usize = out.iter().map(|c| c.nfes).sum();
        assert!(total < 8 * 20, "AG saved nothing: {total}");
        assert_eq!(e.items(), total);
        assert!(e.mean_occupancy() >= 8.0);
    }

    #[test]
    fn incremental_submission_between_pumps() {
        let mut e = engine();
        e.submit(req(0, 1, cfg(2.0)));
        let mut done = Vec::new();
        let mut pumped = 0;
        while !e.idle() {
            done.extend(e.pump().unwrap());
            pumped += 1;
            if pumped == 3 {
                e.submit(req(1, 2, cfg(2.0)));
            }
        }
        assert_eq!(done.len(), 2);
    }

    #[test]
    fn seeds_make_runs_reproducible() {
        let run = || {
            let mut e = engine();
            e.run(vec![req(0, 3, cfg(2.0))]).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a[0].image, b[0].image);
    }

    #[test]
    fn cond_only_is_half_the_cost_of_cfg() {
        let mut e = engine();
        let out = e
            .run(vec![req(0, 1, cfg(2.0)), req(1, 1, cond_only())])
            .unwrap();
        assert_eq!(out[0].nfes, 2 * out[1].nfes);
    }

    #[test]
    fn empty_run_is_fine() {
        let mut e = engine();
        assert!(e.run(vec![]).unwrap().is_empty());
        assert!(e.pump().unwrap().is_empty());
    }

    #[test]
    fn admission_budget_sheds_load_but_in_flight_completes() {
        let be = GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05));
        let adm = Admission {
            max_in_flight: Some(1),
            max_queued_nfes: Some(40),
            ..Admission::unlimited()
        };
        let mut e = Engine::with_scheduler(be, SchedulerKind::Fifo.build(), adm).unwrap();
        e.try_submit(req(0, 1, cfg(2.0))).unwrap(); // cost 20 ≤ 40
        let err = e.try_submit(req(1, 2, cfg(2.0))).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        // the in-flight request is unaffected and completes
        let done = e.drain().unwrap();
        assert_eq!(done.len(), 1);
        // capacity freed → admissible again
        e.try_submit(req(2, 2, cfg(2.0))).unwrap();
        assert_eq!(e.drain().unwrap().len(), 1);
        assert_eq!(e.telemetry().counter("requests_rejected_total", &[]), 1);
        assert_eq!(e.telemetry().counter("requests_admitted_total", &[]), 2);
    }

    #[test]
    fn per_client_quota_sheds_only_the_greedy_client() {
        let be = GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05));
        let adm = Admission {
            max_in_flight_per_client: Some(2),
            ..Admission::unlimited()
        };
        let mut e = Engine::with_scheduler(be, SchedulerKind::Fifo.build(), adm).unwrap();
        let with_client = |id: u64, name: &str| {
            let mut r = req(id, 1, cfg(2.0));
            r.client_id = Some(Arc::from(name));
            r
        };
        e.try_submit(with_client(0, "bulk")).unwrap();
        e.try_submit(with_client(1, "bulk")).unwrap();
        // third bulk request trips the quota; the error names the limit
        let err = e.try_submit(with_client(2, "bulk")).unwrap_err();
        assert!(matches!(err, AdmitError::ClientBusy { .. }), "{err}");
        assert!(err.to_string().contains("per-client limit 2"), "{err}");
        // other clients (and the anonymous lane) are unaffected
        e.try_submit(with_client(3, "live")).unwrap();
        e.try_submit(req(4, 2, cfg(2.0))).unwrap();
        assert_eq!(e.drain().unwrap().len(), 4);
        // completion released the quota: bulk admits again
        e.try_submit(with_client(5, "bulk")).unwrap();
        assert_eq!(e.drain().unwrap().len(), 1);
        let t = e.telemetry();
        assert_eq!(
            t.counter("client_quota_rejected_total", &[("client", "bulk")]),
            1
        );
        assert_eq!(t.counter("requests_rejected_total", &[]), 1);
    }

    #[test]
    fn anonymous_requests_share_one_quota_lane() {
        let be = GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05));
        let adm = Admission {
            max_in_flight_per_client: Some(1),
            ..Admission::unlimited()
        };
        let mut e = Engine::with_scheduler(be, SchedulerKind::Fifo.build(), adm).unwrap();
        e.try_submit(req(0, 1, cfg(2.0))).unwrap();
        let err = e.try_submit(req(1, 2, cfg(2.0))).unwrap_err();
        assert!(err.to_string().contains("<anonymous>"), "{err}");
        assert_eq!(e.drain().unwrap().len(), 1);
    }

    #[test]
    fn worker_pool_changes_throughput_not_results() {
        // identical workloads on the serial engine and on 2/4-lane pools
        // must produce byte-identical completions and the same batch and
        // pool accounting — parallelism is across rows/slots only
        let run = |workers: usize| {
            let mut e = engine();
            e.set_workers(workers);
            assert_eq!(e.workers(), workers.max(1));
            let reqs: Vec<_> = (0..8)
                .map(|i| {
                    let policy = if i % 2 == 0 { cfg(2.0) } else { ag(2.0, 0.99) };
                    req_seeded(i, 1 + (i % 4) as i32, policy)
                })
                .collect();
            let out = e.run(reqs).unwrap();
            (out, e.batches(), e.items())
        };
        let (base, base_batches, base_items) = run(1);
        for workers in [2usize, 4] {
            let (out, batches, items) = run(workers);
            assert_eq!(batches, base_batches, "workers {workers}");
            assert_eq!(items, base_items, "workers {workers}");
            for (a, b) in out.iter().zip(&base) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.image, b.image, "workers {workers}: request {}", a.id);
                assert_eq!(a.nfes, b.nfes, "workers {workers}");
                assert_eq!(a.truncated_at, b.truncated_at, "workers {workers}");
                assert_eq!(a.gammas.len(), b.gammas.len());
                for (x, y) in a.gammas.iter().zip(&b.gammas) {
                    assert!((x.is_nan() && y.is_nan()) || x == y, "workers {workers}");
                }
            }
        }
        // the parallel engine reports its worker-load gauges
        let mut e = engine();
        e.set_workers(4);
        e.run(vec![req(0, 1, cfg(2.0)), req(1, 2, cfg(2.0))]).unwrap();
        let t = e.telemetry();
        assert_eq!(t.gauge("worker_lanes", &[]), Some(4.0));
        let occ = t.gauge("worker_occupancy", &[]).unwrap();
        assert!(occ > 0.0 && occ <= 1.0, "{occ}");
        let eff = t.gauge("parallel_efficiency", &[]).unwrap();
        assert!(eff > 0.0 && eff <= 1.0, "{eff}");
    }

    #[test]
    fn completed_slots_are_reused() {
        let mut e = engine();
        for i in 0..3 {
            let out = e.run(vec![req(i, 1, cfg(2.0))]).unwrap();
            assert_eq!(out[0].id, i);
        }
        assert_eq!(e.state_slots(), 1, "completed slot must be recycled");
        assert_eq!(e.queued_nfes(), 0);
    }

    #[test]
    fn pump_errors_roll_back_the_batch() {
        // try_submit would refuse token 99 (out of the 4-component
        // vocabulary), but the unvalidated `submit` preload path can still
        // inject it: the backend then errors mid-batch and pump must fail
        // cleanly without leaking engine state
        let mut e = engine();
        e.submit(req(0, 99, cfg(2.0)));
        let before = (e.active(), e.queued_nfes(), e.queue_len());
        let err = e.pump().unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert_eq!(
            (e.active(), e.queued_nfes(), e.queue_len()),
            before,
            "a failed pump must not leak accounting or work items"
        );
        // the failure is deterministic: pumping again errors again (never
        // hangs), and the engine's bookkeeping stays intact
        assert!(e.pump().is_err());
        assert_eq!(e.queue_len(), before.2);
        assert_eq!(e.telemetry().counter("pump_errors_total", &[]), 2);
    }

    #[test]
    fn transient_faults_retry_to_byte_identical_completions() {
        use crate::chaos::fault::{FaultPlan, FaultSpec, FaultyBackend};
        let reqs = || -> Vec<Request> {
            (0..4).map(|i| req_seeded(i, 1 + (i % 4) as i32, cfg(2.0))).collect()
        };
        let clean = engine().run(reqs()).unwrap();
        // every 3rd batch errors transiently; the retry budget absorbs it
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("error-every=3").unwrap());
        let be = FaultyBackend::new(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05)), plan.clone());
        let mut e = Engine::new(be).unwrap();
        e.set_batch_retries(3, 0, 0, 42); // base 0ms: no real sleeping in tests
        let faulty = e.run(reqs()).unwrap();
        assert!(plan.errors() > 0, "fault schedule never fired");
        let t = e.telemetry();
        assert_eq!(
            t.counter("batch_retries_total", &[("class", "transient")]),
            plan.errors(),
            "every injected transient error must be absorbed by a retry"
        );
        assert_eq!(t.counter("pump_errors_total", &[]), 0);
        assert_eq!(faulty.len(), clean.len());
        for (a, b) in faulty.iter().zip(&clean) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.image, b.image, "request {}: retries leaked into the math", a.id);
            assert_eq!(a.nfes, b.nfes);
        }
    }

    #[test]
    fn retry_budget_exhaustion_escalates_to_a_fatal_pump_error() {
        use crate::chaos::fault::{FaultPlan, FaultSpec, FaultyBackend};
        let plan = Arc::new(FaultPlan::default());
        // every batch errors: a budget of 2 retries can never succeed
        plan.arm(FaultSpec::parse("error-every=1").unwrap());
        let be = FaultyBackend::new(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05)), plan);
        let mut e = Engine::new(be).unwrap();
        e.set_batch_retries(2, 0, 0, 7);
        e.submit(req(0, 1, cfg(2.0)));
        let before = (e.active(), e.queued_nfes(), e.queue_len());
        let err = e.pump().unwrap_err();
        assert!(err.to_string().contains("transient"), "{err}");
        let t = e.telemetry();
        assert_eq!(t.counter("batch_retries_total", &[("class", "transient")]), 2);
        assert_eq!(t.counter("pump_errors_total", &[]), 1);
        // the final failure rolled the batch back like any other pump error
        assert_eq!((e.active(), e.queued_nfes(), e.queue_len()), before);
    }

    #[test]
    fn fatal_faults_are_never_retried() {
        use crate::chaos::fault::{FaultPlan, FaultSpec, FaultyBackend};
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("fail-after=1").unwrap());
        let be = FaultyBackend::new(GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05)), plan);
        let mut e = Engine::new(be).unwrap();
        e.set_batch_retries(5, 0, 0, 7);
        e.submit(req(0, 1, cfg(2.0)));
        e.pump().unwrap(); // batch 1 is within the fail-after budget
        let err = e.pump().unwrap_err();
        assert!(err.to_string().contains("fatal"), "{err}");
        let t = e.telemetry();
        assert_eq!(t.counter("batch_retries_total", &[("class", "transient")]), 0);
        assert_eq!(t.counter("pump_errors_total", &[]), 1);
    }

    #[test]
    fn salvage_reclaims_only_never_started_requests() {
        let mut e = engine();
        e.submit(req(0, 1, cfg(2.0)));
        e.pump().unwrap(); // request 0 has executed at least one batch
        e.submit(req(1, 2, cfg(2.0)));
        e.submit(req(2, 3, cfg(2.0)));
        let salvaged = e.salvage_unstarted();
        let mut ids: Vec<u64> = salvaged.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2], "started request 0 must not be salvaged");
        // the survivor still completes; the engine goes fully idle after
        assert_eq!(e.active(), 1);
        let done = e.drain().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 0);
        assert!(e.idle());
        assert_eq!(e.queued_nfes(), 0);
        assert_eq!(e.queue_len(), 0);
        // salvaged slots are recycled, and resubmitting a salvaged request
        // elsewhere reproduces the exact same completion (same init noise)
        let fresh = engine().run(vec![req(1, 2, cfg(2.0))]).unwrap();
        let resub = e.run(salvaged.into_iter().filter(|r| r.id == 1).collect()).unwrap();
        assert_eq!(resub[0].image, fresh[0].image);
        assert_eq!(resub[0].nfes, fresh[0].nfes);
    }

    /// §Robustness: the tentpole invariant at engine level — a started
    /// request pulled off a checkpointing engine mid-trajectory and
    /// resumed on a second engine completes byte-identical to an
    /// uninterrupted run, with exact NFE accounting.
    #[test]
    fn salvage_all_resumes_started_requests_byte_identically() {
        let mut e = engine();
        e.set_checkpoints(1);
        e.submit(req(0, 1, cfg(2.0)));
        e.submit(req(1, 2, ag(2.0, 0.99)));
        for _ in 0..3 {
            e.pump().unwrap(); // both requests are mid-flight, checkpointed
        }
        e.submit(req(2, 3, cfg(2.0))); // never started
        let salvaged = e.salvage_all();
        assert_eq!(salvaged.len(), 3, "started-with-checkpoint AND unstarted");
        assert!(e.idle(), "everything re-placeable left the engine");
        assert_eq!(e.active(), 0);
        assert_eq!(e.queued_nfes(), 0);
        let mut survivor = engine();
        survivor.set_checkpoints(1);
        for s in salvaged {
            match s.checkpoint {
                Some(ck) => {
                    assert!(ck.step >= 1);
                    survivor.try_resume(s.req, &ck).unwrap();
                }
                None => {
                    assert_eq!(s.req.id, 2);
                    survivor.try_submit(s.req).unwrap();
                }
            }
        }
        let mut resumed = survivor.drain().unwrap();
        resumed.sort_by_key(|c| c.id);
        let clean = engine()
            .run(vec![req(0, 1, cfg(2.0)), req(1, 2, ag(2.0, 0.99)), req(2, 3, cfg(2.0))])
            .unwrap();
        for (r, c) in resumed.iter().zip(clean.iter()) {
            assert_eq!(r.id, c.id);
            assert_eq!(r.image, c.image, "request {} diverged across resume", r.id);
            assert_eq!(r.nfes, c.nfes, "NFE accounting must survive resume");
            assert_eq!(r.cfg_steps, c.cfg_steps);
            assert_eq!(r.truncated_at, c.truncated_at);
        }
    }

    /// With checkpointing off (the default), a started request is NOT
    /// returned by `salvage_all` — PR 8 semantics exactly.
    #[test]
    fn salvage_all_without_checkpoints_matches_unstarted_only() {
        let mut e = engine();
        e.submit(req(0, 1, cfg(2.0)));
        e.pump().unwrap();
        e.submit(req(1, 2, cfg(2.0)));
        let salvaged = e.salvage_all();
        assert_eq!(salvaged.len(), 1);
        assert_eq!(salvaged[0].req.id, 1);
        assert!(salvaged[0].checkpoint.is_none());
        // the started request stays, to be refused by the shard's die path
        assert_eq!(e.active(), 1);
    }

    #[test]
    fn editing_shape_mismatches_are_rejected_at_admission() {
        use crate::coordinator::policy::pix2pix;
        let mut e = engine();
        // pix2pix plans triple evals of x ‖ src, but the gmm model's input
        // is flat_out-sized — refuse at the door, don't poison a batch
        let mut r = req(0, 1, pix2pix(7.5, 1.5, None, None));
        r.src_image = Some(vec![0.5; 8]);
        let err = e.try_submit(r).unwrap_err();
        assert!(err.to_string().contains("shape mismatch"), "{err}");
        // wrong-length src_image is refused even before the plan check
        let mut r = req(1, 1, pix2pix(7.5, 1.5, None, None));
        r.src_image = Some(vec![0.5; 3]);
        assert!(e.try_submit(r).unwrap_err().to_string().contains("src_image"));
        // wrong-length init_noise would trip a state-machine assert
        let mut r = req(2, 1, cfg(2.0));
        r.init_noise = Some(vec![0.0; 5]);
        assert!(e.try_submit(r).unwrap_err().to_string().contains("init_noise"));
        assert!(e.idle());
    }

    #[test]
    fn malformed_requests_are_rejected_at_admission() {
        let mut e = engine();
        let err = e
            .try_submit(Request::new(0, "gmm", vec![], 1, 4, cfg(2.0)))
            .unwrap_err();
        assert!(err.to_string().contains("invalid request"), "{err}");
        let mut bad_neg = req(1, 1, cfg(2.0));
        bad_neg.neg_tokens = Some(vec![1, 2]);
        assert!(e.try_submit(bad_neg).unwrap_err().to_string().contains("neg_tokens"));
        let mut bad_steps = req(2, 1, cfg(2.0));
        bad_steps.steps = 0;
        assert!(e.try_submit(bad_steps).is_err());
        // out-of-vocabulary condition token: refused by the backend hook
        let err = e.try_submit(req(3, 99, cfg(2.0))).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // absurd step counts are capped before any O(steps) admission work
        let mut huge = req(4, 1, cfg(2.0));
        huge.steps = MAX_STEPS + 1;
        assert!(e.try_submit(huge).unwrap_err().to_string().contains("steps"));
        // nothing was admitted, nothing panicked, the engine stays usable
        assert!(e.idle());
        assert_eq!(e.telemetry().counter("requests_rejected_total", &[]), 5);
        e.try_submit(req(5, 1, cfg(2.0))).unwrap();
        assert_eq!(e.drain().unwrap().len(), 1);
    }

    #[test]
    fn mixed_token_widths_pack_with_zero_padding() {
        // a batch may mix requests with different token widths; narrower
        // rows zero-pad (all-zero = unconditional convention), so results
        // match the explicitly padded form bit-for-bit
        let mut e = engine();
        let out = e
            .run(vec![
                Request::new(0, "gmm", vec![1, 0, 0, 0], 100, 4, cfg(2.0)),
                Request::new(1, "gmm", vec![2], 101, 4, cfg(2.0)),
            ])
            .unwrap();
        assert_eq!(out.len(), 2);
        let mut solo = engine();
        let wide = solo
            .run(vec![Request::new(1, "gmm", vec![2, 0, 0, 0], 101, 4, cfg(2.0))])
            .unwrap();
        assert_eq!(out[1].image, wide[0].image);
    }

    #[test]
    fn buffer_pool_recycles_across_steps_and_requests() {
        let mut e = engine();
        e.run(vec![req(0, 1, cfg(2.0))]).unwrap();
        let allocs_first = e.pool().allocs();
        assert!(allocs_first > 0, "the warmup request must populate the pool");
        e.run(vec![req(1, 2, cfg(2.0))]).unwrap();
        assert_eq!(
            e.pool().allocs(),
            allocs_first,
            "an identically-shaped follow-up request must be served \
             entirely from recycled buffers"
        );
        assert!(e.pool().reuses() > 0);
    }

    #[test]
    fn deadline_misses_are_counted_per_policy() {
        let mut e = engine();
        let mut missed = req(0, 1, cfg(2.0));
        missed.deadline_ms = Some(0); // due immediately → must be missed
        e.submit(missed);
        let mut easy = req(1, 2, cfg(2.0));
        easy.deadline_ms = Some(3_600_000); // an hour of slack → never missed
        e.submit(easy);
        // a request without a deadline never counts as a miss
        e.submit(req(2, 3, cond_only()));
        // make sure the wall clock has advanced past the 0 ms deadline
        std::thread::sleep(std::time::Duration::from_millis(5));
        let done = e.drain().unwrap();
        assert_eq!(done.len(), 3);
        let t = e.telemetry();
        assert_eq!(t.counter("deadline_missed_total", &[("policy", "cfg")]), 1);
        assert_eq!(t.counter("deadline_missed_total", &[("policy", "cond")]), 0);
    }

    #[test]
    fn client_label_cardinality_is_capped() {
        use crate::sched::telemetry::LABEL_VALUE_CAP;
        let mut e = engine();
        let n = LABEL_VALUE_CAP as u64 + 8;
        for i in 0..n {
            let mut r = Request::new(i, "gmm", vec![1, 0, 0, 0], i, 2, cond_only());
            r.client_id = Some(Arc::from(format!("client-{i:04}")));
            e.submit(r);
        }
        assert_eq!(e.drain().unwrap().len() as u64, n);
        let t = e.telemetry();
        // fifo completes in id order: the first CAP clients keep their own
        // label, the 8 beyond the cap collapse into `other`
        assert_eq!(
            t.counter("requests_completed_total", &[("policy", "cond"), ("client", "other")]),
            8
        );
        assert_eq!(
            t.counter(
                "requests_completed_total",
                &[("policy", "cond"), ("client", "client-0000")]
            ),
            1
        );
    }

    #[test]
    fn telemetry_tracks_per_policy_nfes_and_latency() {
        let mut e = engine();
        e.run(vec![
            req_seeded(0, 1, cfg(2.0)),
            req_seeded(1, 1, ag(2.0, 0.995)),
        ])
        .unwrap();
        let t = e.telemetry();
        assert_eq!(t.counter("nfes_total", &[("policy", "cfg")]), 20);
        let ag_nfes = t.counter("nfes_total", &[("policy", "ag")]);
        assert!(ag_nfes < 20, "{ag_nfes}");
        assert_eq!(t.counter_sum("nfes_total") as usize, e.items());
        assert_eq!(t.counter("nfes_saved_total", &[("policy", "ag")]), 20 - ag_nfes);
        assert_eq!(t.counter("nfes_saved_total", &[("policy", "cfg")]), 0);
        assert_eq!(
            t.counter("requests_completed_total", &[("policy", "ag"), ("client", "")]),
            1
        );
        assert_eq!(t.hist_count("queue_wait_ms", &[("policy", "ag")]), 1);
        assert_eq!(t.hist_count("execute_ms", &[("policy", "cfg")]), 1);
        // gauges settle back to empty
        assert_eq!(t.gauge("active_requests", &[]), Some(0.0));
        assert_eq!(t.gauge("queued_nfes", &[]), Some(0.0));
        // the stats snapshot is valid JSON
        let text = crate::util::json::to_string(&e.stats_json());
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.req("scheduler").as_str(), Some("fifo"));
    }

    #[test]
    fn traced_request_timeline_covers_all_stages_monotonically() {
        let mut e = engine();
        let mut r = req(0, 1, ag(2.0, 0.995));
        r.trace = true;
        // pretend the fleet front end spent time on this request
        r.span_admission_us = 5;
        r.span_placement_us = 3;
        r.span_queue_us = 7;
        let out = e.run(vec![r]).unwrap();
        let tl = out[0]
            .timeline
            .as_ref()
            .expect("traced request carries a timeline");
        let rows = tl.as_arr().unwrap();
        let mut seen: Vec<String> = Vec::new();
        let mut last_start = 0u64;
        for row in rows {
            if row.req("type").as_str() != Some("span") {
                continue;
            }
            let start = row.req("start_us").as_usize().unwrap() as u64;
            assert!(start >= last_start, "span starts must be monotonic");
            last_start = start;
            seen.push(row.req("stage").as_str().unwrap().to_owned());
        }
        for st in crate::trace::Stage::ALL {
            assert!(
                seen.iter().any(|s| s == st.name()),
                "timeline is missing stage `{}`: {seen:?}",
                st.name()
            );
        }
        // the per-step stages repeat once per denoising step
        assert_eq!(seen.iter().filter(|s| *s == "denoise").count(), 10);
        assert_eq!(seen.iter().filter(|s| *s == "combine").count(), 10);
        // an untraced request gets no timeline (and no lifecycle spans)
        let out = e.run(vec![req(1, 1, cfg(2.0))]).unwrap();
        assert!(out[0].timeline.is_none());
    }

    #[test]
    fn guidance_events_cover_every_step_and_ledger_matches_counters() {
        let mut e = engine();
        e.set_shard(3);
        e.run(vec![
            req_seeded(0, 1, cfg(2.0)),
            req_seeded(1, 1, ag(2.0, 0.995)),
        ])
        .unwrap();
        let batch = e.drain_spans();
        assert_eq!(batch.shard, 3);
        assert_eq!(batch.dropped, 0);
        let events = batch.events_json();
        // one guidance event per request per step, final step flagged
        for req_id in [0u64, 1] {
            let steps: Vec<&crate::util::json::Value> = events
                .iter()
                .filter(|v| {
                    v.req("type").as_str() == Some("guidance")
                        && v.req("req").as_usize() == Some(req_id as usize)
                })
                .collect();
            assert_eq!(steps.len(), 10, "one decision per step for req {req_id}");
            assert_eq!(steps[9].req("final").as_bool(), Some(true));
            assert_eq!(steps[9].req("shard").as_usize(), Some(3));
            assert_eq!(steps[0].req("final").as_bool(), Some(false));
            assert_eq!(steps[0].req("baseline_nfes").as_usize(), Some(2));
        }
        // the AG request switched from cond+uncond to cond-only evals
        let ag_evals: Vec<&str> = events
            .iter()
            .filter(|v| {
                v.req("type").as_str() == Some("guidance")
                    && v.req("req").as_usize() == Some(1)
            })
            .map(|v| v.req("evals").as_str().unwrap())
            .collect();
        assert_eq!(ag_evals[0], "cond+uncond");
        assert!(ag_evals.contains(&"cond"), "{ag_evals:?}");
        // the profile ledger reproduces the engine's own counters exactly
        let rows = crate::trace::profile::policy_ledger(&events);
        let saved: u64 = rows.iter().map(|r| r.saved).sum();
        let nfes: u64 = rows.iter().map(|r| r.nfes).sum();
        assert_eq!(saved, e.telemetry().counter_sum("nfes_saved_total"));
        assert_eq!(nfes, e.telemetry().counter_sum("nfes_total"));
        let ag_row = rows.iter().find(|r| r.policy.starts_with("ag")).unwrap();
        assert_eq!(ag_row.truncated, 1, "AG truncates under gamma_bar=0.995");
        // draining cleared the ring; stage histograms were fed per pump
        assert!(e.drain_spans().events.is_empty());
        assert!(
            e.telemetry()
                .hist_count("stage_ms", &[("stage", "denoise")])
                > 0
        );
    }
}
