//! Generation requests and their per-step state machine.
//!
//! A request owns its latent, its policy handle, its per-request
//! [`PolicyState`], its trajectory history and its NFE accounting. The
//! engine (`engine.rs`) only moves *evaluation results* between the backend
//! and this state machine; all guidance semantics live in the policy trait
//! (`policy.rs`) — this file never inspects which policy it is running.
//!
//! # §Perf: buffer ownership
//!
//! The steady-state step path is allocation-free. Backend inputs are
//! written in place into packed batch rows ([`RequestState::fill_eval_input`]
//! — no latent/token clones), score results arrive in buffers drawn from
//! the engine's [`BufPool`] and are returned to it by
//! [`RequestState::complete_step`], which runs the fused combine+gamma
//! kernel ([`crate::tensor::combine_and_gamma`]) and the in-place solver
//! update ([`solver::apply_step_in_place`]). The per-step paths that *do*
//! allocate are the ones that must retain data: trajectory/history
//! recording (LINEARAG, `record_trajectory`) and the final `Completion`.

use std::sync::Arc;

use crate::backend::EvalInput;
use crate::coordinator::bufpool::{BufPool, BufSource, StepBufs};
use crate::coordinator::checkpoint::RequestCheckpoint;
use crate::coordinator::policy::{PolicyRef, PolicyState, StepObservation, StepPlan};
use crate::coordinator::solver::{self, StepCoefs};
use crate::ols::ScoreTrajectory;
use crate::tensor::{self, Tensor};
use crate::util::rng::Rng;

/// An inference request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// backend model name (e.g. "dit_b", "dit_edit", "gmm") — interned so
    /// per-step work items share it by refcount instead of re-allocating
    pub model: Arc<str>,
    /// condition tokens
    pub tokens: Vec<i32>,
    /// negative prompt: used in place of the null tokens for the
    /// unconditional stream (the dynamic-negative-prompt capability that
    /// guidance distillation loses and AG keeps — paper §2.2 / Fig. 7).
    pub neg_tokens: Option<Vec<i32>>,
    /// editing source image (flat, `flat_out` length); requires an editing
    /// model that takes `x ‖ src` input
    pub src_image: Option<Vec<f32>>,
    pub seed: u64,
    pub steps: usize,
    pub policy: PolicyRef,
    /// record the (eps_c, eps_u) score trajectory (OLS fitting / Fig. 15)
    pub record_trajectory: bool,
    /// record the per-step data predictions x0_t (Fig. 17's decoded iterates)
    pub record_iterates: bool,
    /// explicit starting noise (overrides the seed-derived x_T); used by the
    /// python-parity integration tests and replication experiments
    pub init_noise: Option<Vec<f32>>,
    /// client/connection identity for fair-share scheduling and the
    /// `client=` telemetry label (None = anonymous shared lane)
    pub client_id: Option<Arc<str>>,
    /// scheduling priority (larger = more important; `deadline` tie-break)
    pub priority: i32,
    /// optional deadline for the EDF scheduler, in milliseconds *from
    /// arrival* — the engine anchors it to its own clock at admission, so
    /// client clocks never enter the ordering
    pub deadline_ms: Option<u64>,
    /// §Observability: opt into lifecycle span recording (`"trace": true`
    /// on the wire). The engine records this request's seven-stage
    /// timeline into its span ring and echoes it on the [`Completion`];
    /// guidance-decision events are recorded regardless of this flag.
    pub trace: bool,
    /// Opt into per-step progress streaming (`"progress": true` on the
    /// wire). The engine emits a [`crate::coordinator::ProgressNote`]
    /// after every completed non-final step; front-ends that can stream
    /// (the reactor) forward them as `{"event":"progress",..}` lines.
    /// Requests that never opt in take the exact historical pump path.
    pub progress: bool,
    /// §Observability: router-side stage durations in microseconds
    /// (global admission check, placement decision, shard queue wait),
    /// stamped by the fleet before the request reaches an engine — the
    /// engine folds them into the span timeline at admission. Zero for
    /// direct engine submissions.
    pub span_admission_us: u64,
    pub span_placement_us: u64,
    pub span_queue_us: u64,
}

impl Request {
    /// Convenience constructor with the common defaults.
    pub fn new(id: u64, model: &str, tokens: Vec<i32>, seed: u64, steps: usize,
               policy: PolicyRef) -> Request {
        Request {
            id,
            model: Arc::from(model),
            tokens,
            neg_tokens: None,
            src_image: None,
            seed,
            steps,
            policy,
            record_trajectory: false,
            record_iterates: false,
            init_noise: None,
            client_id: None,
            priority: 0,
            deadline_ms: None,
            trace: false,
            progress: false,
            span_admission_us: 0,
            span_placement_us: 0,
            span_queue_us: 0,
        }
    }
}

/// The evaluation streams a step may request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalKind {
    /// eps(x, c)
    Cond,
    /// eps(x, ∅) — or eps(x, c_neg) under a negative prompt
    Uncond,
    /// editing: eps(x, c, I)
    EditFull,
    /// editing: eps(x, ∅, I)
    EditImg,
    /// editing: eps(x, ∅, ∅)
    EditNull,
}

/// Completed request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// display name of the policy that served the request (echoed by the
    /// server so clients can attribute per-policy costs)
    pub policy: String,
    /// final data prediction x0 (flat)
    pub image: Vec<f32>,
    pub nfes: usize,
    pub cfg_steps: usize,
    /// step at which the policy's truncation rule fired (truncation
    /// effective from the next step)
    pub truncated_at: Option<usize>,
    /// convergence signal per step: Eq. 7's cosine on the x0 data
    /// predictions (NaN for steps without both streams) — the AG signal
    pub gammas: Vec<f64>,
    /// Eq. 7's cosine on the raw eps predictions (the paper's printed form)
    pub gammas_eps: Vec<f64>,
    pub trajectory: Option<ScoreTrajectory>,
    /// per-step data predictions (present when `record_iterates` was set)
    pub iterates: Vec<Vec<f32>>,
    /// §Observability: the request's serialized span timeline (a JSON
    /// array of events, see [`crate::trace`]), filled by the engine at
    /// completion for requests that set [`Request::trace`] and echoed on
    /// the server's completion line.
    pub timeline: Option<crate::util::json::Value>,
}

/// Live per-request state.
#[derive(Debug)]
pub struct RequestState {
    pub req: Request,
    pub x: Vec<f32>,
    pub x0_prev: Vec<f32>,
    pub step: usize,
    /// the policy's per-request adaptive state (truncation, the canonical
    /// per-step gamma history, counters, scratch) — owned here,
    /// interpreted only by the policy
    pub policy_state: PolicyState,
    pub nfes: usize,
    pub cfg_steps: usize,
    pub gammas_eps: Vec<f64>,
    /// results for the current step's evals, indexed by plan slot; the
    /// buffers come from the engine's pool and go back to it in
    /// [`Self::complete_step`]
    pending: Vec<Option<Vec<f32>>>,
    pending_left: usize,
    plan: StepPlan,
    hist_c: Vec<Tensor>,
    hist_u: Vec<Tensor>,
    /// per-request precomputed solver coefficients, folded once at
    /// admission ([`solver::coef_table`]) — steps never refold
    coefs: Vec<StepCoefs>,
    iterates: Vec<Vec<f32>>,
}

/// Largest slot count any [`StepPlan`] variant needs (the editing triple).
const MAX_SLOTS: usize = 3;

impl RequestState {
    /// Initialize: draw x_T ~ N(0, I) from the request seed and plan step 0.
    pub fn new(req: Request, flat_out: usize) -> RequestState {
        assert!(req.steps >= 1, "request needs at least one step");
        if let Some(neg) = &req.neg_tokens {
            // packed token rows are sized by `tokens`; a wider negative
            // prompt would be silently truncated, so reject it loudly here
            assert_eq!(
                neg.len(),
                req.tokens.len(),
                "neg_tokens width must match tokens width"
            );
        }
        let x = match &req.init_noise {
            Some(noise) => {
                assert_eq!(noise.len(), flat_out, "init_noise length mismatch");
                noise.clone()
            }
            None => Rng::new(req.seed).normal_vec(flat_out),
        };
        let coefs = solver::coef_table(req.steps);
        let mut policy_state = PolicyState::new();
        // reserve the full gamma histories up front so per-step pushes
        // never reallocate mid-flight (the zero-alloc steady-state pin)
        policy_state.gammas.reserve(req.steps);
        let gammas_eps = Vec::with_capacity(req.steps);
        let plan = req.policy.plan(0, req.steps, &policy_state);
        let slots = Self::evals_for(&plan).len();
        let mut pending = Vec::with_capacity(MAX_SLOTS);
        pending.resize_with(slots, || None);
        RequestState {
            req,
            x,
            x0_prev: vec![0.0; flat_out],
            step: 0,
            policy_state,
            nfes: 0,
            cfg_steps: 0,
            gammas_eps,
            pending,
            pending_left: slots,
            plan,
            hist_c: Vec::new(),
            hist_u: Vec::new(),
            coefs,
            iterates: Vec::new(),
        }
    }

    /// §Robustness: re-seed a request state from a mid-flight checkpoint.
    /// The returned state is positioned exactly where the snapshot was
    /// taken — at the boundary after `ck.step` completed steps, with the
    /// next step freshly planned against the restored policy state — so
    /// driving it forward produces the same bytes the uninterrupted run
    /// would have (pinned by `checkpoint_round_trip_resumes_identically`
    /// below and the chaos resume tests).
    pub fn resume(req: Request, flat_out: usize, ck: &RequestCheckpoint) -> RequestState {
        assert!(
            ck.step >= 1 && ck.step < req.steps,
            "checkpoint step {} out of range for a {}-step request",
            ck.step,
            req.steps
        );
        assert_eq!(ck.x.len(), flat_out, "checkpoint latent length mismatch");
        assert_eq!(ck.x0_prev.len(), flat_out, "checkpoint x0 length mismatch");
        let coefs = solver::coef_table(req.steps);
        let mut policy_state = PolicyState::new();
        policy_state.gammas.reserve(req.steps);
        policy_state.gammas.extend_from_slice(&ck.gammas);
        policy_state.scratch.extend_from_slice(&ck.scratch);
        policy_state.truncated = ck.truncated;
        policy_state.truncated_at = ck.truncated_at;
        policy_state.guided_steps = ck.guided_steps;
        let mut gammas_eps = Vec::with_capacity(req.steps);
        gammas_eps.extend_from_slice(&ck.gammas_eps);
        // the next step is planned against the *restored* state, exactly
        // as the replan at the end of `complete_step_core` would have
        let plan = req.policy.plan(ck.step, req.steps, &policy_state);
        let slots = Self::evals_for(&plan).len();
        let mut pending = Vec::with_capacity(MAX_SLOTS);
        pending.resize_with(slots, || None);
        RequestState {
            req,
            x: ck.x.clone(),
            x0_prev: ck.x0_prev.clone(),
            step: ck.step,
            policy_state,
            nfes: ck.nfes,
            cfg_steps: ck.cfg_steps,
            gammas_eps,
            pending,
            pending_left: slots,
            plan,
            hist_c: ck
                .hist_c
                .iter()
                .map(|d| Tensor::new(vec![flat_out], d.clone()))
                .collect(),
            hist_u: ck
                .hist_u
                .iter()
                .map(|d| Tensor::new(vec![flat_out], d.clone()))
                .collect(),
            coefs,
            iterates: ck.iterates.clone(),
        }
    }

    /// §Robustness: copy the live solver cursor into `ck`, which must have
    /// been sized by [`crate::coordinator::checkpoint::CheckpointStore::register`].
    /// Runs at step boundaries only (the engine calls it right after a
    /// completed step, before the next step executes), so the in-flight
    /// `pending` slots are structurally empty and need no capture. The
    /// common-path copies are `clear()` + `extend_from_slice` into reserved
    /// capacity — no allocation (pinned by `ckpt_zero_alloc.rs`); only the
    /// history/iterate captures allocate, mirroring the recording paths
    /// that already allocate per step.
    pub fn save_checkpoint(&self, ck: &mut RequestCheckpoint) {
        debug_assert_eq!(
            self.pending_left,
            self.pending.len(),
            "checkpoints are taken at step boundaries only"
        );
        ck.id = self.req.id;
        ck.step = self.step;
        ck.nfes = self.nfes;
        ck.cfg_steps = self.cfg_steps;
        ck.truncated = self.policy_state.truncated;
        ck.truncated_at = self.policy_state.truncated_at;
        ck.guided_steps = self.policy_state.guided_steps;
        ck.x.clear();
        ck.x.extend_from_slice(&self.x);
        ck.x0_prev.clear();
        ck.x0_prev.extend_from_slice(&self.x0_prev);
        ck.gammas.clear();
        ck.gammas.extend_from_slice(&self.policy_state.gammas);
        ck.scratch.clear();
        ck.scratch.extend_from_slice(&self.policy_state.scratch);
        ck.gammas_eps.clear();
        ck.gammas_eps.extend_from_slice(&self.gammas_eps);
        ck.hist_c.clear();
        ck.hist_c.extend(self.hist_c.iter().map(|t| t.data.clone()));
        ck.hist_u.clear();
        ck.hist_u.extend(self.hist_u.iter().map(|t| t.data.clone()));
        ck.iterates.clear();
        ck.iterates.extend(self.iterates.iter().cloned());
    }

    pub(crate) fn evals_for(plan: &StepPlan) -> &'static [EvalKind] {
        match plan {
            StepPlan::Guided { .. } => &[EvalKind::Cond, EvalKind::Uncond],
            StepPlan::CondOnly | StepPlan::LinearGuided { .. } => &[EvalKind::Cond],
            StepPlan::UncondOnly => &[EvalKind::Uncond],
            StepPlan::EditGuided { .. } => {
                &[EvalKind::EditFull, EvalKind::EditImg, EvalKind::EditNull]
            }
            StepPlan::EditCondOnly => &[EvalKind::EditFull],
        }
    }

    /// Evals required for the current step, in slot order.
    pub fn current_evals(&self) -> &'static [EvalKind] {
        Self::evals_for(&self.plan)
    }

    /// The plan the current step executes — read by the engine's tracing
    /// layer *before* step completion replans (the guidance-decision
    /// event records what actually ran, which `complete_step` forgets).
    pub fn current_plan(&self) -> &StepPlan {
        &self.plan
    }

    /// The engine's cost signal: evaluations still owed by the current
    /// step plus the plan-sequence cost of every future step under the
    /// *live* policy state. Exact for deterministic policies; for adaptive
    /// ones it is the no-further-truncation upper bound, which tightens
    /// the moment `observe` truncates — cost-aware scheduling keys off it.
    pub fn remaining_nfes(&self) -> usize {
        self.pending_left
            + (self.step + 1..self.req.steps)
                .map(|i| self.req.policy.plan(i, self.req.steps, &self.policy_state).nfes())
                .sum::<usize>()
    }

    /// Current continuous time for the step.
    pub fn current_t(&self) -> f64 {
        solver::timestep(self.step, self.req.steps)
    }

    /// Flattened input length one eval of `kind` writes — what the packed
    /// batch row must hold. The engine checks this against the backend's
    /// `flat_in` so a request/model shape mismatch is a structured error,
    /// not a slice panic.
    pub fn eval_input_len(&self, kind: EvalKind) -> usize {
        match (&self.req.src_image, kind) {
            (Some(src), EvalKind::EditFull | EvalKind::EditImg | EvalKind::EditNull) => {
                self.x.len() + src.len()
            }
            _ => self.x.len(),
        }
    }

    /// Write one eval's backend inputs in place: `x_out` is a
    /// `flat_in`-length packed batch row, `tokens_out` a token row of the
    /// request's token width. Every slot is written (token tails and
    /// absent stream halves are zero-filled), so rows need no
    /// pre-initialization beyond their length.
    pub fn fill_eval_input(&self, kind: EvalKind, x_out: &mut [f32], tokens_out: &mut [i32]) {
        fn write_tokens(dst: &mut [i32], src: &[i32]) {
            let n = src.len().min(dst.len());
            dst[..n].copy_from_slice(&src[..n]);
            dst[n..].fill(0);
        }
        match kind {
            EvalKind::Cond | EvalKind::EditFull => write_tokens(tokens_out, &self.req.tokens),
            EvalKind::Uncond | EvalKind::EditImg => match &self.req.neg_tokens {
                Some(neg) => write_tokens(tokens_out, neg),
                None => tokens_out.fill(0),
            },
            EvalKind::EditNull => tokens_out.fill(0),
        }
        let d = self.x.len();
        let edit = self.req.src_image.is_some()
            && matches!(
                kind,
                EvalKind::EditFull | EvalKind::EditImg | EvalKind::EditNull
            );
        if edit {
            // editing model input is x ‖ src (or x ‖ 0 for the null-image eval)
            let src = self.req.src_image.as_ref().unwrap();
            x_out[..d].copy_from_slice(&self.x);
            if matches!(kind, EvalKind::EditFull | EvalKind::EditImg) {
                x_out[d..d + src.len()].copy_from_slice(src);
            } else {
                x_out[d..d + src.len()].fill(0.0);
            }
        } else {
            x_out[..d].copy_from_slice(&self.x);
        }
    }

    /// Build the backend input for one eval slot as owned vectors — the
    /// compatibility/testing form of [`Self::fill_eval_input`] (the engine
    /// fills packed rows instead of allocating these).
    pub fn eval_input(&self, kind: EvalKind) -> EvalInput {
        let d = self.x.len();
        let edit = self.req.src_image.is_some()
            && matches!(
                kind,
                EvalKind::EditFull | EvalKind::EditImg | EvalKind::EditNull
            );
        let xlen = if edit {
            d + self.req.src_image.as_ref().unwrap().len()
        } else {
            d
        };
        let mut x = vec![0.0f32; xlen];
        let mut tokens = vec![0i32; self.req.tokens.len()];
        self.fill_eval_input(kind, &mut x, &mut tokens);
        EvalInput {
            x,
            t: self.current_t() as f32,
            tokens,
        }
    }

    /// Record one eval result (by slot index). Returns true when the step
    /// has all its results and can be advanced with [`Self::complete_step`].
    pub fn deliver(&mut self, slot: usize, eps: Vec<f32>) -> bool {
        assert!(self.pending[slot].is_none(), "duplicate delivery");
        self.pending[slot] = Some(eps);
        self.pending_left -= 1;
        self.nfes += 1;
        self.pending_left == 0
    }

    /// Whether the current plan combines streams and therefore needs one
    /// spare buffer from the pool mid-step. The engine pre-stages exactly
    /// this buffer into a [`StepBufs`] before a parallel completion.
    pub fn needs_combine_buf(&self) -> bool {
        matches!(
            self.plan,
            StepPlan::Guided { .. } | StepPlan::LinearGuided { .. } | StepPlan::EditGuided { .. }
        )
    }

    /// Combine the step's evals per the plan, let the policy observe the
    /// outcome, advance the solver in place, and set up the next step.
    /// Slot/epsilon buffers are recycled through `pool` (except the ones
    /// history recording must keep). Returns `Some(Completion)` when the
    /// request finishes.
    pub fn complete_step(&mut self, pool: &mut BufPool) -> Option<Completion> {
        self.complete_step_core(pool)
    }

    /// [`Self::complete_step`] against pre-staged per-slot buffers — the
    /// form the engine runs on worker lanes (§Perf: parallel execution).
    /// The engine stages `bufs.spare` beforehand (iff
    /// [`Self::needs_combine_buf`]) and drains `bufs.returned` into the
    /// pool afterwards, so this method touches no shared state beyond the
    /// request's own. Bit-identical to the pool form.
    pub fn complete_step_buffered(&mut self, bufs: &mut StepBufs) -> Option<Completion> {
        self.complete_step_core(bufs)
    }

    /// Shared implementation of the two `complete_step` forms: identical
    /// math and buffer discipline, differing only in where buffers come
    /// from and go ([`BufSource`]).
    fn complete_step_core<S: BufSource>(&mut self, pool: &mut S) -> Option<Completion> {
        assert_eq!(self.pending_left, 0, "step still has pending evals");
        let dim = self.x.len();
        let record = self.req.record_trajectory || self.req.policy.needs_history();
        let step_coefs = self.coefs[self.step];
        // Eq. 7's gamma is probed on the x0 data predictions
        // (x0 = j_x x + j_eps eps): an affine re-parameterization of the
        // same network outputs whose cond/uncond difference shrinks with
        // sigma/alpha, making the AG signal robust on small models
        // (DESIGN.md §Hardware-Adaptation).
        let (jx, je) = (step_coefs.j_x as f32, step_coefs.j_eps as f32);
        let plan_nfes = self.plan.nfes();
        let plan_guided = self.plan.guided();

        let (eps, gamma, gamma_eps) = match &self.plan {
            StepPlan::Guided { s } => {
                let c = self.pending[0].take().expect("slot 0 delivered");
                let u = self.pending[1].take().expect("slot 1 delivered");
                let mut eps = pool.take(dim);
                let g = tensor::combine_and_gamma(&c, &u, *s, &self.x, jx, je, &mut eps);
                if record {
                    self.hist_c.push(Tensor::new(vec![dim], c));
                    self.hist_u.push(Tensor::new(vec![dim], u));
                } else {
                    pool.put(c);
                    pool.put(u);
                }
                (eps, g.gamma_x0, g.gamma_eps)
            }
            StepPlan::CondOnly => {
                // conditional-only steps have no unconditional stream;
                // history-consuming policies never emit this plan.
                debug_assert!(!record || !self.req.policy.needs_history());
                let eps = self.pending[0].take().expect("slot 0 delivered");
                (eps, f64::NAN, f64::NAN)
            }
            StepPlan::UncondOnly => {
                let eps = self.pending[0].take().expect("slot 0 delivered");
                (eps, f64::NAN, f64::NAN)
            }
            StepPlan::LinearGuided { s, coeffs } => {
                let c_buf = self.pending[0].take().expect("slot 0 delivered");
                self.hist_c.push(Tensor::new(vec![dim], c_buf));
                let u_hat = coeffs.predict(self.step, &self.hist_c, &self.hist_u);
                let c = self.hist_c.last().expect("just pushed");
                let mut eps = pool.take(dim);
                let g = tensor::combine_and_gamma(
                    &c.data, &u_hat.data, *s, &self.x, jx, je, &mut eps,
                );
                self.hist_u.push(u_hat);
                (eps, g.gamma_x0, g.gamma_eps)
            }
            StepPlan::EditGuided { s_text, s_img } => {
                let full = self.pending[0].take().expect("slot 0 delivered");
                let img = self.pending[1].take().expect("slot 1 delivered");
                let null = self.pending[2].take().expect("slot 2 delivered");
                let mut eps = pool.take(dim);
                // Eq. 9: null + s_text (full - img) + s_img (img - null).
                // For editing, the convergence signal is the raw-ε cosine of
                // the instruction pair: both streams share the source-image
                // anchor, so their x0 predictions agree almost immediately
                // while the instruction-guidance direction (what Eq. 9's
                // s_text term needs) converges gradually — the paper's
                // "terms in Eq. 9 converge over time".
                let gamma_eps = tensor::edit_combine_and_gamma(
                    &full, &img, &null, *s_text, *s_img, &mut eps,
                );
                pool.put(full);
                pool.put(img);
                pool.put(null);
                (eps, gamma_eps, gamma_eps)
            }
            StepPlan::EditCondOnly => {
                let eps = self.pending[0].take().expect("slot 0 delivered");
                (eps, f64::NAN, f64::NAN)
            }
        };
        self.gammas_eps.push(gamma_eps);

        // feed the policy's per-request state: the canonical gamma history
        // (also reported in the Completion) plus whatever the policy's own
        // observation rule derives (truncation, adaptive scales, …).
        // Accounting first, then observe.
        self.policy_state.gammas.push(gamma);
        if plan_guided {
            self.cfg_steps += 1;
            self.policy_state.guided_steps += 1;
        }
        let obs = StepObservation {
            step: self.step,
            total: self.req.steps,
            gamma,
            gamma_eps,
            nfes: plan_nfes,
            guided: plan_guided,
        };
        self.req.policy.observe(&mut self.policy_state, &obs);

        // solver advance, fully in place; the combined epsilon goes back
        // to the pool
        solver::apply_step_in_place(&mut self.x, &eps, &mut self.x0_prev, &step_coefs);
        pool.put(eps);
        if self.req.record_iterates {
            self.iterates.push(self.x0_prev.clone());
        }
        self.step += 1;

        if self.step == self.req.steps {
            let trajectory = if self.req.record_trajectory {
                Some(ScoreTrajectory {
                    eps_c: std::mem::take(&mut self.hist_c),
                    eps_u: std::mem::take(&mut self.hist_u),
                })
            } else {
                None
            };
            return Some(Completion {
                id: self.req.id,
                policy: self.req.policy.name(),
                image: std::mem::take(&mut self.x0_prev),
                nfes: self.nfes,
                cfg_steps: self.cfg_steps,
                truncated_at: self.policy_state.truncated_at,
                gammas: std::mem::take(&mut self.policy_state.gammas),
                gammas_eps: std::mem::take(&mut self.gammas_eps),
                trajectory,
                iterates: std::mem::take(&mut self.iterates),
                timeline: None,
            });
        }

        // plan the next step against the policy's updated state
        self.plan = self
            .req
            .policy
            .plan(self.step, self.req.steps, &self.policy_state);
        let slots = Self::evals_for(&self.plan).len();
        self.pending.clear();
        self.pending.resize_with(slots, || None);
        self.pending_left = slots;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{ag, cfg, cond_only, pix2pix, PolicyRef};

    fn mk_state(policy: PolicyRef) -> RequestState {
        let req = Request::new(1, "gmm", vec![1, 0, 0, 0], 42, 4, policy);
        RequestState::new(req, 8)
    }

    fn pool() -> BufPool {
        BufPool::new()
    }

    #[test]
    fn seeded_init_is_deterministic() {
        let a = mk_state(cfg(2.0));
        let b = mk_state(cfg(2.0));
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn cfg_step_lifecycle_and_nfe_count() {
        let mut p = pool();
        let mut st = mk_state(cfg(2.0));
        for step in 0..4 {
            let evals = st.current_evals();
            assert_eq!(evals, &[EvalKind::Cond, EvalKind::Uncond][..]);
            assert!(!st.deliver(0, vec![0.1; 8]));
            assert!(st.deliver(1, vec![0.2; 8]));
            let done = st.complete_step(&mut p);
            assert_eq!(done.is_some(), step == 3);
        }
        // every pooled buffer came back: 2 slot buffers + 1 eps in flight
        // at a time, all recycled across the 4 steps
        assert!(p.pooled() >= 1, "step buffers must return to the pool");
    }

    #[test]
    fn completion_reports_accounting() {
        let mut p = pool();
        let mut st = mk_state(cfg(2.0));
        let mut out = None;
        for _ in 0..4 {
            st.deliver(0, vec![0.1; 8]);
            st.deliver(1, vec![0.1; 8]);
            out = st.complete_step(&mut p);
        }
        let c = out.unwrap();
        assert_eq!(c.nfes, 8);
        assert_eq!(c.cfg_steps, 4);
        assert_eq!(c.gammas.len(), 4);
        assert_eq!(c.truncated_at, None);
    }

    #[test]
    fn ag_truncates_on_identical_streams() {
        // identical cond/uncond → gamma = 1 → truncate after step 0.
        let mut p = pool();
        let mut st = mk_state(ag(2.0, 0.999));
        st.deliver(0, vec![0.5; 8]);
        st.deliver(1, vec![0.5; 8]);
        assert!(st.complete_step(&mut p).is_none());
        assert_eq!(st.policy_state.truncated_at, Some(0));
        // subsequent steps are conditional-only
        assert_eq!(st.current_evals(), &[EvalKind::Cond][..]);
        st.deliver(0, vec![0.4; 8]);
        st.complete_step(&mut p);
        assert_eq!(st.current_evals(), &[EvalKind::Cond][..]);
    }

    #[test]
    fn policy_state_tracks_gammas_and_guided_steps() {
        let mut p = pool();
        let mut st = mk_state(cfg(2.0));
        st.deliver(0, vec![0.5; 8]);
        st.deliver(1, vec![0.5; 8]);
        st.complete_step(&mut p);
        assert_eq!(st.policy_state.guided_steps, 1);
        assert_eq!(st.policy_state.gammas.len(), 1);
        assert!((st.policy_state.gammas[0] - 1.0).abs() < 1e-12);

        let mut st = mk_state(cond_only());
        st.deliver(0, vec![0.5; 8]);
        st.complete_step(&mut p);
        assert_eq!(st.policy_state.guided_steps, 0);
        assert!(st.policy_state.gammas[0].is_nan());
    }

    #[test]
    fn remaining_nfes_tracks_deliveries_and_truncation() {
        let mut p = pool();
        // fresh CFG state: the estimate equals the policy's worst case
        let mut st = mk_state(cfg(2.0)); // 4 steps → 8 evals
        assert_eq!(st.remaining_nfes(), 8);
        st.deliver(0, vec![0.1; 8]);
        assert_eq!(st.remaining_nfes(), 7);
        st.deliver(1, vec![0.2; 8]);
        st.complete_step(&mut p);
        assert_eq!(st.remaining_nfes(), 6);

        // AG truncation halves the per-step cost of the remaining steps
        let mut st = mk_state(ag(2.0, 0.999));
        assert_eq!(st.remaining_nfes(), 8);
        st.deliver(0, vec![0.5; 8]);
        st.deliver(1, vec![0.5; 8]);
        st.complete_step(&mut p); // identical streams → gamma = 1 → truncates
        assert_eq!(st.remaining_nfes(), 3, "steps 1..3 conditional-only");
    }

    #[test]
    fn negative_prompt_replaces_uncond_tokens() {
        let mut req = Request::new(1, "m", vec![1, 2, 0, 0], 0, 2, cfg(2.0));
        req.neg_tokens = Some(vec![0, 3, 0, 0]);
        let st = RequestState::new(req, 8);
        let inp = st.eval_input(EvalKind::Uncond);
        assert_eq!(inp.tokens, vec![0, 3, 0, 0]);
        let inp = st.eval_input(EvalKind::Cond);
        assert_eq!(inp.tokens, vec![1, 2, 0, 0]);
    }

    #[test]
    fn fill_eval_input_matches_eval_input() {
        let mut req = Request::new(1, "dit_edit", vec![1, 2, 0, 0], 3, 2,
                                   pix2pix(7.5, 1.5, None, None));
        req.neg_tokens = Some(vec![0, 9, 0, 0]);
        req.src_image = Some(vec![0.7; 8]);
        let st = RequestState::new(req, 8);
        for kind in [
            EvalKind::EditFull,
            EvalKind::EditImg,
            EvalKind::EditNull,
        ] {
            let owned = st.eval_input(kind);
            let mut x = vec![9.9f32; owned.x.len()];
            let mut toks = vec![7i32; owned.tokens.len()];
            st.fill_eval_input(kind, &mut x, &mut toks);
            assert_eq!(x, owned.x, "{kind:?}");
            assert_eq!(toks, owned.tokens, "{kind:?}");
        }
    }

    #[test]
    fn edit_inputs_concatenate_source() {
        let mut req = Request::new(1, "dit_edit", vec![0, 2, 0, 0], 0, 2,
                                   pix2pix(7.5, 1.5, None, None));
        req.src_image = Some(vec![0.7; 8]);
        let st = RequestState::new(req, 8);
        let full = st.eval_input(EvalKind::EditFull);
        assert_eq!(full.x.len(), 16);
        assert_eq!(&full.x[8..], &[0.7f32; 8][..]);
        let null = st.eval_input(EvalKind::EditNull);
        assert_eq!(&null.x[8..], &[0.0f32; 8][..]);
        assert_eq!(null.tokens, vec![0, 0, 0, 0]);
        // eq-9 triple eval costs 3 NFEs
        assert_eq!(st.current_evals().len(), 3);
    }

    #[test]
    fn trajectory_recorded_when_requested() {
        let mut p = pool();
        let mut req = Request::new(1, "m", vec![1, 0, 0, 0], 7, 3, cfg(2.0));
        req.record_trajectory = true;
        let mut st = RequestState::new(req, 8);
        let mut out = None;
        for i in 0..3 {
            st.deliver(0, vec![i as f32 + 0.5; 8]);
            st.deliver(1, vec![i as f32; 8]);
            out = st.complete_step(&mut p);
        }
        let tr = out.unwrap().trajectory.unwrap();
        assert_eq!(tr.eps_c.len(), 3);
        assert_eq!(tr.eps_u.len(), 3);
        assert_eq!(tr.eps_c[1].data, vec![1.5; 8]);
        // recorded slot buffers must NOT be recycled into the pool; only
        // the single combined-eps buffer cycles (1 alloc, then reuses)
        assert_eq!(p.pooled(), 1);
        assert_eq!(p.allocs(), 1);
        assert_eq!(p.reuses(), 2);
    }

    /// §Robustness: serialize → restore → identical next step (and on to
    /// an identical completion). The AG policy truncates mid-run here, so
    /// the checkpoint carries a non-trivial policy state (truncation flag,
    /// NaN gammas) and the trajectory recording exercises the history
    /// round trip.
    #[test]
    fn checkpoint_round_trip_resumes_identically() {
        fn mk() -> RequestState {
            let mut req = Request::new(7, "gmm", vec![1, 0, 0, 0], 99, 6, ag(2.0, 0.9));
            req.record_trajectory = true;
            req.record_iterates = true;
            RequestState::new(req, 8)
        }
        fn drive(st: &mut RequestState, p: &mut BufPool, step: usize) -> Option<Completion> {
            for slot in 0..st.current_evals().len() {
                st.deliver(slot, vec![0.3 + 0.2 * slot as f32 + 0.05 * step as f32; 8]);
            }
            st.complete_step(p)
        }
        fn bits(v: &[f64]) -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        }
        let mut p = pool();
        let mut a = mk();
        for s in 0..3 {
            assert!(drive(&mut a, &mut p, s).is_none());
        }
        let mut ck = RequestCheckpoint::default();
        a.save_checkpoint(&mut ck);
        assert_eq!(ck.step, 3);
        assert_eq!(ck.nfes, a.nfes);
        // wire round trip: byte equality is the invariant (NaN gammas make
        // float equality useless)
        let bytes = ck.to_bytes();
        let ck = RequestCheckpoint::from_bytes(&bytes).unwrap();
        assert_eq!(ck.to_bytes(), bytes);
        let mut b = RequestState::resume(mk().req, 8, &ck);
        assert_eq!(b.step, a.step);
        assert_eq!(b.x, a.x);
        assert_eq!(b.x0_prev, a.x0_prev);
        assert_eq!(b.remaining_nfes(), a.remaining_nfes());
        assert_eq!(b.current_evals(), a.current_evals());
        // drive both to completion on identical deliveries: every byte of
        // the completion must match
        let (mut ca, mut cb) = (None, None);
        for s in 3..6 {
            ca = drive(&mut a, &mut p, s);
            cb = drive(&mut b, &mut p, s);
            assert_eq!(b.x, a.x, "diverged at step {s}");
        }
        let (ca, cb) = (ca.unwrap(), cb.unwrap());
        assert_eq!(ca.image, cb.image);
        assert_eq!(ca.nfes, cb.nfes);
        assert_eq!(ca.cfg_steps, cb.cfg_steps);
        assert_eq!(ca.truncated_at, cb.truncated_at);
        assert_eq!(bits(&ca.gammas), bits(&cb.gammas));
        assert_eq!(bits(&ca.gammas_eps), bits(&cb.gammas_eps));
        assert_eq!(ca.iterates, cb.iterates);
        let (ta, tb) = (ca.trajectory.unwrap(), cb.trajectory.unwrap());
        assert_eq!(
            ta.eps_c.iter().map(|t| &t.data).collect::<Vec<_>>(),
            tb.eps_c.iter().map(|t| &t.data).collect::<Vec<_>>()
        );
    }

    #[test]
    #[should_panic(expected = "duplicate delivery")]
    fn duplicate_delivery_panics() {
        let mut st = mk_state(cfg(2.0));
        st.deliver(0, vec![0.0; 8]);
        st.deliver(0, vec![0.0; 8]);
    }

    #[test]
    fn times_decrease_over_steps() {
        let mut p = pool();
        let mut st = mk_state(cond_only());
        let t0 = st.current_t();
        st.deliver(0, vec![0.0; 8]);
        st.complete_step(&mut p);
        assert!(st.current_t() < t0);
    }
}
