//! Follow-up-literature guidance policies, implemented *purely as plugins*
//! against the open [`Policy`](crate::coordinator::policy::Policy) API:
//! nothing in `engine.rs` or `request.rs` knows these exist — they are
//! ordinary trait impls wired in through the
//! [`PolicyRegistry`](crate::coordinator::spec::PolicyRegistry).
//!
//!  * [`CompressedCfg`] — periodic guidance compression (Dinh et al.,
//!    *Compress Guidance in Conditional Diffusion Sampling*): run the full
//!    guidance pair on every k-th step only, conditional in between.
//!  * [`AdaptiveScale`] — step-adaptive guidance scale (Zhang et al., *How
//!    Much To Guide*): decay the scale as the convergence signal gamma_t
//!    rises, and drop guidance entirely once it saturates. Uses the
//!    per-request gamma history in
//!    [`PolicyState`](crate::coordinator::policy::PolicyState) — state no
//!    single shared boolean could carry.

use crate::coordinator::policy::{Policy, PolicyState, StepObservation, StepPlan};
use crate::coordinator::spec::{PolicyRegistry, PolicySpec};
use crate::util::json;

/// Guided step every `period` steps (step 0, period, 2·period, …),
/// conditional-only in between. `period = 1` degenerates to plain CFG.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedCfg {
    pub s: f32,
    pub period: usize,
}

impl Policy for CompressedCfg {
    fn name(&self) -> String {
        format!("compressed-cfg(k={})", self.period)
    }

    fn plan(&self, step: usize, _total: usize, _state: &PolicyState) -> StepPlan {
        // `.max(1)` guards direct construction with period 0 (the registry
        // builder rejects it, but the struct and helper are public).
        if step % self.period.max(1) == 0 {
            StepPlan::Guided { s: self.s }
        } else {
            StepPlan::CondOnly
        }
    }

    fn spec(&self) -> PolicySpec {
        PolicySpec::new("compressed-cfg")
            .with("s", json::num(self.s as f64))
            .with("period", json::num(self.period as f64))
    }
}

/// Guidance scale ramped from `s_max` down to `s_min` as the observed
/// gamma_t rises across `[gamma_lo, gamma_hi]`; once gamma_t reaches
/// `gamma_hi` the scale has pinned at `s_min` and the unconditional stream
/// is dropped entirely (guidance no longer buys anything — the policy's own
/// truncation rule, expressed without engine support).
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveScale {
    pub s_max: f32,
    pub s_min: f32,
    pub gamma_lo: f64,
    pub gamma_hi: f64,
}

impl AdaptiveScale {
    /// The scale for the next step given the last observed gamma.
    fn scale(&self, state: &PolicyState) -> f32 {
        match state.last_gamma() {
            Some(g) => {
                let span = (self.gamma_hi - self.gamma_lo).max(f64::EPSILON);
                let frac = ((g - self.gamma_lo) / span).clamp(0.0, 1.0) as f32;
                self.s_max + (self.s_min - self.s_max) * frac
            }
            // no observation yet: full strength
            None => self.s_max,
        }
    }
}

impl Policy for AdaptiveScale {
    fn name(&self) -> String {
        format!("adaptive-scale({}→{})", self.s_max, self.s_min)
    }

    fn plan(&self, _step: usize, _total: usize, state: &PolicyState) -> StepPlan {
        if state.truncated {
            StepPlan::CondOnly
        } else {
            StepPlan::Guided {
                s: self.scale(state),
            }
        }
    }

    fn observe(&self, state: &mut PolicyState, obs: &StepObservation) {
        // NaN gamma (single-stream step) never saturates the ramp.
        if !state.truncated && obs.gamma >= self.gamma_hi {
            state.truncated = true;
            state.truncated_at = Some(obs.step);
        }
    }

    fn spec(&self) -> PolicySpec {
        PolicySpec::new("adaptive-scale")
            .with("s_max", json::num(self.s_max as f64))
            .with("s_min", json::num(self.s_min as f64))
            .with("gamma_lo", json::num(self.gamma_lo))
            .with("gamma_hi", json::num(self.gamma_hi))
    }
}

/// Register the plugin policies (called by
/// [`PolicyRegistry::builtin`]; external policy crates follow the same
/// pattern).
pub fn register(reg: &mut PolicyRegistry) {
    reg.register("compressed-cfg", |spec| {
        let period = spec.usize_or("period", 4)?;
        if period == 0 {
            return Err(spec.bad("period", "must be >= 1"));
        }
        Ok(CompressedCfg {
            s: spec.f32_or("s", 7.5)?,
            period,
        }
        .into_ref())
    });
    reg.register("adaptive-scale", |spec| {
        let gamma_lo = spec.f64_or("gamma_lo", 0.9)?;
        let gamma_hi = spec.f64_or("gamma_hi", 0.9995)?;
        if gamma_hi <= gamma_lo {
            return Err(spec.bad("gamma_hi", "must be > gamma_lo"));
        }
        Ok(AdaptiveScale {
            s_max: spec.f32_or("s_max", 7.5)?,
            s_min: spec.f32_or("s_min", 1.5)?,
            gamma_lo,
            gamma_hi,
        }
        .into_ref())
    });
}

/// Constructor helpers matching `policy.rs`'s short form.
pub fn compressed_cfg(s: f32, period: usize) -> crate::coordinator::policy::PolicyRef {
    CompressedCfg { s, period }.into_ref()
}

pub fn adaptive_scale(
    s_max: f32,
    s_min: f32,
    gamma_lo: f64,
    gamma_hi: f64,
) -> crate::coordinator::policy::PolicyRef {
    AdaptiveScale {
        s_max,
        s_min,
        gamma_lo,
        gamma_hi,
    }
    .into_ref()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compressed_cfg_guides_every_kth_step() {
        let p = CompressedCfg { s: 2.0, period: 4 };
        let st = PolicyState::new();
        let guided: Vec<usize> = (0..12).filter(|&i| p.plan(i, 12, &st).guided()).collect();
        assert_eq!(guided, vec![0, 4, 8]);
        // 3 guided * 2 + 9 cond = 15
        assert_eq!(p.max_nfes(12), 15);
        // period 1 ≡ CFG; period 0 (direct construction) degrades to 1
        // instead of panicking on the modulo
        assert_eq!(CompressedCfg { s: 2.0, period: 1 }.max_nfes(10), 20);
        assert_eq!(CompressedCfg { s: 2.0, period: 0 }.max_nfes(10), 20);
    }

    #[test]
    fn adaptive_scale_decays_with_gamma_and_truncates() {
        let p = AdaptiveScale {
            s_max: 8.0,
            s_min: 2.0,
            gamma_lo: 0.5,
            gamma_hi: 0.9,
        };
        let mut st = PolicyState::new();
        // no observation yet: full strength
        assert_eq!(p.plan(0, 10, &st), StepPlan::Guided { s: 8.0 });
        // halfway up the ramp: s = 8 + (2-8)*0.5 = 5
        st.gammas.push(0.7);
        assert_eq!(p.plan(1, 10, &st), StepPlan::Guided { s: 5.0 });
        // below the ramp: clamped to s_max
        st.gammas.push(0.2);
        assert_eq!(p.plan(2, 10, &st), StepPlan::Guided { s: 8.0 });
        // saturation: observe() truncates, plan drops the pair
        st.gammas.push(0.95);
        p.observe(
            &mut st,
            &StepObservation {
                step: 3,
                total: 10,
                gamma: 0.95,
                gamma_eps: 0.95,
                nfes: 2,
                guided: true,
            },
        );
        assert!(st.truncated);
        assert_eq!(st.truncated_at, Some(3));
        assert_eq!(p.plan(4, 10, &st), StepPlan::CondOnly);
        // worst case (fresh state) is still 2 NFEs/step
        assert_eq!(p.max_nfes(10), 20);
    }
}
