//! L3 coordinator: the paper's system contribution.
//!
//! * [`policy`] — guidance policies (CFG / AG / LINEARAG / searched / pix2pix)
//! * [`solver`] — cosine-VP schedule + DPM-Solver++(2M) coefficient folding
//! * [`request`] — per-request state machine (combine, truncation, history)
//! * [`engine`] — continuation batching of NFE work items over a [`crate::Backend`]

pub mod engine;
pub mod policy;
pub mod request;
pub mod solver;
