//! L3 coordinator: the paper's system contribution.
//!
//! * [`policy`] — the open guidance-policy API: the [`policy::Policy`]
//!   trait, per-request [`policy::PolicyState`], and the built-in policies
//!   (CFG / AG / LINEARAG / searched / pix2pix / …)
//! * [`spec`] — the `PolicySpec` wire/config format and the
//!   [`spec::PolicyRegistry`] that constructs policies by name
//! * [`ext`] — follow-up-literature policies plugged in through the trait
//!   API (no engine changes)
//! * [`solver`] — cosine-VP schedule + DPM-Solver++(2M) coefficient folding
//! * [`request`] — per-request state machine (combine, policy state, history)
//! * [`checkpoint`] — §Robustness: resumable mid-flight snapshots of a
//!   request's solver cursor, for byte-identical failover across shard
//!   death (`--checkpoint-steps`)
//! * [`bufpool`] — the length-keyed buffer pool behind the zero-allocation
//!   steady-state hot path (§Perf: buffer ownership)
//! * [`engine`] — continuation batching of NFE work items over a
//!   [`crate::Backend`], ordered by a pluggable [`crate::sched::Scheduler`]
//!   with admission control and telemetry ([`crate::sched`])

pub mod bufpool;
pub mod checkpoint;
pub mod engine;
pub mod ext;
pub mod policy;
pub mod request;
pub mod solver;
pub mod spec;
