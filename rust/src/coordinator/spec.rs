//! `PolicySpec` — the serde-free wire/config form of a guidance policy —
//! and the registry that constructs policies from it.
//!
//! A spec is a policy *kind* (wire name) plus a flat parameter map of
//! [`json::Value`]s:
//!
//! ```text
//! "ag"                                          (bare name, defaults)
//! {"kind": "ag", "s": 7.5, "gamma_bar": 0.9988}
//! {"kind": "searched", "choices": ["cond", 2.5, "uncond", {"cfg": 3.0}]}
//! ```
//!
//! The same format is accepted by the server line protocol (`"policy"`
//! field), the `agd` CLI (`--policy`, plus per-parameter flags), and config
//! files; [`Policy::spec`] emits it back, so any constructed policy
//! round-trips through JSON.
//!
//! [`PolicyRegistry`] maps kind → builder. [`PolicyRegistry::builtin`]
//! registers the eight paper policies plus the [`crate::coordinator::ext`]
//! plugins; callers can [`PolicyRegistry::register`] additional policies
//! without touching anything else — the registry is the single point where
//! a new policy becomes reachable from every front-end.
//!
//! The registry can also be extended at startup with named *aliases* —
//! presets that expand to a full spec. `agd serve --policy-file FILE`
//! loads them from a JSON object mapping alias → spec:
//!
//! ```text
//! {"fast-ag": {"kind": "ag", "gamma_bar": 0.997, "s": 5.0},
//!  "bulk": "cond"}
//! ```
//!
//! Aliases are validated at load time (unknown kind / bad parameters fail
//! at startup, not on first request) and resolve before server defaults
//! apply, so a request's explicit parameters override the preset's and the
//! preset's override the server's.
//!
//! # Persisted OLS coefficients (`coeffs_file`)
//!
//! A `linear-ag` spec can reference server-side coefficients instead of
//! inlining the (large) OLS JSON over the wire:
//!
//! ```text
//! {"kind": "linear-ag", "coeffs_file": "dit_b_20step.json"}
//! ```
//!
//! When the registry has a coefficients directory
//! ([`PolicyRegistry::set_coeffs_dir`]; `agd serve --coeffs-dir DIR`),
//! [`PolicyRegistry::build`] resolves `coeffs_file` against it at build
//! time — loading the file's JSON into the `coeffs` parameter before the
//! builder runs. The name must be a plain relative path (no `..`, no
//! absolute paths): clients name files, the server owns the directory.
//! Inline `coeffs` win when both are present, and aliases referencing a
//! `coeffs_file` are dry-run built at registration, so a missing file
//! fails at startup rather than on the first request.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use crate::coordinator::policy::{
    Ag, AgFixedPrefix, AlternatingCfg, Cfg, CondOnly, LinearAg, Pix2Pix, Policy, PolicyRef,
    Searched, StepChoice,
};
use crate::ols::OlsCoeffs;
use crate::util::cli::Args;
use crate::util::json::{self, Value};

/// Wire/config form of a policy: kind + parameters (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySpec {
    pub kind: String,
    pub params: BTreeMap<String, Value>,
}

impl PolicySpec {
    pub fn new(kind: &str) -> PolicySpec {
        PolicySpec {
            kind: kind.to_owned(),
            params: BTreeMap::new(),
        }
    }

    /// Builder-style parameter setter (`"kind"` is reserved for the kind).
    pub fn with(mut self, key: &str, value: Value) -> PolicySpec {
        self.params.insert(key.to_owned(), value);
        self
    }

    /// Insert a parameter only if absent — how front-ends inject their
    /// configured defaults without overriding explicit client values.
    pub fn set_default(&mut self, key: &str, value: Value) {
        self.params.entry(key.to_owned()).or_insert(value);
    }

    /// The kind with aliases resolved (e.g. `distilled` → `cond`).
    pub fn canonical_kind(&self) -> &str {
        canonical(&self.kind)
    }

    /// Parse from a JSON value: a bare string kind, or an object with a
    /// `"kind"` field whose remaining fields become parameters.
    pub fn from_json(v: &Value) -> Result<PolicySpec, SpecError> {
        match v {
            Value::Str(name) => Ok(PolicySpec::new(name)),
            Value::Obj(m) => {
                let kind = m
                    .get("kind")
                    .and_then(Value::as_str)
                    .ok_or_else(|| SpecError::BadSpec {
                        msg: "policy object needs a string `kind` field".into(),
                    })?
                    .to_owned();
                let params = m
                    .iter()
                    .filter(|(k, _)| k.as_str() != "kind")
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect();
                Ok(PolicySpec { kind, params })
            }
            _ => Err(SpecError::BadSpec {
                msg: "policy must be a string name or an object".into(),
            }),
        }
    }

    /// Serialize to the JSON object form (inverse of [`Self::from_json`]).
    pub fn to_json(&self) -> Value {
        let mut m: BTreeMap<String, Value> = self.params.clone();
        m.insert("kind".to_owned(), Value::Str(self.kind.clone()));
        Value::Obj(m)
    }

    /// Parse from text: a bare kind name, or inline JSON (`{...}`).
    pub fn parse(text: &str) -> Result<PolicySpec, SpecError> {
        let text = text.trim();
        if text.starts_with('{') {
            let v = json::parse(text).map_err(|e| SpecError::BadSpec {
                msg: format!("inline policy json: {e}"),
            })?;
            PolicySpec::from_json(&v)
        } else if text.is_empty() {
            Err(SpecError::BadSpec {
                msg: "empty policy name".into(),
            })
        } else {
            Ok(PolicySpec::new(text))
        }
    }

    /// Build a spec from CLI arguments: `--policy NAME|JSON` plus the
    /// per-parameter flags (`--guidance`, `--gamma-bar`, `--cfg-steps`,
    /// `--period`, `--coeffs FILE`, `--choices LIST`, …), which override
    /// any value carried in the `--policy` JSON.
    pub fn from_cli(args: &Args) -> Result<PolicySpec, SpecError> {
        let mut spec = PolicySpec::parse(args.get_or("policy", "ag"))?;
        const NUM_FLAGS: &[(&str, &str)] = &[
            ("s", "guidance"),
            ("gamma_bar", "gamma-bar"),
            ("cfg_steps", "cfg-steps"),
            ("period", "period"),
            ("full_prefix", "full-prefix"),
            ("s_text", "s-text"),
            ("s_img", "s-img"),
            ("s_max", "s-max"),
            ("s_min", "s-min"),
            ("gamma_lo", "gamma-lo"),
            ("gamma_hi", "gamma-hi"),
        ];
        for &(key, flag) in NUM_FLAGS {
            if let Some(raw) = args.get(flag) {
                let v: f64 = raw.parse().map_err(|_| SpecError::BadField {
                    kind: spec.kind.clone(),
                    field: key,
                    msg: format!("--{flag}: expected a number, got `{raw}`"),
                })?;
                spec.params.insert(key.to_owned(), Value::Num(v));
            }
        }
        if let Some(path) = args.get("coeffs") {
            let text = std::fs::read_to_string(path).map_err(|e| SpecError::BadField {
                kind: spec.kind.clone(),
                field: "coeffs",
                msg: format!("--coeffs {path}: {e}"),
            })?;
            let v = json::parse(&text).map_err(|e| SpecError::BadField {
                kind: spec.kind.clone(),
                field: "coeffs",
                msg: format!("--coeffs {path}: {e}"),
            })?;
            spec.params.insert("coeffs".to_owned(), v);
        }
        if let Some(list) = args.get("choices") {
            let arr: Vec<Value> = list
                .split(',')
                .map(|tok| {
                    let tok = tok.trim();
                    match tok.parse::<f64>() {
                        Ok(n) => Value::Num(n),
                        Err(_) => json::s(tok),
                    }
                })
                .collect();
            spec.params.insert("choices".to_owned(), Value::Arr(arr));
        }
        Ok(spec)
    }

    // -- typed parameter accessors (absent or null → default) ---------------

    /// Error constructor for builders — public so external plugins can
    /// report parameter problems uniformly.
    pub fn bad(&self, field: &'static str, msg: impl Into<String>) -> SpecError {
        SpecError::BadField {
            kind: self.kind.clone(),
            field,
            msg: msg.into(),
        }
    }

    pub fn missing(&self, field: &'static str) -> SpecError {
        SpecError::MissingField {
            kind: self.kind.clone(),
            field,
        }
    }

    pub fn get(&self, field: &str) -> Option<&Value> {
        match self.params.get(field) {
            Some(Value::Null) | None => None,
            Some(v) => Some(v),
        }
    }

    pub fn f64_or(&self, field: &'static str, default: f64) -> Result<f64, SpecError> {
        match self.get(field) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| self.bad(field, "expected a number")),
        }
    }

    pub fn f32_or(&self, field: &'static str, default: f32) -> Result<f32, SpecError> {
        self.f64_or(field, default as f64).map(|v| v as f32)
    }

    pub fn usize_or(&self, field: &'static str, default: usize) -> Result<usize, SpecError> {
        match self.get(field) {
            None => Ok(default),
            Some(v) => v
                .as_usize()
                .ok_or_else(|| self.bad(field, "expected a non-negative integer")),
        }
    }

    pub fn opt_f64(&self, field: &'static str) -> Result<Option<f64>, SpecError> {
        match self.get(field) {
            None => Ok(None),
            Some(v) => v
                .as_f64()
                .map(Some)
                .ok_or_else(|| self.bad(field, "expected a number")),
        }
    }

    pub fn opt_usize(&self, field: &'static str) -> Result<Option<usize>, SpecError> {
        match self.get(field) {
            None => Ok(None),
            Some(v) => v
                .as_usize()
                .map(Some)
                .ok_or_else(|| self.bad(field, "expected a non-negative integer")),
        }
    }
}

/// Errors from spec parsing and policy construction.
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// No builder registered under the requested kind; carries the
    /// registered names so front-ends can report them to the client.
    UnknownPolicy { kind: String, known: Vec<String> },
    BadSpec { msg: String },
    MissingField { kind: String, field: &'static str },
    BadField {
        kind: String,
        field: &'static str,
        msg: String,
    },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::UnknownPolicy { kind, known } => {
                write!(f, "unknown policy `{kind}` (registered: {})", known.join(", "))
            }
            SpecError::BadSpec { msg } => write!(f, "bad policy spec: {msg}"),
            SpecError::MissingField { kind, field } => {
                write!(f, "policy `{kind}`: missing required `{field}`")
            }
            SpecError::BadField { kind, field, msg } => {
                write!(f, "policy `{kind}`: bad `{field}`: {msg}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

/// Resolve kind aliases to the canonical registered name.
fn canonical(kind: &str) -> &str {
    match kind {
        "cond-only" | "distilled" => "cond",
        other => other,
    }
}

type Builder = Box<dyn Fn(&PolicySpec) -> Result<PolicyRef, SpecError> + Send + Sync>;

/// Constructs policies by wire name. See module docs.
pub struct PolicyRegistry {
    builders: BTreeMap<String, Builder>,
    /// Named presets: alias → the spec it expands to (see module docs).
    aliases: BTreeMap<String, PolicySpec>,
    /// Server-side directory `coeffs_file` parameters resolve against
    /// (None = the parameter is refused; see module docs).
    coeffs_dir: Option<std::path::PathBuf>,
    /// Parsed coefficient tables memoized by resolved path: each file is
    /// read and parsed once per process, so per-request builds of a
    /// persisted-OLS policy are served from memory (a changed file on
    /// disk is picked up on restart — deliberate, so in-flight traffic
    /// never sees a half-written table). Mutex (not RefCell) because the
    /// registry is shared across connection threads.
    coeffs_cache: std::sync::Mutex<BTreeMap<std::path::PathBuf, Value>>,
}

impl fmt::Debug for PolicyRegistry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PolicyRegistry")
            .field("names", &self.names())
            .finish()
    }
}

impl PolicyRegistry {
    /// An empty registry (for fully custom policy sets).
    pub fn new() -> PolicyRegistry {
        PolicyRegistry {
            builders: BTreeMap::new(),
            aliases: BTreeMap::new(),
            coeffs_dir: None,
            coeffs_cache: std::sync::Mutex::new(BTreeMap::new()),
        }
    }

    /// Configure the server-side directory that `coeffs_file` parameters
    /// resolve against (`agd serve --coeffs-dir DIR`). Without it, specs
    /// naming a `coeffs_file` are refused with a pointer to the flag.
    pub fn set_coeffs_dir(&mut self, dir: impl Into<std::path::PathBuf>) {
        self.coeffs_dir = Some(dir.into());
    }

    /// The built-in set: the eight paper policies plus the
    /// [`crate::coordinator::ext`] plugins.
    pub fn builtin() -> PolicyRegistry {
        let mut reg = PolicyRegistry::new();
        reg.register("cfg", |spec| {
            Ok(Cfg {
                s: spec.f32_or("s", 7.5)?,
            }
            .into_ref())
        });
        reg.register("cond", |_spec| Ok(CondOnly.into_ref()));
        reg.register("ag", |spec| {
            Ok(Ag {
                s: spec.f32_or("s", 7.5)?,
                gamma_bar: spec.f64_or("gamma_bar", 0.9988)?,
            }
            .into_ref())
        });
        reg.register("ag-prefix", |spec| {
            Ok(AgFixedPrefix {
                s: spec.f32_or("s", 7.5)?,
                cfg_steps: spec.usize_or("cfg_steps", 5)?,
            }
            .into_ref())
        });
        reg.register("alternating", |spec| {
            Ok(AlternatingCfg {
                s: spec.f32_or("s", 7.5)?,
            }
            .into_ref())
        });
        reg.register("linear-ag", |spec| {
            let v = spec.get("coeffs").ok_or_else(|| spec.missing("coeffs"))?;
            let coeffs = OlsCoeffs::from_json(v)
                .ok_or_else(|| spec.bad("coeffs", "expected {beta_c, beta_u} arrays"))?;
            Ok(LinearAg {
                s: spec.f32_or("s", 7.5)?,
                coeffs: Arc::new(coeffs),
            }
            .into_ref())
        });
        reg.register("searched", |spec| {
            let default_s = spec.f32_or("s", 7.5)?;
            let arr = spec
                .get("choices")
                .and_then(Value::as_arr)
                .ok_or_else(|| spec.missing("choices"))?;
            let choices = arr
                .iter()
                .map(|v| choice_from_json(spec, v, default_s))
                .collect::<Result<Vec<StepChoice>, SpecError>>()?;
            Ok(Searched { choices }.into_ref())
        });
        reg.register("pix2pix", |spec| {
            Ok(Pix2Pix {
                s_text: spec.f32_or("s_text", 7.5)?,
                s_img: spec.f32_or("s_img", 1.5)?,
                gamma_bar: spec.opt_f64("gamma_bar")?,
                full_prefix: spec.opt_usize("full_prefix")?,
            }
            .into_ref())
        });
        crate::coordinator::ext::register(&mut reg);
        reg
    }

    /// Register (or replace) a builder under a wire name.
    pub fn register<F>(&mut self, name: &str, builder: F)
    where
        F: Fn(&PolicySpec) -> Result<PolicyRef, SpecError> + Send + Sync + 'static,
    {
        self.builders.insert(name.to_owned(), Box::new(builder));
    }

    /// Register a named alias: a preset spec the alias expands to. The
    /// target is validated *now* — an unknown kind or bad parameters are a
    /// registration error, so a typo fails at registration rather than on
    /// the first request. Alias names must not shadow a registered
    /// builder, and an alias referencing another alias must be registered
    /// *after* its target (use [`Self::load_alias_file`] for
    /// order-independent bulk loading).
    pub fn register_alias(&mut self, name: &str, target: PolicySpec) -> Result<(), SpecError> {
        if self.builders.contains_key(canonical(name)) {
            return Err(SpecError::BadSpec {
                msg: format!("alias `{name}` shadows a registered policy"),
            });
        }
        // full dry-run build so every parameter is checked (including any
        // coeffs_file reference — a missing file fails at registration)
        let mut resolved = self.resolve(&target)?;
        self.load_coeffs_file(&mut resolved)?;
        match self.builders.get(canonical(&resolved.kind)) {
            Some(b) => b(&resolved).map(|_| ())?,
            None => {
                return Err(SpecError::UnknownPolicy {
                    kind: resolved.kind.clone(),
                    known: self.names(),
                })
            }
        }
        self.aliases.insert(name.to_owned(), target);
        Ok(())
    }

    /// Extend the registry with aliases from a JSON file mapping alias →
    /// spec (object or bare-name string; see module docs). Returns how
    /// many aliases were loaded; any unreadable file, non-object document,
    /// or invalid spec is an error.
    ///
    /// Loading is two-pass — every name is registered before any target is
    /// validated — so aliases may reference each other regardless of their
    /// order in the file (unlike [`Self::register_alias`], which validates
    /// eagerly and therefore needs dependency order). On any error the
    /// registry is left exactly as it was.
    pub fn load_alias_file(&mut self, path: &str) -> Result<usize, SpecError> {
        let text = std::fs::read_to_string(path).map_err(|e| SpecError::BadSpec {
            msg: format!("policy file `{path}`: {e}"),
        })?;
        let v = json::parse(&text).map_err(|e| SpecError::BadSpec {
            msg: format!("policy file `{path}`: {e}"),
        })?;
        let Some(entries) = v.as_obj() else {
            return Err(SpecError::BadSpec {
                msg: format!("policy file `{path}`: expected an object of alias → spec"),
            });
        };
        // pass 1: parse + insert every alias name, remembering what each
        // insertion displaced so an error can restore the exact prior state
        let mut added: Vec<(String, Option<PolicySpec>)> = Vec::new();
        let mut first_err: Option<SpecError> = None;
        for (alias, spec_json) in entries {
            if self.builders.contains_key(canonical(alias)) {
                first_err = Some(SpecError::BadSpec {
                    msg: format!(
                        "policy file `{path}`, alias `{alias}`: shadows a registered policy"
                    ),
                });
                break;
            }
            match PolicySpec::from_json(spec_json) {
                Ok(target) => {
                    let prev = self.aliases.insert(alias.clone(), target);
                    added.push((alias.clone(), prev));
                }
                Err(e) => {
                    first_err = Some(SpecError::BadSpec {
                        msg: format!("policy file `{path}`, alias `{alias}`: {e}"),
                    });
                    break;
                }
            }
        }
        // pass 2: validate each alias by a dry-run build (resolves chains
        // and trips the cycle guard)
        if first_err.is_none() {
            for (alias, _) in &added {
                if let Err(e) = self.build(&PolicySpec::new(alias)) {
                    first_err = Some(SpecError::BadSpec {
                        msg: format!("policy file `{path}`, alias `{alias}`: {e}"),
                    });
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            // unwind newest-first so re-inserted entries win over removals
            for (alias, prev) in added.into_iter().rev() {
                match prev {
                    Some(spec) => {
                        self.aliases.insert(alias, spec);
                    }
                    None => {
                        self.aliases.remove(&alias);
                    }
                }
            }
            return Err(e);
        }
        Ok(added.len())
    }

    /// Expand aliases: while the spec's kind names an alias, merge the
    /// spec's parameters *over* the alias target's (explicit request
    /// values beat preset values) and continue with the target's kind.
    /// Non-alias kinds pass through untouched; [`Self::build`] reports
    /// unknown ones. Front-ends that inject their own defaults (the
    /// server) resolve first so presets beat server defaults.
    pub fn resolve(&self, spec: &PolicySpec) -> Result<PolicySpec, SpecError> {
        let mut cur = spec.clone();
        let mut hops = 0;
        while let Some(target) = self.aliases.get(canonical(&cur.kind)) {
            hops += 1;
            if hops > 8 {
                return Err(SpecError::BadSpec {
                    msg: format!("policy alias cycle at `{}`", cur.kind),
                });
            }
            let mut merged = target.clone();
            for (k, v) in cur.params {
                merged.params.insert(k, v);
            }
            cur = merged;
        }
        Ok(cur)
    }

    /// Registered wire names (builders and aliases), sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .builders
            .keys()
            .chain(self.aliases.keys())
            .cloned()
            .collect();
        names.sort();
        names
    }

    /// Resolve a `coeffs_file` parameter (if any) into inline `coeffs` by
    /// reading the named file from the configured coefficients directory.
    /// Policy-agnostic on purpose: any builder that reads `coeffs` gains
    /// the persisted path for free. Explicit inline `coeffs` win.
    fn load_coeffs_file(&self, spec: &mut PolicySpec) -> Result<(), SpecError> {
        let Some(v) = spec.get("coeffs_file") else {
            return Ok(());
        };
        let Some(name) = v.as_str().map(str::to_owned) else {
            return Err(spec.bad("coeffs_file", "expected a file name string"));
        };
        if spec.get("coeffs").is_some() {
            // an explicit inline table beats the server-side reference
            spec.params.remove("coeffs_file");
            return Ok(());
        }
        let Some(dir) = &self.coeffs_dir else {
            return Err(spec.bad(
                "coeffs_file",
                "no server-side coefficients directory configured \
                 (start with --coeffs-dir DIR, or inline `coeffs`)",
            ));
        };
        // clients name files, the server owns the directory: only plain
        // relative paths, no `..`/absolute escape hatches
        let rel = std::path::Path::new(&name);
        let plain = !rel.as_os_str().is_empty()
            && rel
                .components()
                .all(|c| matches!(c, std::path::Component::Normal(_)));
        if !plain {
            return Err(spec.bad(
                "coeffs_file",
                format!("`{name}` must be a plain relative path inside the coefficients directory"),
            ));
        }
        let path = dir.join(rel);
        let mut cache = self
            .coeffs_cache
            .lock()
            .expect("coeffs cache lock poisoned");
        let coeffs = match cache.get(&path) {
            Some(v) => v.clone(),
            None => {
                let text = std::fs::read_to_string(&path)
                    .map_err(|e| spec.bad("coeffs_file", format!("`{name}`: {e}")))?;
                let v = json::parse(&text).map_err(|e| {
                    spec.bad("coeffs_file", format!("`{name}`: not valid JSON: {e}"))
                })?;
                cache.insert(path, v.clone());
                v
            }
        };
        drop(cache);
        spec.params.remove("coeffs_file");
        spec.params.insert("coeffs".to_owned(), coeffs);
        Ok(())
    }

    /// Construct the policy a spec describes (aliases resolve first, then
    /// any `coeffs_file` reference loads from the coefficients directory).
    pub fn build(&self, spec: &PolicySpec) -> Result<PolicyRef, SpecError> {
        let mut spec = self.resolve(spec)?;
        self.load_coeffs_file(&mut spec)?;
        match self.builders.get(canonical(&spec.kind)) {
            Some(b) => b(&spec),
            None => Err(SpecError::UnknownPolicy {
                kind: spec.kind.clone(),
                known: self.names(),
            }),
        }
    }
}

impl Default for PolicyRegistry {
    fn default() -> PolicyRegistry {
        PolicyRegistry::builtin()
    }
}

/// One searched-policy step choice from its wire form:
/// `"uncond" | "cond" | "cfg" | <number> | {"cfg": s}`.
fn choice_from_json(
    spec: &PolicySpec,
    v: &Value,
    default_s: f32,
) -> Result<StepChoice, SpecError> {
    match v {
        Value::Str(t) if t == "uncond" => Ok(StepChoice::Uncond),
        Value::Str(t) if t == "cond" => Ok(StepChoice::Cond),
        Value::Str(t) if t == "cfg" => Ok(StepChoice::Cfg { s: default_s }),
        Value::Num(n) => Ok(StepChoice::Cfg { s: *n as f32 }),
        Value::Obj(_) => v
            .get("cfg")
            .and_then(Value::as_f64)
            .map(|s| StepChoice::Cfg { s: s as f32 })
            .ok_or_else(|| spec.bad("choices", "object entries must be {\"cfg\": s}")),
        _ => Err(spec.bad(
            "choices",
            "entries must be \"uncond\" | \"cond\" | \"cfg\" | number | {\"cfg\": s}",
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::PolicyState;

    /// One fully-parameterized spec per registered policy.
    fn example_specs() -> Vec<PolicySpec> {
        vec![
            PolicySpec::new("cfg").with("s", json::num(2.0)),
            PolicySpec::new("cond"),
            PolicySpec::new("ag")
                .with("s", json::num(2.0))
                .with("gamma_bar", json::num(0.99)),
            PolicySpec::new("ag-prefix")
                .with("s", json::num(2.0))
                .with("cfg_steps", json::num(3.0)),
            PolicySpec::new("alternating").with("s", json::num(2.0)),
            PolicySpec::new("linear-ag")
                .with("s", json::num(2.0))
                .with("coeffs", OlsCoeffs::identity(8).to_json()),
            PolicySpec::new("searched").with(
                "choices",
                json::arr(vec![
                    json::s("cond"),
                    json::num(2.5),
                    json::s("uncond"),
                    json::obj(vec![("cfg", json::num(3.0))]),
                ]),
            ),
            PolicySpec::new("pix2pix")
                .with("s_text", json::num(2.0))
                .with("s_img", json::num(1.0))
                .with("gamma_bar", json::num(0.99))
                .with("full_prefix", json::num(3.0)),
            PolicySpec::new("compressed-cfg")
                .with("s", json::num(2.0))
                .with("period", json::num(3.0)),
            PolicySpec::new("adaptive-scale")
                .with("s_max", json::num(3.0))
                .with("s_min", json::num(1.0))
                .with("gamma_lo", json::num(0.5))
                .with("gamma_hi", json::num(0.99)),
        ]
    }

    #[test]
    fn every_registered_policy_round_trips_through_json() {
        let reg = PolicyRegistry::builtin();
        let specs = example_specs();
        // the example list covers the whole registry
        let mut covered: Vec<String> =
            specs.iter().map(|s| s.canonical_kind().to_owned()).collect();
        covered.sort();
        assert_eq!(covered, reg.names(), "add a round-trip example for new policies");

        for spec in specs {
            let p1 = reg.build(&spec).unwrap_or_else(|e| panic!("{e}"));
            // serialize the fully-explicit spec and re-parse it
            let text = json::to_string(&p1.spec().to_json());
            let spec2 = PolicySpec::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(spec2, p1.spec(), "{text}");
            let p2 = reg.build(&spec2).unwrap();
            assert_eq!(p1.name(), p2.name());
            // the cheap label accessor must agree with the full spec
            assert_eq!(p1.kind(), p1.spec().kind);
            // identical plan sequences under a fresh state
            let st = PolicyState::new();
            for i in 0..8 {
                assert_eq!(p1.plan(i, 8, &st), p2.plan(i, 8, &st), "step {i} of {text}");
            }
            assert_eq!(p1.max_nfes(8), p2.max_nfes(8));
        }
    }

    #[test]
    fn unknown_policy_error_lists_registered_names() {
        let reg = PolicyRegistry::builtin();
        let err = reg.build(&PolicySpec::new("warp")).unwrap_err();
        match &err {
            SpecError::UnknownPolicy { kind, known } => {
                assert_eq!(kind, "warp");
                assert!(known.contains(&"ag".to_owned()));
                assert!(known.contains(&"compressed-cfg".to_owned()));
                assert!(known.contains(&"adaptive-scale".to_owned()));
            }
            other => panic!("expected UnknownPolicy, got {other:?}"),
        }
        assert!(err.to_string().contains("registered:"));
    }

    #[test]
    fn kind_aliases_resolve() {
        let reg = PolicyRegistry::builtin();
        for name in ["cond", "cond-only", "distilled"] {
            let p = reg.build(&PolicySpec::new(name)).unwrap();
            assert_eq!(p.name(), "cond-only");
        }
    }

    #[test]
    fn bare_names_and_inline_json_parse() {
        let spec = PolicySpec::parse("ag").unwrap();
        assert_eq!(spec.kind, "ag");
        assert!(spec.params.is_empty());
        let spec = PolicySpec::parse(r#"{"kind": "cfg", "s": 3.5}"#).unwrap();
        assert_eq!(spec.kind, "cfg");
        assert_eq!(spec.f64_or("s", 0.0).unwrap(), 3.5);
        assert!(PolicySpec::parse("").is_err());
        assert!(PolicySpec::parse("{not json").is_err());
    }

    #[test]
    fn defaults_do_not_override_explicit_params() {
        let mut spec = PolicySpec::new("ag").with("gamma_bar", json::num(0.5));
        spec.set_default("gamma_bar", json::num(0.9988));
        spec.set_default("s", json::num(7.5));
        assert_eq!(spec.f64_or("gamma_bar", 0.0).unwrap(), 0.5);
        assert_eq!(spec.f64_or("s", 0.0).unwrap(), 7.5);
    }

    #[test]
    fn bad_and_missing_fields_are_reported() {
        let reg = PolicyRegistry::builtin();
        // wrong type
        let err = reg
            .build(&PolicySpec::new("cfg").with("s", json::s("seven")))
            .unwrap_err();
        assert!(matches!(err, SpecError::BadField { field: "s", .. }), "{err}");
        // linear-ag without coefficients
        let err = reg.build(&PolicySpec::new("linear-ag")).unwrap_err();
        assert!(
            matches!(err, SpecError::MissingField { field: "coeffs", .. }),
            "{err}"
        );
        // searched without choices
        let err = reg.build(&PolicySpec::new("searched")).unwrap_err();
        assert!(
            matches!(err, SpecError::MissingField { field: "choices", .. }),
            "{err}"
        );
        // null counts as absent
        let p = reg
            .build(&PolicySpec::new("pix2pix").with("gamma_bar", Value::Null))
            .unwrap();
        assert_eq!(p.name(), "pix2pix");
    }

    #[test]
    fn aliases_expand_with_request_params_winning() {
        let mut reg = PolicyRegistry::builtin();
        reg.register_alias(
            "fast-ag",
            PolicySpec::new("ag")
                .with("gamma_bar", json::num(0.5))
                .with("s", json::num(3.0)),
        )
        .unwrap();
        assert!(reg.names().contains(&"fast-ag".to_owned()));
        // bare use: the preset's parameters apply
        let p = reg.build(&PolicySpec::new("fast-ag")).unwrap();
        assert_eq!(p.name(), "ag(ḡ=0.5)");
        // explicit request params override the preset
        let p = reg
            .build(&PolicySpec::new("fast-ag").with("gamma_bar", json::num(0.9)))
            .unwrap();
        assert_eq!(p.name(), "ag(ḡ=0.9)");
        // resolve() exposes the merged spec so front-ends can layer their
        // defaults *under* the preset
        let spec = reg.resolve(&PolicySpec::new("fast-ag")).unwrap();
        assert_eq!(spec.canonical_kind(), "ag");
        assert_eq!(spec.f64_or("s", 0.0).unwrap(), 3.0);
        // unknown kinds pass through resolve and fail at build with the
        // full name list (aliases included)
        let err = reg.build(&PolicySpec::new("warp")).unwrap_err();
        match err {
            SpecError::UnknownPolicy { known, .. } => {
                assert!(known.contains(&"fast-ag".to_owned()));
            }
            other => panic!("expected UnknownPolicy, got {other:?}"),
        }
    }

    #[test]
    fn bad_aliases_fail_at_registration() {
        let mut reg = PolicyRegistry::builtin();
        // unknown target kind
        assert!(reg.register_alias("x", PolicySpec::new("warp")).is_err());
        // bad parameter type
        assert!(reg
            .register_alias("y", PolicySpec::new("cfg").with("s", json::s("seven")))
            .is_err());
        // missing required field
        assert!(reg.register_alias("z", PolicySpec::new("searched")).is_err());
        // shadowing a builtin
        assert!(reg.register_alias("cfg", PolicySpec::new("ag")).is_err());
        // nothing leaked into the name list
        assert_eq!(reg.names(), PolicyRegistry::builtin().names());
    }

    #[test]
    fn alias_file_round_trip() {
        let path = std::env::temp_dir().join("agd_policy_aliases_test.json");
        std::fs::write(
            &path,
            r#"{"bulk": "cond",
                "fast-ag": {"kind": "ag", "gamma_bar": 0.9, "s": 2.0}}"#,
        )
        .unwrap();
        let mut reg = PolicyRegistry::builtin();
        let n = reg.load_alias_file(path.to_str().unwrap()).unwrap();
        assert_eq!(n, 2);
        assert_eq!(reg.build(&PolicySpec::new("bulk")).unwrap().name(), "cond-only");
        assert_eq!(
            reg.build(&PolicySpec::new("fast-ag")).unwrap().name(),
            "ag(ḡ=0.9)"
        );
        std::fs::remove_file(&path).ok();
        // unreadable file / bad document are startup errors
        assert!(reg.load_alias_file("/nonexistent/aliases.json").is_err());
        let bad = std::env::temp_dir().join("agd_policy_aliases_bad.json");
        std::fs::write(&bad, "[1, 2]").unwrap();
        assert!(reg.load_alias_file(bad.to_str().unwrap()).is_err());
        std::fs::remove_file(&bad).ok();
    }

    #[test]
    fn alias_file_chains_load_regardless_of_order() {
        // "a-fast" references "base" but sorts before it — two-pass
        // loading must still accept the file.
        let path = std::env::temp_dir().join("agd_policy_aliases_chain.json");
        std::fs::write(
            &path,
            r#"{"a-fast": {"kind": "base", "s": 3.0},
                "base": {"kind": "ag", "gamma_bar": 0.9}}"#,
        )
        .unwrap();
        let mut reg = PolicyRegistry::builtin();
        assert_eq!(reg.load_alias_file(path.to_str().unwrap()).unwrap(), 2);
        let p = reg.build(&PolicySpec::new("a-fast")).unwrap();
        assert_eq!(p.name(), "ag(ḡ=0.9)");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_alias_file_leaves_the_registry_untouched() {
        let path = std::env::temp_dir().join("agd_policy_aliases_partial.json");
        // "good" is fine on its own, but "missing" targets an unknown kind
        std::fs::write(
            &path,
            r#"{"good": "cond", "missing": {"kind": "warp"}}"#,
        )
        .unwrap();
        let mut reg = PolicyRegistry::builtin();
        // a pre-existing alias that the failing file tries to redefine
        reg.register_alias("good", PolicySpec::new("cfg").with("s", json::num(9.0)))
            .unwrap();
        assert!(reg.load_alias_file(path.to_str().unwrap()).is_err());
        // the failed load restored the *prior* definition, not deleted it
        assert_eq!(reg.build(&PolicySpec::new("good")).unwrap().name(), "cfg(s=9)");
        assert!(reg.build(&PolicySpec::new("missing")).is_err());
        std::fs::remove_file(&path).ok();

        // alias-to-alias cycles are caught at load, not first request
        let before = reg.names();
        let cyc = std::env::temp_dir().join("agd_policy_aliases_cycle.json");
        std::fs::write(&cyc, r#"{"ping": "pong", "pong": "ping"}"#).unwrap();
        let err = reg.load_alias_file(cyc.to_str().unwrap()).unwrap_err();
        assert!(err.to_string().contains("cycle"), "{err}");
        assert_eq!(reg.names(), before);
        std::fs::remove_file(&cyc).ok();
    }

    #[test]
    fn coeffs_file_resolves_against_the_server_directory() {
        let dir = std::env::temp_dir().join("agd_coeffs_dir_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("id8.json"),
            json::to_string(&OlsCoeffs::identity(8).to_json()),
        )
        .unwrap();

        // without a configured directory the parameter is refused
        let mut reg = PolicyRegistry::builtin();
        let spec = PolicySpec::new("linear-ag").with("coeffs_file", json::s("id8.json"));
        let err = reg.build(&spec).unwrap_err();
        assert!(err.to_string().contains("--coeffs-dir"), "{err}");

        reg.set_coeffs_dir(&dir);
        let p = reg.build(&spec).unwrap();
        assert!(p.name().starts_with("linear-ag"), "{}", p.name());
        // the built policy carries the loaded table, not the file name
        assert!(p.spec().get("coeffs").is_some());
        assert!(p.spec().get("coeffs_file").is_none());

        // inline coeffs win over the file reference
        let both = PolicySpec::new("linear-ag")
            .with("coeffs_file", json::s("missing.json"))
            .with("coeffs", OlsCoeffs::identity(4).to_json());
        assert!(reg.build(&both).is_ok(), "inline coeffs must short-circuit the file");

        // traversal and absolute paths are refused, named files must exist
        for bad in ["../secrets.json", "/etc/passwd", ""] {
            let spec = PolicySpec::new("linear-ag").with("coeffs_file", json::s(bad));
            let err = reg.build(&spec).unwrap_err();
            assert!(
                err.to_string().contains("plain relative path"),
                "{bad}: {err}"
            );
        }
        let spec = PolicySpec::new("linear-ag").with("coeffs_file", json::s("nope.json"));
        assert!(reg.build(&spec).is_err());
        // non-JSON content is a structured error
        std::fs::write(dir.join("garbage.json"), "not json").unwrap();
        let spec = PolicySpec::new("linear-ag").with("coeffs_file", json::s("garbage.json"));
        let err = reg.build(&spec).unwrap_err();
        assert!(err.to_string().contains("not valid JSON"), "{err}");

        // aliases referencing a coeffs_file are validated at registration
        reg.register_alias(
            "persisted",
            PolicySpec::new("linear-ag").with("coeffs_file", json::s("id8.json")),
        )
        .unwrap();
        assert!(reg.build(&PolicySpec::new("persisted")).is_ok());
        assert!(reg
            .register_alias(
                "broken",
                PolicySpec::new("linear-ag").with("coeffs_file", json::s("nope.json")),
            )
            .is_err());

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_cli_builds_any_policy() {
        let args = |s: &str| Args::parse(s.split_whitespace().map(str::to_owned));
        let reg = PolicyRegistry::builtin();

        let spec = PolicySpec::from_cli(&args("--policy ag --guidance 2 --gamma-bar 0.9")).unwrap();
        let p = reg.build(&spec).unwrap();
        assert_eq!(p.name(), "ag(ḡ=0.9)");

        let spec =
            PolicySpec::from_cli(&args("--policy compressed-cfg --period 5 --guidance 2")).unwrap();
        assert_eq!(reg.build(&spec).unwrap().max_nfes(10), 12);

        let spec = PolicySpec::from_cli(&args("--policy searched --choices cfg,cond,2.5")).unwrap();
        let p = reg.build(&spec).unwrap();
        assert_eq!(p.max_nfes(3), 5);

        // inline JSON with a flag override
        let spec = PolicySpec::from_cli(&args(
            "--policy {\"kind\":\"ag-prefix\",\"cfg_steps\":2} --guidance 3",
        ))
        .unwrap();
        let p = reg.build(&spec).unwrap();
        assert_eq!(p.max_nfes(10), 12);

        assert!(PolicySpec::from_cli(&args("--policy ag --guidance abc")).is_err());
    }

    #[test]
    fn every_registered_name_is_reachable_from_the_cli() {
        let args = |s: &str| Args::parse(s.split_whitespace().map(str::to_owned));
        let reg = PolicyRegistry::builtin();
        for name in reg.names() {
            // policies with required structured params get them via flags
            let extra = match name.as_str() {
                "searched" => " --choices cfg,cond",
                _ => "",
            };
            let line = format!("--policy {name}{extra}");
            let mut spec = PolicySpec::from_cli(&args(&line)).unwrap();
            if name == "linear-ag" {
                // --coeffs takes a file path; inject the value directly here
                spec.params
                    .insert("coeffs".into(), OlsCoeffs::identity(4).to_json());
            }
            let p = reg
                .build(&spec)
                .unwrap_or_else(|e| panic!("--policy {name}: {e}"));
            assert!(p.max_nfes(4) >= 4, "{name}");
        }
    }
}
