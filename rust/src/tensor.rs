//! Host-side tensors: the coordinator's working representation of latents
//! and score estimates (dense f32, row-major). Conversions to/from
//! `xla::Literal` live in `runtime/`; everything in the policy/solver hot
//! path operates on these buffers directly.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Squared L2 norm.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Cosine similarity with another tensor (Eq. 7's gamma when applied to
    /// score estimates) — the pure-Rust mirror of the fused kernel's scalar.
    pub fn cosine(&self, other: &Tensor) -> f64 {
        assert_eq!(self.len(), other.len());
        let mut dot = 0f64;
        let mut na = 0f64;
        let mut nb = 0f64;
        for (&a, &b) in self.data.iter().zip(&other.data) {
            dot += a as f64 * b as f64;
            na += a as f64 * a as f64;
            nb += b as f64 * b as f64;
        }
        dot / (na.sqrt() * nb.sqrt()).max(1e-12)
    }

    /// `self += alpha * other` (LINEARAG's accumulation primitive).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.len(), other.len());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Mean squared error against another tensor.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.len(), other.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum::<f64>()
            / self.len() as f64
    }

    /// CFG combine (Eq. 3) done host-side: `u + s * (c - u)`. Used by the
    /// GmmBackend path and LINEARAG (where the "u" is an OLS estimate that
    /// never went through the device).
    pub fn cfg_combine(cond: &Tensor, uncond: &Tensor, s: f32) -> Tensor {
        assert_eq!(cond.len(), uncond.len());
        let data = cond
            .data
            .iter()
            .zip(&uncond.data)
            .map(|(&c, &u)| u + s * (c - u))
            .collect();
        Tensor::new(cond.shape.clone(), data)
    }
}

/// Dense row-major i32 tensor (token inputs).
#[derive(Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl fmt::Debug for TensorI32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TensorI32{:?}{:?}", self.shape, self.data)
    }
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> TensorI32 {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI32 { shape, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[should_panic(expected = "does not match")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn cosine_identities() {
        let a = Tensor::new(vec![4], vec![1.0, 2.0, -1.0, 0.5]);
        let mut b = a.clone();
        assert!((a.cosine(&b) - 1.0).abs() < 1e-9);
        b.scale(-3.0);
        assert!((a.cosine(&b) + 1.0).abs() < 1e-9);
        let c = Tensor::new(vec![4], vec![2.0, -1.0, 0.0, 0.0]);
        // orthogonal: 1*2 + 2*(-1) = 0
        assert!(a.cosine(&c).abs() < 1e-9);
    }

    #[test]
    fn cfg_combine_matches_formula() {
        let c = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let u = Tensor::new(vec![3], vec![0.0, 1.0, 2.0]);
        let out = Tensor::cfg_combine(&c, &u, 7.5);
        assert_eq!(out.data, vec![7.5, 8.5, 9.5]);
        // s = 1 → conditional
        assert_eq!(Tensor::cfg_combine(&c, &u, 1.0).data, c.data);
        // s = 0 → unconditional
        assert_eq!(Tensor::cfg_combine(&c, &u, 0.0).data, u.data);
    }

    #[test]
    fn axpy_and_mse() {
        let mut a = Tensor::zeros(vec![3]);
        let b = Tensor::new(vec![3], vec![1.0, -2.0, 4.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![0.5, -1.0, 2.0]);
        assert!((a.mse(&b) - ((0.25 + 1.0 + 4.0) / 3.0)).abs() < 1e-6);
    }
}
