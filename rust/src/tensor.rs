//! Host-side tensors: the coordinator's working representation of latents
//! and score estimates (dense f32, row-major). Conversions to/from
//! `xla::Literal` live in `runtime/`; everything in the policy/solver hot
//! path operates on these buffers directly.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Squared L2 norm.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Cosine similarity with another tensor (Eq. 7's gamma when applied to
    /// score estimates) — the pure-Rust mirror of the fused kernel's scalar.
    pub fn cosine(&self, other: &Tensor) -> f64 {
        assert_eq!(self.len(), other.len());
        let mut dot = 0f64;
        let mut na = 0f64;
        let mut nb = 0f64;
        for (&a, &b) in self.data.iter().zip(&other.data) {
            dot += a as f64 * b as f64;
            na += a as f64 * a as f64;
            nb += b as f64 * b as f64;
        }
        dot / (na.sqrt() * nb.sqrt()).max(1e-12)
    }

    /// `self += alpha * other` (LINEARAG's accumulation primitive).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.len(), other.len());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Mean squared error against another tensor.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.len(), other.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum::<f64>()
            / self.len() as f64
    }

    /// CFG combine (Eq. 3) done host-side: `u + s * (c - u)`. Used by the
    /// GmmBackend path and LINEARAG (where the "u" is an OLS estimate that
    /// never went through the device).
    pub fn cfg_combine(cond: &Tensor, uncond: &Tensor, s: f32) -> Tensor {
        assert_eq!(cond.len(), uncond.len());
        let data = cond
            .data
            .iter()
            .zip(&uncond.data)
            .map(|(&c, &u)| u + s * (c - u))
            .collect();
        Tensor::new(cond.shape.clone(), data)
    }
}

/// Both gamma probes of one guided step, as produced by
/// [`combine_and_gamma`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CombineGamma {
    /// Eq. 7's cosine on the x0 data predictions (the AG signal; see
    /// `request.rs` for why the re-parameterization is used).
    pub gamma_x0: f64,
    /// Eq. 7's cosine on the raw eps predictions (the paper's printed form).
    pub gamma_eps: f64,
}

/// Fused CFG combine (Eq. 3) + both gamma probes in **one pass** over the
/// two score buffers: `eps_out[i] = u + s (c - u)`, the raw-eps cosine, and
/// the x0-re-parameterized cosine (`x0 = j_x x + j_eps eps`). The seed path
/// traversed `c`/`u` three times ([`Tensor::cfg_combine`], [`Tensor::cosine`]
/// and the x0 probe); this keeps every accumulator's per-index operation
/// order identical, so the results are bit-identical to the unfused path
/// (pinned by `fused_combine_matches_unfused_path`).
pub fn combine_and_gamma(
    cond: &[f32],
    uncond: &[f32],
    s: f32,
    x: &[f32],
    j_x: f32,
    j_eps: f32,
    eps_out: &mut [f32],
) -> CombineGamma {
    assert_eq!(cond.len(), uncond.len());
    assert_eq!(cond.len(), x.len());
    assert_eq!(cond.len(), eps_out.len());
    let (mut dot_e, mut na_e, mut nb_e) = (0f64, 0f64, 0f64);
    let (mut dot_x, mut na_x, mut nb_x) = (0f64, 0f64, 0f64);
    for i in 0..cond.len() {
        let c = cond[i];
        let u = uncond[i];
        eps_out[i] = u + s * (c - u);
        dot_e += c as f64 * u as f64;
        na_e += c as f64 * c as f64;
        nb_e += u as f64 * u as f64;
        let xa = (j_x * x[i] + j_eps * c) as f64;
        let xb = (j_x * x[i] + j_eps * u) as f64;
        dot_x += xa * xb;
        na_x += xa * xa;
        nb_x += xb * xb;
    }
    CombineGamma {
        gamma_x0: dot_x / (na_x.sqrt() * nb_x.sqrt()).max(1e-12),
        gamma_eps: dot_e / (na_e.sqrt() * nb_e.sqrt()).max(1e-12),
    }
}

/// Fused editing combine (Eq. 9) + the instruction-pair gamma in one pass:
/// `eps_out = null + s_text (full - img) + s_img (img - null)` accumulated
/// in exactly the seed path's axpy order (term by term, so the f32 sums are
/// bit-identical), returning `cosine(full, img)`.
pub fn edit_combine_and_gamma(
    full: &[f32],
    img: &[f32],
    null: &[f32],
    s_text: f32,
    s_img: f32,
    eps_out: &mut [f32],
) -> f64 {
    assert_eq!(full.len(), img.len());
    assert_eq!(full.len(), null.len());
    assert_eq!(full.len(), eps_out.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for i in 0..full.len() {
        let f = full[i];
        let g = img[i];
        let n = null[i];
        let mut v = n;
        v += s_text * f;
        v += -s_text * g;
        v += s_img * g;
        v += -s_img * n;
        eps_out[i] = v;
        dot += f as f64 * g as f64;
        na += f as f64 * f as f64;
        nb += g as f64 * g as f64;
    }
    dot / (na.sqrt() * nb.sqrt()).max(1e-12)
}

/// Dense row-major i32 tensor (token inputs).
#[derive(Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl fmt::Debug for TensorI32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TensorI32{:?}{:?}", self.shape, self.data)
    }
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> TensorI32 {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI32 { shape, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[should_panic(expected = "does not match")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn cosine_identities() {
        let a = Tensor::new(vec![4], vec![1.0, 2.0, -1.0, 0.5]);
        let mut b = a.clone();
        assert!((a.cosine(&b) - 1.0).abs() < 1e-9);
        b.scale(-3.0);
        assert!((a.cosine(&b) + 1.0).abs() < 1e-9);
        let c = Tensor::new(vec![4], vec![2.0, -1.0, 0.0, 0.0]);
        // orthogonal: 1*2 + 2*(-1) = 0
        assert!(a.cosine(&c).abs() < 1e-9);
    }

    #[test]
    fn cfg_combine_matches_formula() {
        let c = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let u = Tensor::new(vec![3], vec![0.0, 1.0, 2.0]);
        let out = Tensor::cfg_combine(&c, &u, 7.5);
        assert_eq!(out.data, vec![7.5, 8.5, 9.5]);
        // s = 1 → conditional
        assert_eq!(Tensor::cfg_combine(&c, &u, 1.0).data, c.data);
        // s = 0 → unconditional
        assert_eq!(Tensor::cfg_combine(&c, &u, 0.0).data, u.data);
    }

    #[test]
    fn fused_combine_matches_unfused_path() {
        // the fused kernel must be bit-identical to the seed sequence:
        // cfg_combine + cosine + the x0-re-parameterized cosine
        let mut rng = crate::util::rng::Rng::new(5);
        let dim = 96;
        let c = Tensor::new(vec![dim], rng.normal_vec(dim));
        let u = Tensor::new(vec![dim], rng.normal_vec(dim));
        let x = rng.normal_vec(dim);
        let (s, jx, je) = (7.5f32, 1.3f32, -0.8f32);

        let eps_ref = Tensor::cfg_combine(&c, &u, s);
        let gamma_eps_ref = c.cosine(&u);
        let xa: Vec<f32> = (0..dim).map(|i| jx * x[i] + je * c.data[i]).collect();
        let xb: Vec<f32> = (0..dim).map(|i| jx * x[i] + je * u.data[i]).collect();
        let gamma_x0_ref =
            Tensor::new(vec![dim], xa).cosine(&Tensor::new(vec![dim], xb));

        let mut eps = vec![0.0f32; dim];
        let g = combine_and_gamma(&c.data, &u.data, s, &x, jx, je, &mut eps);
        assert_eq!(eps, eps_ref.data);
        assert_eq!(g.gamma_eps, gamma_eps_ref);
        assert_eq!(g.gamma_x0, gamma_x0_ref);
    }

    #[test]
    fn fused_edit_combine_matches_axpy_sequence() {
        let mut rng = crate::util::rng::Rng::new(6);
        let dim = 64;
        let full = Tensor::new(vec![dim], rng.normal_vec(dim));
        let img = Tensor::new(vec![dim], rng.normal_vec(dim));
        let null = Tensor::new(vec![dim], rng.normal_vec(dim));
        let (s_text, s_img) = (7.5f32, 1.5f32);

        // the seed path's exact Eq. 9 accumulation
        let mut eps_ref = null.clone();
        eps_ref.axpy(s_text, &full);
        eps_ref.axpy(-s_text, &img);
        eps_ref.axpy(s_img, &img);
        eps_ref.axpy(-s_img, &null);
        let gamma_ref = full.cosine(&img);

        let mut eps = vec![0.0f32; dim];
        let gamma = edit_combine_and_gamma(
            &full.data, &img.data, &null.data, s_text, s_img, &mut eps,
        );
        assert_eq!(eps, eps_ref.data);
        assert_eq!(gamma, gamma_ref);
    }

    #[test]
    fn axpy_and_mse() {
        let mut a = Tensor::zeros(vec![3]);
        let b = Tensor::new(vec![3], vec![1.0, -2.0, 4.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![0.5, -1.0, 2.0]);
        assert!((a.mse(&b) - ((0.25 + 1.0 + 4.0) / 3.0)).abs() < 1e-6);
    }
}
