//! Procedural shape renderer — Rust mirror of `python/compile/data.py`'s
//! deterministic path (no augmentation). Used by the editing experiment
//! (source images for Eq. 9), attribute probes, and workload generation.
//!
//! Keep the geometry in sync with data.py: signed-distance masks with a 1px
//! anti-aliased edge over a 0.08-grey background, output in [-1, 1].

use crate::prompts::{Prompt, COLORS, POSITIONS, SHAPES, SIZES};

pub const IMG: usize = 16;

fn rgb_of(color: &str) -> [f64; 3] {
    match color {
        "red" => [0.9, 0.15, 0.15],
        "green" => [0.15, 0.85, 0.2],
        "blue" => [0.2, 0.3, 0.95],
        "yellow" => [0.9, 0.85, 0.2],
        "white" => [0.95, 0.95, 0.95],
        _ => unreachable!("unknown color {color}"),
    }
}

fn center_of(position: &str) -> (f64, f64) {
    match position {
        "center" => (8.0, 8.0),
        "top-left" => (4.5, 4.5),
        "top-right" => (4.5, 11.5),
        "bottom-left" => (11.5, 4.5),
        "bottom-right" => (11.5, 11.5),
        _ => unreachable!("unknown position {position}"),
    }
}

fn sdf(shape: &str, dy: f64, dx: f64, radius: f64) -> f64 {
    match shape {
        "circle" => (dy * dy + dx * dx).sqrt() - radius,
        "square" => dy.abs().max(dx.abs()) - radius,
        "triangle" => (dy - radius).max((-dy) * 0.5 + dx.abs() - radius),
        "cross" => {
            let bar = radius * 0.45;
            let h = (dy.abs() - bar).max(dx.abs() - radius);
            let v = (dx.abs() - bar).max(dy.abs() - radius);
            h.min(v)
        }
        _ => unreachable!("unknown shape {shape}"),
    }
}

/// Render a prompt to a flat `(16*16*3)` RGB image in [-1, 1]
/// (deterministic: matches `data.render(prompt, rng=None)`).
pub fn render(p: &Prompt) -> Vec<f32> {
    let (cy, cx) = center_of(POSITIONS[p.position]);
    let radius = if SIZES[p.size] == "small" { 2.4 } else { 4.2 };
    let rgb = rgb_of(COLORS[p.color]);
    let mut img = vec![0f32; IMG * IMG * 3];
    for y in 0..IMG {
        for x in 0..IMG {
            let d = sdf(SHAPES[p.shape], y as f64 - cy, x as f64 - cx, radius);
            let m = (0.5 - d).clamp(0.0, 1.0); // 1px anti-aliased edge
            for c in 0..3 {
                let v = 0.08 * (1.0 - m) + rgb[c] * m;
                img[(y * IMG + x) * 3 + c] = (v * 2.0 - 1.0) as f32;
            }
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::probe::color_dominance;

    #[test]
    fn renders_in_range() {
        for i in (0..200).step_by(13) {
            let img = render(&Prompt::nth(i));
            assert_eq!(img.len(), 768);
            assert!(img.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn color_is_where_it_should_be() {
        // large red circle at the center → red dominant in the shape region
        let p = Prompt { shape: 0, color: 0, position: 0, size: 1 };
        let img = render(&p);
        assert!(color_dominance(&img, IMG, IMG, 0) > 0.8);
        let center = &img[(8 * IMG + 8) * 3..(8 * IMG + 8) * 3 + 3];
        assert!(center[0] > 0.5 && center[1] < 0.0);
    }

    #[test]
    fn positions_are_distinct() {
        let imgs: Vec<Vec<f32>> = (0..5)
            .map(|pos| render(&Prompt { shape: 1, color: 2, position: pos, size: 1 }))
            .collect();
        for i in 0..5 {
            for j in i + 1..5 {
                let d: f32 = imgs[i]
                    .iter()
                    .zip(&imgs[j])
                    .map(|(&a, &b)| (a - b).abs())
                    .fold(0.0, f32::max);
                assert!(d > 0.5, "positions {i}/{j} too similar");
            }
        }
    }

    #[test]
    fn shapes_are_distinct() {
        let imgs: Vec<Vec<f32>> = (0..4)
            .map(|s| render(&Prompt { shape: s, color: 4, position: 0, size: 1 }))
            .collect();
        for i in 0..4 {
            for j in i + 1..4 {
                let d: f32 = imgs[i]
                    .iter()
                    .zip(&imgs[j])
                    .map(|(&a, &b)| (a - b).abs())
                    .fold(0.0, f32::max);
                assert!(d > 0.3, "shapes {i}/{j} too similar");
            }
        }
    }
}
