//! Analytic conditional diffusion oracle: a Gaussian-mixture data
//! distribution whose *exact* conditional and unconditional scores are
//! available in closed form.
//!
//! This is the PJRT-free test substrate for the whole coordinator: it
//! implements the same `Backend` interface as the AOT'd DiT denoiser, but
//! its epsilon-predictions come from the true posterior of a GMM, so
//! coordinator tests can assert *semantic* properties (AG truncation
//! behaviour, gamma convergence, policy NFE accounting, solver transport)
//! without any artifacts on disk.
//!
//! Math: for VP diffusion `x_t = a x0 + s eps` over a mixture
//! `p(x0 | c) = sum_k w_k(c) N(mu_k, v I)`, the marginal at time t is a
//! mixture of `N(a mu_k, (a^2 v + s^2) I)` and the MMSE noise prediction is
//!
//!   eps(x, t, c) = -s * score = sum_k r_k(x) * (x - a mu_k) * s / (a^2 v + s^2)
//!
//! with softmax responsibilities r_k. The unconditional score uses uniform
//! component weights; a condition selects a single component. As t -> 0 the
//! responsibilities of both collapse onto the mode nearest x, which is
//! exactly the cosine-similarity convergence (Eq. 7) the paper observes in
//! trained networks.

use crate::coordinator::solver;

/// Reusable responsibility scratch for [`Gmm::eps_into`]: holds the
/// per-component logits/softmax weights so the mixture score evaluates
/// without allocating. One scratch serves any number of sequential calls;
/// capacity settles at the component count after the first use.
#[derive(Debug, Clone, Default)]
pub struct GmmScratch {
    weights: Vec<f64>,
}

impl GmmScratch {
    /// Pre-reserve the per-component logit capacity so a scratch's first
    /// use performs no allocation — the sharded backend warms one scratch
    /// per worker lane up front, keeping the steady-state parallel path
    /// allocation-free even for a lane that sees its first mixture row
    /// late (`rust/tests/par_zero_alloc.rs`).
    pub fn warm(&mut self, components: usize) {
        self.weights.reserve(components);
    }
}

/// Conditional Gaussian-mixture score model.
#[derive(Debug, Clone)]
pub struct Gmm {
    pub dim: usize,
    /// component means, row-major `(k, dim)`
    pub means: Vec<Vec<f32>>,
    /// shared isotropic data variance
    pub var: f64,
}

impl Gmm {
    /// A well-separated mixture on coordinate axes — the default test model.
    pub fn axes(dim: usize, components: usize, radius: f32, var: f64) -> Gmm {
        assert!(components <= 2 * dim, "need an axis direction per component");
        let means = (0..components)
            .map(|k| {
                let mut m = vec![0.0f32; dim];
                let axis = k / 2;
                m[axis] = if k % 2 == 0 { radius } else { -radius };
                m
            })
            .collect();
        Gmm {
            dim,
            means,
            var,
        }
    }

    pub fn components(&self) -> usize {
        self.means.len()
    }

    /// Exact noise prediction. `cond = Some(k)` conditions on component `k`;
    /// `None` is the unconditional (uniform-mixture) score. Allocating
    /// convenience form of [`Self::eps_into`].
    pub fn eps(&self, x: &[f32], t: f64, cond: Option<usize>) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.eps_into(x, t, cond, &mut out, &mut GmmScratch::default());
        out
    }

    /// Exact noise prediction written into `out` (length `dim`) using the
    /// caller's [`GmmScratch`] — the allocation-free form the serving hot
    /// path runs on. Bit-identical to [`Self::eps`].
    pub fn eps_into(
        &self,
        x: &[f32],
        t: f64,
        cond: Option<usize>,
        out: &mut [f32],
        scratch: &mut GmmScratch,
    ) {
        assert_eq!(x.len(), self.dim);
        assert_eq!(out.len(), self.dim);
        let (a, s) = solver::alpha_sigma(t);
        let tau = a * a * self.var + s * s; // marginal component variance
        match cond {
            Some(k) => self.eps_single_into(x, a, s, tau, k, out),
            None => self.eps_mixture_into(x, a, s, tau, out, scratch),
        }
    }

    fn eps_single_into(&self, x: &[f32], a: f64, s: f64, tau: f64, k: usize, out: &mut [f32]) {
        let mu = &self.means[k];
        for i in 0..self.dim {
            out[i] = ((x[i] as f64 - a * mu[i] as f64) * s / tau) as f32;
        }
    }

    fn eps_mixture_into(
        &self,
        x: &[f32],
        a: f64,
        s: f64,
        tau: f64,
        out: &mut [f32],
        scratch: &mut GmmScratch,
    ) {
        // responsibilities via log-sum-exp of -|x - a mu_k|^2 / (2 tau);
        // the logits are exponentiated in place, so one scratch buffer
        // serves both roles.
        let w = &mut scratch.weights;
        w.clear();
        for mu in &self.means {
            let d2: f64 = x
                .iter()
                .zip(mu)
                .map(|(&xi, &mi)| {
                    let d = xi as f64 - a * mi as f64;
                    d * d
                })
                .sum();
            w.push(-d2 / (2.0 * tau));
        }
        let max = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for l in w.iter_mut() {
            *l = (*l - max).exp();
        }
        let z: f64 = w.iter().sum();
        out.fill(0.0);
        for (k, mu) in self.means.iter().enumerate() {
            let r = w[k] / z;
            for i in 0..self.dim {
                out[i] += (r * (x[i] as f64 - a * mu[i] as f64) * s / tau) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn toy() -> Gmm {
        Gmm::axes(8, 4, 3.0, 0.05)
    }

    #[test]
    fn single_component_eps_is_linear() {
        let g = toy();
        let x = vec![1.0f32; 8];
        let e1 = g.eps(&x, 0.5, Some(0));
        // doubling (x - a*mu) doubles eps: check via x' = a*mu + 2*(x - a*mu)
        let (a, _) = solver::alpha_sigma(0.5);
        let x2: Vec<f32> = x
            .iter()
            .zip(&g.means[0])
            .map(|(&xi, &mi)| (a as f32) * mi + 2.0 * (xi - (a as f32) * mi))
            .collect();
        let e2 = g.eps(&x2, 0.5, Some(0));
        for (v1, v2) in e1.iter().zip(&e2) {
            assert!((2.0 * v1 - v2).abs() < 1e-5);
        }
    }

    #[test]
    fn scratch_api_matches_allocating_eps_bitwise() {
        // one reused scratch across interleaved cond/uncond calls at
        // different times must reproduce the allocating path exactly
        let g = toy();
        let mut scratch = GmmScratch::default();
        let mut out = vec![0.0f32; 8];
        let mut rng = Rng::new(11);
        for i in 0..12 {
            let x = rng.normal_vec(8);
            let t = 0.05 + 0.9 * (i as f64 / 12.0);
            let cond = match i % 3 {
                0 => None,
                1 => Some(0),
                _ => Some(3),
            };
            g.eps_into(&x, t, cond, &mut out, &mut scratch);
            assert_eq!(out, g.eps(&x, t, cond), "call {i}");
        }
    }

    #[test]
    fn uncond_equals_cond_far_from_other_modes() {
        // deep inside component 0's basin the mixture score ≈ component-0 score
        let g = toy();
        let (a, _) = solver::alpha_sigma(0.05);
        let mut x = vec![0.0f32; 8];
        x[0] = (a as f32) * 3.0 + 0.01; // at component 0's scaled mean
        let ec = g.eps(&x, 0.05, Some(0));
        let eu = g.eps(&x, 0.05, None);
        for (c, u) in ec.iter().zip(&eu) {
            assert!((c - u).abs() < 1e-4, "{c} vs {u}");
        }
    }

    #[test]
    fn gamma_converges_along_denoising_trajectory() {
        // Run the actual DPM++ sampler conditioned on component 1 and check
        // the paper's Eq. 7 phenomenon: cosine(eps_c, eps_u) -> 1 as t -> 0.
        let g = toy();
        let steps = 20;
        let ts = solver::timesteps(steps);
        let mut rng = Rng::new(3);
        let mut x = rng.normal_vec(8);
        let mut x0_prev = vec![0.0f32; 8];
        let mut gammas = Vec::new();
        for i in 0..steps {
            let ec = g.eps(&x, ts[i], Some(1));
            let eu = g.eps(&x, ts[i], None);
            let tc = Tensor::new(vec![8], ec.clone());
            let tu = Tensor::new(vec![8], eu);
            gammas.push(tc.cosine(&tu));
            // guide with s = 2 then step
            let eps: Vec<f32> = tc
                .data
                .iter()
                .zip(&tu.data)
                .map(|(&c, &u)| u + 2.0 * (c - u))
                .collect();
            let t_r = if i > 0 { Some(ts[i - 1]) } else { None };
            let c = solver::fold_coefs(ts[i], ts[i + 1], t_r);
            let (xn, x0) = solver::apply_step(&x, &eps, &x0_prev, &c);
            x = xn;
            x0_prev = x0;
        }
        // late gamma must exceed early gamma and approach 1
        let early = gammas[..5].iter().sum::<f64>() / 5.0;
        let late = gammas[steps - 5..].iter().sum::<f64>() / 5.0;
        assert!(late > early, "late {late} <= early {early}");
        assert!(late > 0.999, "late gamma {late}");
    }

    #[test]
    fn sampling_transports_to_conditioned_mode() {
        // CFG sampling conditioned on component k must land near mu_k.
        let g = toy();
        let steps = 20;
        let ts = solver::timesteps(steps);
        for k in 0..g.components() {
            let mut rng = Rng::new(100 + k as u64);
            let mut x = rng.normal_vec(8);
            let mut x0_prev = vec![0.0f32; 8];
            for i in 0..steps {
                let ec = g.eps(&x, ts[i], Some(k));
                let eu = g.eps(&x, ts[i], None);
                let eps: Vec<f32> = ec
                    .iter()
                    .zip(&eu)
                    .map(|(&c, &u)| u + 2.0 * (c - u))
                    .collect();
                let t_r = if i > 0 { Some(ts[i - 1]) } else { None };
                let c = solver::fold_coefs(ts[i], ts[i + 1], t_r);
                let (xn, x0) = solver::apply_step(&x, &eps, &x0_prev, &c);
                x = xn;
                x0_prev = x0;
            }
            let dist: f64 = x0_prev
                .iter()
                .zip(&g.means[k])
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(dist < 1.5, "component {k}: landed {dist} away");
        }
    }
}
