//! Analytic simulation substrates (PJRT-free test oracles).

pub mod gmm;
