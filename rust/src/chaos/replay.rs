//! Open-loop trace replay over real TCP (`agd replay`, §Robustness).
//!
//! Replays a captured trace ([`super::trace`]) against a live server:
//! records are dealt round-robin across `--connections N` real TCP
//! connections, and each connection re-issues its records *open-loop* —
//! send time is `epoch + offset_us / speed`, never gated on the previous
//! reply — so a slow server accumulates backlog exactly like it would
//! under the original arrival process. Replies are matched FIFO per
//! connection (the line protocol answers in order on one connection).
//!
//! `--max-in-flight N` switches to **closed-loop** mode (§Observability):
//! the captured schedule is ignored and each connection instead keeps up
//! to `N` requests outstanding, sending the next as soon as a reply frees
//! a slot. Open-loop answers "what does the captured load do to this
//! server?"; closed-loop answers "how fast can this server go under
//! bounded concurrency?" — the `achieved_rps` scalar in
//! `BENCH_replay.json` is the throughput measurement.
//!
//! `--pipeline DEPTH` is closed-loop over the **pipelined wire protocol**
//! (§Scale, `docs/PROTOCOL.md`): every request is tagged with a unique
//! wire `"id"`, up to `DEPTH` ride one connection concurrently, and
//! replies are matched by their echoed id rather than FIFO — which is
//! what lets the reactor front end interleave them out of order. Streamed
//! `{"event": "progress"}` lines are skipped (they are samples, not
//! replies); wire latency is recorded per id at its *terminal* reply.
//! This is the depth-N/conn throughput measurement for the reactor;
//! against `--net threads` it degrades gracefully (the threaded loop
//! answers in order, ids still match).
//!
//! Per request the replayer records wire latency (send → reply line
//! read), the structured `code` on shed/error replies, and — when the
//! trace record carries a digest *and* the envelope asked for the image —
//! whether the served completion is byte-identical to the captured one
//! ([`super::trace::reply_digest`]). The aggregate lands in
//! `BENCH_replay.json` via [`crate::perfstat`] (wire-latency
//! p50/p95/p99 + derived scalars).
//!
//! After the run, `agd replay` scrapes the fleet's **survival counters**
//! (`{"cmd": "stats"}` → [`fetch_survival`]) into the same report: how
//! many batches were transiently retried, jobs salvaged off dying
//! shards, and shards died/respawned while the replay was being served.
//! Replayed digests matching the capture *plus* non-zero survival
//! counters is the whole robustness claim in one artifact: the fleet
//! took damage and the bytes did not change (`docs/ROBUSTNESS.md`).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::chaos::trace::{reply_digest, TraceRecord};
use crate::perfstat::Summary;
use crate::util::json::{self, Value};

/// Replay parameters (`agd replay --trace F --speed X --connections N`).
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub addr: String,
    /// Time compression: 2.0 replays at twice the captured rate.
    pub speed: f64,
    /// Concurrent TCP connections records are dealt across.
    pub connections: usize,
    /// Per-reply read timeout; a stalled reply counts as a transport
    /// error and abandons that connection's remaining records.
    pub timeout_ms: u64,
    /// Closed-loop cap on outstanding requests per connection
    /// (§Observability). `0` keeps the open-loop captured schedule; `N>0`
    /// ignores record offsets and keeps up to `N` requests in flight,
    /// sending the next the moment a reply frees a slot.
    pub max_in_flight: usize,
    /// Pipelined closed-loop depth (§Scale): `N>0` tags every request
    /// with a unique wire `"id"`, keeps up to `N` in flight per
    /// connection, and matches replies by echoed id (progress events
    /// skipped). Takes precedence over `max_in_flight`.
    pub pipeline: usize,
}

impl Default for ReplayConfig {
    fn default() -> ReplayConfig {
        ReplayConfig {
            addr: "127.0.0.1:7458".into(),
            speed: 1.0,
            connections: 4,
            timeout_ms: 30_000,
            max_in_flight: 0,
            pipeline: 0,
        }
    }
}

/// Aggregate replay result.
#[derive(Debug, Clone, Default)]
pub struct ReplayOutcome {
    /// Requests written to a socket.
    pub sent: usize,
    /// Completion replies (no `error` field).
    pub completed: usize,
    /// Structured refusals by `code` (`queue_full`, `draining`, …);
    /// error replies without a code count under `"error"`.
    pub shed: BTreeMap<String, usize>,
    /// Connect/write/read failures and timeouts (requests with no reply).
    pub transport_errors: usize,
    /// Completions that carried enough bytes to digest-check.
    pub digest_checked: usize,
    /// Digest-checked completions that diverged from the trace.
    pub digest_mismatches: usize,
    /// Wire latency (send → reply read) of every reply, ms.
    pub latencies_ms: Vec<f64>,
    /// Whole-replay wall time, ms.
    pub wall_ms: f64,
}

impl ReplayOutcome {
    fn merge(&mut self, other: ReplayOutcome) {
        self.sent += other.sent;
        self.completed += other.completed;
        for (code, n) in other.shed {
            *self.shed.entry(code).or_insert(0) += n;
        }
        self.transport_errors += other.transport_errors;
        self.digest_checked += other.digest_checked;
        self.digest_mismatches += other.digest_mismatches;
        self.latencies_ms.extend(other.latencies_ms);
    }

    pub fn shed_total(&self) -> usize {
        self.shed.values().sum()
    }
}

/// §Robustness: fleet survival counters scraped from `{"cmd": "stats"}`
/// after a replay — the adversity the fleet absorbed while serving it.
/// Each field sums one counter family across the fleet.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SurvivalCounters {
    /// `batch_retries_total` — transiently-failed batches retried.
    pub batch_retries: u64,
    /// `jobs_salvaged_total` — never-started jobs re-placed off dying
    /// shards.
    pub jobs_salvaged: u64,
    /// `jobs_resumed_total` — checkpointed mid-flight jobs resumed on
    /// survivors (`--checkpoint-steps`).
    pub jobs_resumed: u64,
    /// `shard_died_total` — lifetime shard deaths (persistent ledger;
    /// survives respawn).
    pub shards_died: u64,
    /// `shard_respawned_total` — supervisor respawns.
    pub shards_respawned: u64,
}

/// Sum one counter family out of a flat `{"name{label=v}": n}` counters
/// object. Merged fleet telemetry publishes most series twice — summed
/// (no `shard=` label) and per-shard — so fleet-total keys are preferred
/// and the `shard=`-labelled copies are only summed for series that
/// exist *exclusively* per-shard (`shard_died_total`,
/// `shard_respawned_total`).
fn sum_counter(counters: &Value, name: &str) -> u64 {
    let Some(obj) = counters.as_obj() else { return 0 };
    let (mut fleet, mut sharded) = (0.0f64, 0.0f64);
    let mut saw_fleet = false;
    for (k, v) in obj {
        let is_family = k == name
            || k.strip_prefix(name).is_some_and(|rest| rest.starts_with('{'));
        if !is_family {
            continue;
        }
        let val = v.as_f64().unwrap_or(0.0);
        if k.contains("shard=") {
            sharded += val;
        } else {
            fleet += val;
            saw_fleet = true;
        }
    }
    (if saw_fleet { fleet } else { sharded }) as u64
}

/// One `{"cmd": "stats"}` round trip against `addr`, reduced to the
/// [`SurvivalCounters`] the replay report embeds. Failure is an error —
/// the caller decides whether a missing scrape invalidates the run
/// (`agd replay` degrades to a report without the survival section).
pub fn fetch_survival(addr: &str, timeout_ms: u64) -> Result<SurvivalCounters> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("stats connect {addr}"))?;
    stream
        .set_read_timeout(Some(Duration::from_millis(timeout_ms.max(1))))
        .ok();
    let mut writer = stream.try_clone().context("stats stream clone")?;
    writer
        .write_all(b"{\"cmd\": \"stats\"}\n")
        .context("stats write")?;
    let mut line = String::new();
    BufReader::new(stream)
        .read_line(&mut line)
        .context("stats read")?;
    let v = json::parse(line.trim())
        .map_err(|e| anyhow::anyhow!("stats reply is not JSON: {e}"))?;
    let null = Value::Null;
    let counters = v
        .get("telemetry")
        .and_then(|t| t.get("counters"))
        .unwrap_or(&null);
    Ok(SurvivalCounters {
        batch_retries: sum_counter(counters, "batch_retries_total"),
        jobs_salvaged: sum_counter(counters, "jobs_salvaged_total"),
        jobs_resumed: sum_counter(counters, "jobs_resumed_total"),
        shards_died: sum_counter(counters, "shard_died_total"),
        shards_respawned: sum_counter(counters, "shard_respawned_total"),
    })
}

/// What one connection expects back for one sent request.
struct Expected {
    sent_at: Instant,
    digest: Option<String>,
    /// The wire id the request was tagged with (pipelined mode only);
    /// `None` matches FIFO.
    wire_id: Option<u64>,
}

/// Classify one terminal reply line into the outcome tallies (shared by
/// the FIFO and by-id readers).
fn tally_reply(out: &mut ReplayOutcome, v: &Value, exp: &Expected) {
    if v.get("error").is_some() {
        let code = v
            .get("code")
            .and_then(Value::as_str)
            .unwrap_or("error")
            .to_owned();
        *out.shed.entry(code).or_insert(0) += 1;
        return;
    }
    out.completed += 1;
    if let Some(expected) = &exp.digest {
        if let Some(got) = reply_digest(v) {
            out.digest_checked += 1;
            if got != *expected {
                out.digest_mismatches += 1;
            }
        }
    }
}

/// The record's request line with a replayer-assigned wire `"id"`
/// (pipelined mode). Overwrites any captured id: replay ids must be
/// unique per connection for by-id matching.
fn tagged_line(rec: &TraceRecord, id: u64) -> String {
    let mut env = rec.envelope.clone();
    if let Value::Obj(m) = &mut env {
        m.insert("id".into(), json::num(id as f64));
    }
    json::to_string(&env)
}

/// Replay `records` (already offset-sorted — [`super::trace::read_trace`]
/// guarantees it) against `cfg.addr`. Errors only on setup (no records,
/// unreachable address on every connection); per-request failures are
/// counted in the outcome instead.
pub fn replay(records: &[TraceRecord], cfg: &ReplayConfig) -> Result<ReplayOutcome> {
    anyhow::ensure!(!records.is_empty(), "trace is empty");
    anyhow::ensure!(cfg.speed > 0.0, "--speed must be > 0");
    let conns = cfg.connections.max(1);
    // deal records round-robin, preserving each connection's time order
    let mut per_conn: Vec<Vec<TraceRecord>> = vec![Vec::new(); conns];
    for (i, r) in records.iter().enumerate() {
        per_conn[i % conns].push(r.clone());
    }
    // small lead so the earliest record is not already late at epoch
    let epoch = Instant::now() + Duration::from_millis(5);
    let speed = cfg.speed;
    let timeout = Duration::from_millis(cfg.timeout_ms.max(1));
    let max_in_flight = cfg.max_in_flight;
    let pipeline = cfg.pipeline;
    let addr = cfg.addr.clone();
    let t0 = Instant::now();
    let handles: Vec<_> = per_conn
        .into_iter()
        .filter(|batch| !batch.is_empty())
        .map(|batch| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                run_connection(&addr, batch, epoch, speed, timeout, max_in_flight, pipeline)
            })
        })
        .collect();
    let mut outcome = ReplayOutcome::default();
    let mut connect_err = None;
    for h in handles {
        match h.join().expect("replay connection thread") {
            Ok(part) => outcome.merge(part),
            Err(e) => connect_err = Some(e),
        }
    }
    if outcome.sent == 0 {
        // every connection failed before sending anything — that is a
        // setup error (bad --addr), not a chaos observation
        return Err(
            connect_err.unwrap_or_else(|| anyhow::anyhow!("replay sent nothing"))
        );
    }
    outcome.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok(outcome)
}

/// Closed-loop slot bookkeeping shared between a connection's writer and
/// reader: outstanding-request count + a flag the reader raises when the
/// connection dies so the writer stops waiting.
type Slots = std::sync::Arc<(std::sync::Mutex<usize>, std::sync::Condvar)>;
type DeadFlag = std::sync::Arc<std::sync::atomic::AtomicBool>;

/// The historical reader: replies matched FIFO to what was sent (the
/// line protocol answers in order on one connection).
fn read_replies_fifo(
    stream: TcpStream,
    rx: std::sync::mpsc::Receiver<Expected>,
    slots: &Slots,
    dead: &DeadFlag,
    cap: usize,
) -> ReplayOutcome {
    let mut out = ReplayOutcome::default();
    let mut lines = BufReader::new(stream);
    for exp in rx.iter() {
        let mut line = String::new();
        match lines.read_line(&mut line) {
            Ok(n) if n > 0 => {}
            // EOF/timeout: this reply — and every reply behind it on
            // this connection — is gone
            _ => {
                out.transport_errors += 1;
                out.transport_errors += rx.try_iter().count();
                dead.store(true, std::sync::atomic::Ordering::SeqCst);
                slots.1.notify_all();
                return out;
            }
        }
        if cap > 0 {
            *slots.0.lock().unwrap() -= 1;
            slots.1.notify_one();
        }
        out.latencies_ms
            .push(exp.sent_at.elapsed().as_secs_f64() * 1e3);
        let Ok(v) = json::parse(line.trim()) else {
            out.transport_errors += 1;
            continue;
        };
        tally_reply(&mut out, &v, &exp);
    }
    out
}

/// The pipelined reader (§Scale): replies matched by echoed wire `"id"`,
/// in whatever order the reactor interleaves them; streamed
/// `{"event": "progress"}` lines are skipped. The writer registers each
/// [`Expected`] *before* writing its request, so a reply can never beat
/// its bookkeeping here.
fn read_replies_by_id(
    stream: TcpStream,
    rx: std::sync::mpsc::Receiver<Expected>,
    slots: &Slots,
    dead: &DeadFlag,
) -> ReplayOutcome {
    use std::sync::mpsc::TryRecvError;
    let mut out = ReplayOutcome::default();
    let mut lines = BufReader::new(stream);
    let mut pending: std::collections::HashMap<u64, Expected> = std::collections::HashMap::new();
    let mut closed = false;
    loop {
        loop {
            match rx.try_recv() {
                Ok(exp) => {
                    let id = exp.wire_id.expect("pipelined Expected carries an id");
                    pending.insert(id, exp);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        if closed && pending.is_empty() {
            return out;
        }
        let mut line = String::new();
        match lines.read_line(&mut line) {
            Ok(n) if n > 0 => {}
            // EOF/timeout: every reply still owed on this connection is
            // gone (the channel may still hold Expecteds the loop above
            // has not drained yet)
            _ => {
                out.transport_errors += pending.len() + rx.try_iter().count();
                dead.store(true, std::sync::atomic::Ordering::SeqCst);
                slots.1.notify_all();
                return out;
            }
        }
        let Ok(v) = json::parse(line.trim()) else {
            out.transport_errors += 1;
            continue;
        };
        // progress events are samples, not replies: they do not free a
        // slot and carry no latency observation
        if v.get("event").and_then(Value::as_str) == Some("progress") {
            continue;
        }
        let Some(exp) = v
            .get("id")
            .and_then(Value::as_f64)
            .and_then(|id| pending.remove(&(id as u64)))
        else {
            // a reply the replayer cannot attribute (no id, unknown id)
            out.transport_errors += 1;
            continue;
        };
        *slots.0.lock().unwrap() -= 1;
        slots.1.notify_one();
        out.latencies_ms
            .push(exp.sent_at.elapsed().as_secs_f64() * 1e3);
        tally_reply(&mut out, &v, &exp);
    }
}

/// One connection: a writer (this thread — pacing the captured schedule
/// open-loop, or gating on free slots closed-loop/pipelined) and a
/// reader thread matching replies FIFO or by wire id.
fn run_connection(
    addr: &str,
    batch: Vec<TraceRecord>,
    epoch: Instant,
    speed: f64,
    timeout: Duration,
    max_in_flight: usize,
    pipeline: usize,
) -> Result<ReplayOutcome> {
    // pipelined mode is closed-loop at the pipeline depth
    let cap = if pipeline > 0 { pipeline } else { max_in_flight };
    let stream =
        TcpStream::connect(addr).with_context(|| format!("replay connect {addr}"))?;
    stream.set_read_timeout(Some(timeout)).ok();
    let reader_stream = stream.try_clone().context("replay stream clone")?;
    let (tx, rx) = channel::<Expected>();
    let slots: Slots =
        std::sync::Arc::new((std::sync::Mutex::new(0usize), std::sync::Condvar::new()));
    let conn_dead: DeadFlag =
        std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let (r_slots, r_dead) = (slots.clone(), conn_dead.clone());
    let reader = std::thread::spawn(move || {
        if pipeline > 0 {
            read_replies_by_id(reader_stream, rx, &r_slots, &r_dead)
        } else {
            read_replies_fifo(reader_stream, rx, &r_slots, &r_dead, cap)
        }
    });
    let mut writer = stream;
    let mut sent = 0usize;
    let mut write_errors = 0usize;
    for (i, rec) in batch.iter().enumerate() {
        if cap > 0 {
            // closed-loop: ignore the captured schedule, wait for a slot
            let (lock, cv) = &*slots;
            let mut in_flight = lock.lock().unwrap();
            while *in_flight >= cap && !conn_dead.load(std::sync::atomic::Ordering::SeqCst)
            {
                let (guard, _) = cv
                    .wait_timeout(in_flight, Duration::from_millis(100))
                    .unwrap();
                in_flight = guard;
            }
            if conn_dead.load(std::sync::atomic::Ordering::SeqCst) {
                // reader already counted the in-flight tail; the rest of
                // the batch was never sent
                write_errors = batch.len() - sent;
                break;
            }
            *in_flight += 1;
        } else {
            // open-loop: send at the captured (speed-compressed) offset
            let due =
                epoch + Duration::from_micros((rec.offset_us as f64 / speed) as u64);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        let digest = rec
            .digest
            .clone()
            .filter(|_| rec.wants_image());
        let (line, wire_id) = if pipeline > 0 {
            (tagged_line(rec, i as u64), Some(i as u64))
        } else {
            (rec.request_line(), None)
        };
        let sent_at = Instant::now();
        if pipeline > 0 {
            // register the expectation before the bytes leave: a fast
            // reply must find its id already in the reader's table
            let _ = tx.send(Expected {
                sent_at,
                digest,
                wire_id,
            });
            if writer
                .write_all(line.as_bytes())
                .and_then(|()| writer.write_all(b"\n"))
                .is_err()
            {
                // connection is gone; everything left on it is unserved
                // (the orphaned expectation resolves at the reader's EOF)
                write_errors = batch.len() - sent;
                break;
            }
            sent += 1;
        } else {
            if writer
                .write_all(line.as_bytes())
                .and_then(|()| writer.write_all(b"\n"))
                .is_err()
            {
                write_errors = batch.len() - sent;
                break;
            }
            sent += 1;
            let _ = tx.send(Expected {
                sent_at,
                digest,
                wire_id,
            });
        }
    }
    drop(tx); // reader drains and returns
    let _ = writer.shutdown(std::net::Shutdown::Write);
    let mut out = reader.join().expect("replay reader thread");
    out.sent = sent;
    out.transport_errors += write_errors;
    Ok(out)
}

/// Bundle the outcome into the `BENCH_replay.json` shape: the
/// wire-latency [`Summary`] row plus derived scalars. When a post-run
/// stats scrape succeeded, its [`SurvivalCounters`] ride along as
/// `survived_*` scalars — zero survival counters with clean digests
/// means an undisturbed run; non-zero counters with clean digests means
/// the fleet absorbed faults without changing a byte.
pub fn report_json(
    outcome: &ReplayOutcome,
    cfg: &ReplayConfig,
    survival: Option<&SurvivalCounters>,
) -> Value {
    let lat = Summary::from_samples_ms("replay_wire_latency", &outcome.latencies_ms);
    let wall_s = outcome.wall_ms / 1e3;
    let mut derived: Vec<(String, f64)> = vec![
        ("sent".into(), outcome.sent as f64),
        ("completed".into(), outcome.completed as f64),
        ("shed_total".into(), outcome.shed_total() as f64),
        ("transport_errors".into(), outcome.transport_errors as f64),
        ("digest_checked".into(), outcome.digest_checked as f64),
        (
            "digest_mismatches".into(),
            outcome.digest_mismatches as f64,
        ),
        ("wall_ms".into(), outcome.wall_ms),
        (
            "achieved_rps".into(),
            if wall_s > 0.0 {
                outcome.completed as f64 / wall_s
            } else {
                0.0
            },
        ),
        ("speed".into(), cfg.speed),
        ("connections".into(), cfg.connections as f64),
        // 0 = open-loop (captured schedule); N = closed-loop at N
        // in-flight per connection, where achieved_rps is the measured
        // bounded-concurrency throughput
        ("max_in_flight".into(), cfg.max_in_flight as f64),
        // 0 = one request on the wire at a time; N = pipelined with N
        // wire ids in flight per connection
        ("pipeline".into(), cfg.pipeline as f64),
    ];
    for (code, n) in &outcome.shed {
        derived.push((format!("shed_{code}"), *n as f64));
    }
    if let Some(s) = survival {
        derived.push(("survived_batch_retries".into(), s.batch_retries as f64));
        derived.push(("survived_jobs_salvaged".into(), s.jobs_salvaged as f64));
        derived.push(("survived_jobs_resumed".into(), s.jobs_resumed as f64));
        derived.push(("survived_shard_deaths".into(), s.shards_died as f64));
        derived.push((
            "survived_shard_respawns".into(),
            s.shards_respawned as f64,
        ));
    }
    let borrowed: Vec<(&str, f64)> =
        derived.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    crate::perfstat::summaries_to_json(&[lat], &borrowed)
}

/// Write [`report_json`] to `path` (the `BENCH_replay.json` artifact).
pub fn write_report(
    path: &str,
    outcome: &ReplayOutcome,
    cfg: &ReplayConfig,
    survival: Option<&SurvivalCounters>,
) -> Result<()> {
    let text = json::to_string(&report_json(outcome, cfg, survival));
    std::fs::write(path, text).with_context(|| format!("writing replay report {path}"))?;
    eprintln!("replay report written to {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::TcpListener;

    /// A line server that completes every request as a fixed tiny
    /// completion (echoing an image when asked) — enough to exercise the
    /// replay plumbing without a fleet.
    fn spawn_stub_server(shed_every: usize) -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                std::thread::spawn(move || {
                    let mut writer = stream.try_clone().unwrap();
                    let reader = BufReader::new(stream);
                    for (i, line) in reader.lines().map_while(Result::ok).enumerate() {
                        let v = json::parse(&line).unwrap();
                        let reply = if shed_every > 0 && (i + 1) % shed_every == 0 {
                            r#"{"error": "queue full: stub", "code": "queue_full"}"#
                                .to_owned()
                        } else if v.get("image").and_then(Value::as_bool) == Some(true) {
                            r#"{"id": 0, "nfes": 4, "cfg_steps": 2, "truncated_at": null, "image": [0.5, -0.25]}"#.to_owned()
                        } else {
                            r#"{"id": 0, "nfes": 4, "cfg_steps": 2, "truncated_at": null}"#
                                .to_owned()
                        };
                        if writeln!(writer, "{reply}").is_err() {
                            break;
                        }
                    }
                });
            }
        });
        addr
    }

    fn record(offset_us: u64, image: bool, digest: Option<&str>) -> TraceRecord {
        let envelope = json::parse(&format!(
            r#"{{"prompt": "red circle", "steps": 4, "image": {image}}}"#
        ))
        .unwrap();
        TraceRecord {
            offset_us,
            client_id: None,
            digest: digest.map(str::to_owned),
            envelope,
        }
    }

    /// The digest the stub server's fixed image reply hashes to.
    fn stub_digest() -> String {
        crate::chaos::trace::digest_parts(&[0.5, -0.25], 4, 2, None)
    }

    #[test]
    fn replays_a_trace_and_checks_digests() {
        let addr = spawn_stub_server(0);
        let good = stub_digest();
        let records = vec![
            record(0, true, Some(&good)),
            record(100, true, Some("deadbeefdeadbeef")), // mismatch
            record(200, false, Some(&good)),             // no image → unverifiable
            record(300, true, None),                     // no digest → unverifiable
        ];
        let cfg = ReplayConfig {
            addr: addr.to_string(),
            speed: 100.0,
            connections: 2,
            timeout_ms: 5_000,
            max_in_flight: 0,
            pipeline: 0,
        };
        let out = replay(&records, &cfg).unwrap();
        assert_eq!(out.sent, 4);
        assert_eq!(out.completed, 4);
        assert_eq!(out.transport_errors, 0);
        assert_eq!(out.digest_checked, 2);
        assert_eq!(out.digest_mismatches, 1);
        assert_eq!(out.latencies_ms.len(), 4);
        assert!(out.wall_ms > 0.0);
    }

    /// Closed-loop mode ignores the captured offsets: records scheduled
    /// far in the future still replay immediately, gated only by the
    /// in-flight cap, and the report carries the cap + achieved rate.
    #[test]
    fn closed_loop_ignores_offsets_and_caps_in_flight() {
        let addr = spawn_stub_server(0);
        // offsets an hour apart — open-loop at speed 1 would take hours
        let records: Vec<TraceRecord> = (0..8)
            .map(|i| record(i * 3_600_000_000, false, None))
            .collect();
        let cfg = ReplayConfig {
            addr: addr.to_string(),
            speed: 1.0,
            connections: 2,
            timeout_ms: 5_000,
            max_in_flight: 2,
            pipeline: 0,
        };
        let t0 = Instant::now();
        let out = replay(&records, &cfg).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "closed-loop must not honour the captured schedule"
        );
        assert_eq!(out.sent, 8);
        assert_eq!(out.completed, 8);
        assert_eq!(out.transport_errors, 0);
        assert_eq!(out.latencies_ms.len(), 8);
        let v = report_json(&out, &cfg, None);
        let d = v.req("derived");
        assert_eq!(d.req("max_in_flight").as_f64(), Some(2.0));
        assert!(d.req("achieved_rps").as_f64().unwrap() > 0.0);
    }

    /// A pipelined stub: reads every request first (the client must not
    /// be gated on replies), then answers **in reverse order**, echoing
    /// each request's wire id and interleaving progress events that the
    /// replayer must skip. Only an id-matching reader can pass this.
    fn spawn_pipelined_stub(expect: usize) -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                std::thread::spawn(move || {
                    let mut writer = stream.try_clone().unwrap();
                    let mut lines = BufReader::new(stream).lines().map_while(Result::ok);
                    let mut ids = Vec::new();
                    while ids.len() < expect {
                        let Some(line) = lines.next() else { break };
                        let v = json::parse(&line).unwrap();
                        ids.push(v.req("id").as_f64().unwrap() as u64);
                    }
                    for id in ids.iter().rev() {
                        let _ = writeln!(
                            writer,
                            r#"{{"event": "progress", "id": {id}, "step": 1, "of": 4, "gamma": 0.5, "nfes": 2}}"#
                        );
                        let _ = writeln!(
                            writer,
                            r#"{{"id": {id}, "nfes": 4, "cfg_steps": 2, "truncated_at": null, "image": [0.5, -0.25]}}"#
                        );
                    }
                });
            }
        });
        addr
    }

    /// `--pipeline DEPTH` keeps DEPTH wire ids in flight, matches
    /// replies by echoed id (here: fully reversed), skips progress
    /// frames, and still verifies digests per request.
    #[test]
    fn pipelined_mode_matches_replies_by_wire_id() {
        let n = 6;
        let addr = spawn_pipelined_stub(n);
        let good = stub_digest();
        let records: Vec<TraceRecord> =
            (0..n).map(|i| record(i as u64 * 100, true, Some(&good))).collect();
        let cfg = ReplayConfig {
            addr: addr.to_string(),
            speed: 1.0,
            connections: 1,
            timeout_ms: 5_000,
            max_in_flight: 0,
            pipeline: n, // the stub answers nothing until all N arrive
        };
        let out = replay(&records, &cfg).unwrap();
        assert_eq!(out.sent, n);
        assert_eq!(out.completed, n);
        assert_eq!(out.transport_errors, 0);
        assert_eq!(out.digest_checked, n);
        assert_eq!(out.digest_mismatches, 0);
        assert_eq!(out.latencies_ms.len(), n);
        let d = report_json(&out, &cfg, None);
        assert_eq!(d.req("derived").req("pipeline").as_f64(), Some(n as f64));
    }

    #[test]
    fn shed_replies_are_tallied_by_code() {
        let addr = spawn_stub_server(2); // every 2nd request per conn shed
        let records: Vec<TraceRecord> =
            (0..6).map(|i| record(i * 50, false, None)).collect();
        let cfg = ReplayConfig {
            addr: addr.to_string(),
            speed: 50.0,
            connections: 1,
            timeout_ms: 5_000,
            max_in_flight: 0,
            pipeline: 0,
        };
        let out = replay(&records, &cfg).unwrap();
        assert_eq!(out.sent, 6);
        assert_eq!(out.completed, 3);
        assert_eq!(out.shed.get("queue_full"), Some(&3));
        assert_eq!(out.shed_total(), 3);
    }

    #[test]
    fn unreachable_address_is_a_setup_error() {
        // a bound-then-dropped listener leaves a port nothing accepts on
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let cfg = ReplayConfig {
            addr: dead.to_string(),
            ..ReplayConfig::default()
        };
        assert!(replay(&[record(0, false, None)], &cfg).is_err());
        assert!(replay(&[], &ReplayConfig::default()).is_err());
    }

    #[test]
    fn report_json_carries_latency_row_and_derived_scalars() {
        let mut out = ReplayOutcome {
            sent: 10,
            completed: 8,
            transport_errors: 0,
            digest_checked: 8,
            digest_mismatches: 0,
            latencies_ms: (1..=10).map(|i| i as f64).collect(),
            wall_ms: 2000.0,
            ..ReplayOutcome::default()
        };
        out.shed.insert("queue_full".into(), 2);
        let cfg = ReplayConfig::default();
        let v = report_json(&out, &cfg, None);
        let rows = v.req("benchmarks").as_arr().unwrap();
        assert_eq!(rows[0].req("name").as_str(), Some("replay_wire_latency"));
        assert_eq!(rows[0].req("iters").as_usize(), Some(10));
        assert!(rows[0].req("p99_ms").as_f64().unwrap() >= rows[0].req("p50_ms").as_f64().unwrap());
        let d = v.req("derived");
        assert_eq!(d.req("completed").as_f64(), Some(8.0));
        assert_eq!(d.req("shed_queue_full").as_f64(), Some(2.0));
        assert_eq!(d.req("achieved_rps").as_f64(), Some(4.0));
        // without a scrape the survival section is absent, not zeroed —
        // "unknown" and "undisturbed" must stay distinguishable
        assert!(d.get("survived_batch_retries").is_none());
        let s = SurvivalCounters {
            batch_retries: 3,
            jobs_salvaged: 2,
            jobs_resumed: 4,
            shards_died: 1,
            shards_respawned: 1,
        };
        let d2 = report_json(&out, &cfg, Some(&s));
        let d2 = d2.req("derived");
        assert_eq!(d2.req("survived_batch_retries").as_f64(), Some(3.0));
        assert_eq!(d2.req("survived_jobs_salvaged").as_f64(), Some(2.0));
        assert_eq!(d2.req("survived_jobs_resumed").as_f64(), Some(4.0));
        assert_eq!(d2.req("survived_shard_deaths").as_f64(), Some(1.0));
        assert_eq!(d2.req("survived_shard_respawns").as_f64(), Some(1.0));
    }

    /// Survival counters sum the fleet-total keys and fall back to the
    /// `shard=`-labelled copies only for series that exist per-shard
    /// exclusively — no double counting either way.
    #[test]
    fn survival_counter_sums_prefer_fleet_totals() {
        let counters = json::parse(
            r#"{"batch_retries_total{class=transient}": 4,
                "batch_retries_total{class=transient,shard=0}": 3,
                "batch_retries_total{class=transient,shard=1}": 1,
                "shard_died_total{shard=0}": 2,
                "shard_died_total{shard=1}": 1,
                "shard_respawned_total{shard=0}": 2,
                "jobs_salvaged_totally_unrelated": 99}"#,
        )
        .unwrap();
        assert_eq!(sum_counter(&counters, "batch_retries_total"), 4);
        assert_eq!(sum_counter(&counters, "shard_died_total"), 3);
        assert_eq!(sum_counter(&counters, "shard_respawned_total"), 2);
        // name matching is exact-family: `jobs_salvaged_total` must not
        // swallow `jobs_salvaged_totally_unrelated`
        assert_eq!(sum_counter(&counters, "jobs_salvaged_total"), 0);
        assert_eq!(sum_counter(&Value::Null, "anything"), 0);
    }

    /// [`fetch_survival`] against a stub stats endpoint: one round trip,
    /// counters reduced per family.
    #[test]
    fn fetch_survival_scrapes_a_stats_reply() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line).unwrap();
            assert!(line.contains("stats"), "{line}");
            writeln!(
                writer,
                r#"{{"shards": 2, "telemetry": {{"counters": {{
                    "batch_retries_total{{class=transient}}": 5,
                    "jobs_salvaged_total{{shard=0}}": 2,
                    "shard_died_total{{shard=0}}": 1,
                    "shard_respawned_total{{shard=0}}": 1}}}}}}"#
            )
            .unwrap();
        });
        let s = fetch_survival(&addr.to_string(), 5_000).unwrap();
        assert_eq!(
            s,
            SurvivalCounters {
                batch_retries: 5,
                jobs_salvaged: 2,
                jobs_resumed: 0,
                shards_died: 1,
                shards_respawned: 1,
            }
        );
        // an unreachable endpoint is an error the caller can degrade on
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        assert!(fetch_survival(&dead.to_string(), 200).is_err());
    }
}
