//! §Robustness: scheduled fault injection for any [`Backend`].
//!
//! [`FaultyBackend`] wraps a real backend and injects failures into the
//! two batch-execution entry points (`denoise_into`/`denoise_into_par`)
//! on a deterministic schedule — the missing half of the chaos harness:
//! PR 6 could only kill shards from the *outside* (`kill-shard`); this
//! makes the compute substrate itself misbehave, which is what transient
//! device resets, OOM retries and wedged kernels look like in production.
//!
//! The schedule is a [`FaultPlan`]: a lock-free, re-armable set of
//! trigger points over the wrapper's own batch counter (1-based — the
//! first batch a backend executes is batch 1). Plans are parsed from the
//! spec grammar ([`FaultSpec::parse`]) used by `agd serve --fault-spec`
//! and the chaos director's `fault` op:
//!
//! ```text
//!   error-every=N      every Nth batch fails (transient)
//!   error-at=K         batch K fails (transient)
//!   stall-at=K:M       batch K sleeps M ms, then executes normally
//!   fail-after=K       every batch past K fails (fatal, permanent)
//! ```
//!
//! Clauses combine with commas (`error-every=3,stall-at=5:200`). Checks
//! run in severity order: fail-after (fatal) → stall → error-at →
//! error-every. Because plans live behind an `Arc` and every field is
//! atomic, the director can re-arm or clear a plan *while shards are
//! executing* without a lock — and the per-shard batch counter lives on
//! the wrapper (not the plan), so each shard sees the same deterministic
//! schedule regardless of how the fleet interleaves.
//!
//! Injected failures are typed ([`BackendFault`], carrying a
//! [`FaultClass`]): the engine's bounded-retry loop (`--max-batch-retries`)
//! classifies errors via [`classify`] and retries only transients —
//! anything it cannot downcast stays fatal, preserving the historical
//! die-on-first-error behaviour for real backend bugs. Retry pacing is a
//! seeded decorrelated-jitter backoff ([`JitterBackoff`]) so retry storms
//! desynchronize across shards while staying reproducible in tests.
//!
//! §Perf: the unarmed (all-zero) plan is the production configuration —
//! `serve` always wraps the backend so the director can arm faults later.
//! The pass-through check is five relaxed atomic loads and no allocation,
//! pinned by `rust/tests/fault_zero_alloc.rs`.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::backend::{Backend, BatchBuf, BatchOut};
use crate::exec::{ExecPool, RunStats};
use crate::util::rng::Rng;

/// Severity of an injected (or classified) backend failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// Worth retrying: the batch may succeed on a later attempt
    /// (device reset, allocator pressure, a wedged-then-recovered lane).
    Transient,
    /// Permanent: retrying cannot help; the shard's death path runs.
    Fatal,
}

impl FaultClass {
    /// Telemetry label value (`batch_retries_total{class=}`).
    pub fn name(self) -> &'static str {
        match self {
            FaultClass::Transient => "transient",
            FaultClass::Fatal => "fatal",
        }
    }
}

/// A typed injected backend failure. Carried inside `anyhow::Error` so it
/// crosses the existing `Result` plumbing unchanged; the engine recovers
/// the class with [`classify`].
#[derive(Debug, Clone)]
pub struct BackendFault {
    pub class: FaultClass,
    /// Which trigger fired: `error-every` | `error-at` | `fail-after`.
    pub kind: &'static str,
    /// 1-based batch number (on the injecting wrapper) that tripped.
    pub batch: u64,
}

impl fmt::Display for BackendFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected {} backend fault ({} at batch {})",
            self.class.name(),
            self.kind,
            self.batch
        )
    }
}

impl std::error::Error for BackendFault {}

/// Recover the failure class from any backend error. Unknown errors are
/// [`FaultClass::Fatal`] — a real backend bug must keep running the
/// historical death path, never spin in a retry loop.
pub fn classify(e: &anyhow::Error) -> FaultClass {
    e.downcast_ref::<BackendFault>()
        .map(|f| f.class)
        .unwrap_or(FaultClass::Fatal)
}

/// A parsed fault schedule (see the grammar in the module docs). `0`
/// disables a trigger — batch numbers are 1-based precisely so the
/// all-zero default means "no faults".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Every Nth batch errors (transient); 0 = off.
    pub error_every: u64,
    /// Batch K errors (transient); 0 = off.
    pub error_at: u64,
    /// Batch K stalls before executing; 0 = off.
    pub stall_at: u64,
    /// Stall duration in milliseconds.
    pub stall_ms: u64,
    /// Every batch past K errors (fatal); 0 = off.
    pub fail_after: u64,
}

impl FaultSpec {
    /// Parse the comma-joined clause grammar. Errors name the bad clause
    /// and the valid forms — a typo in `--fault-spec` or a scenario file
    /// must fail the run loudly, not silently inject nothing.
    pub fn parse(text: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for clause in text.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let Some((key, val)) = clause.split_once('=') else {
                return Err(format!(
                    "fault clause `{clause}` is not key=value (valid: \
                     error-every=N, error-at=K, stall-at=K:M, fail-after=K)"
                ));
            };
            let num = |v: &str| {
                v.parse::<u64>()
                    .map_err(|_| format!("fault clause `{key}`: `{v}` is not a number"))
            };
            match key {
                "error-every" => spec.error_every = num(val)?,
                "error-at" => spec.error_at = num(val)?,
                "fail-after" => spec.fail_after = num(val)?,
                "stall-at" => {
                    let Some((k, ms)) = val.split_once(':') else {
                        return Err(format!(
                            "fault clause `stall-at` wants BATCH:MS, got `{val}`"
                        ));
                    };
                    spec.stall_at = num(k)?;
                    spec.stall_ms = num(ms)?;
                }
                other => {
                    return Err(format!(
                        "unknown fault clause `{other}` (valid: error-every=N, \
                         error-at=K, stall-at=K:M, fail-after=K)"
                    ));
                }
            }
        }
        Ok(spec)
    }

    /// No trigger armed (the pass-through production configuration).
    pub fn is_clear(&self) -> bool {
        *self == FaultSpec::default()
    }
}

/// The live, shared fault schedule: a [`FaultSpec`] as atomics (re-armable
/// mid-run by the chaos director) plus per-kind injection counters. One
/// plan is shared by every shard's wrapper via `Arc`; the batch counters
/// driving the schedule are per-wrapper (see module docs).
#[derive(Debug, Default)]
pub struct FaultPlan {
    error_every: AtomicU64,
    error_at: AtomicU64,
    stall_at: AtomicU64,
    stall_ms: AtomicU64,
    fail_after: AtomicU64,
    injected_errors: AtomicU64,
    injected_stalls: AtomicU64,
    injected_fatals: AtomicU64,
}

impl FaultPlan {
    /// Install `spec`, replacing whatever was armed. Counters are kept —
    /// they are a monotonic injection ledger, not part of the schedule.
    pub fn arm(&self, spec: FaultSpec) {
        self.error_every.store(spec.error_every, Ordering::Relaxed);
        self.error_at.store(spec.error_at, Ordering::Relaxed);
        self.stall_at.store(spec.stall_at, Ordering::Relaxed);
        self.stall_ms.store(spec.stall_ms, Ordering::Relaxed);
        self.fail_after.store(spec.fail_after, Ordering::Relaxed);
    }

    /// Disarm every trigger (the director's `fault clear`).
    pub fn clear(&self) {
        self.arm(FaultSpec::default());
    }

    /// Is any trigger armed?
    pub fn armed(&self) -> bool {
        self.error_every.load(Ordering::Relaxed) != 0
            || self.error_at.load(Ordering::Relaxed) != 0
            || self.stall_at.load(Ordering::Relaxed) != 0
            || self.fail_after.load(Ordering::Relaxed) != 0
    }

    /// Transient errors injected so far (all wrappers sharing this plan).
    pub fn errors(&self) -> u64 {
        self.injected_errors.load(Ordering::Relaxed)
    }

    /// Stalls injected so far.
    pub fn stalls(&self) -> u64 {
        self.injected_stalls.load(Ordering::Relaxed)
    }

    /// Fatal errors injected so far.
    pub fn fatals(&self) -> u64 {
        self.injected_fatals.load(Ordering::Relaxed)
    }
}

/// A [`Backend`] wrapper injecting its [`FaultPlan`]'s schedule into the
/// batch-execution path. Every other trait method delegates untouched, so
/// wrapping changes *when* batches fail, never what they compute.
pub struct FaultyBackend<B: Backend> {
    inner: B,
    plan: Arc<FaultPlan>,
    /// Batches this wrapper has been asked to execute (1-based in checks).
    batches: u64,
}

impl<B: Backend> FaultyBackend<B> {
    pub fn new(inner: B, plan: Arc<FaultPlan>) -> FaultyBackend<B> {
        FaultyBackend {
            inner,
            plan,
            batches: 0,
        }
    }

    /// The wrapped backend (tests reach its counters through here).
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Batches seen by this wrapper (injected failures included).
    pub fn batches_seen(&self) -> u64 {
        self.batches
    }

    /// Run the schedule for the next batch: count it, then fire whichever
    /// trigger matches (severity order — fatal, stall, transient). The
    /// unarmed path is branch-predictable atomic loads, nothing else.
    fn check(&mut self) -> Result<()> {
        self.batches += 1;
        let n = self.batches;
        let fail_after = self.plan.fail_after.load(Ordering::Relaxed);
        if fail_after != 0 && n > fail_after {
            self.plan.injected_fatals.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(BackendFault {
                class: FaultClass::Fatal,
                kind: "fail-after",
                batch: n,
            }));
        }
        let stall_at = self.plan.stall_at.load(Ordering::Relaxed);
        if stall_at != 0 && n == stall_at {
            let ms = self.plan.stall_ms.load(Ordering::Relaxed);
            self.plan.injected_stalls.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(ms));
        }
        let error_at = self.plan.error_at.load(Ordering::Relaxed);
        if error_at != 0 && n == error_at {
            self.plan.injected_errors.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(BackendFault {
                class: FaultClass::Transient,
                kind: "error-at",
                batch: n,
            }));
        }
        let every = self.plan.error_every.load(Ordering::Relaxed);
        if every != 0 && n % every == 0 {
            self.plan.injected_errors.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(BackendFault {
                class: FaultClass::Transient,
                kind: "error-every",
                batch: n,
            }));
        }
        Ok(())
    }
}

impl<B: Backend> Backend for FaultyBackend<B> {
    fn flat_in(&self, model: &str) -> usize {
        self.inner.flat_in(model)
    }

    fn flat_out(&self, model: &str) -> usize {
        self.inner.flat_out(model)
    }

    fn buckets(&self) -> &[usize] {
        self.inner.buckets()
    }

    fn max_batch(&self, model: &str) -> usize {
        self.inner.max_batch(model)
    }

    fn validate_tokens(&self, model: &str, tokens: &[i32]) -> Result<(), &'static str> {
        self.inner.validate_tokens(model, tokens)
    }

    fn denoise_into(&mut self, model: &str, batch: &BatchBuf, out: &mut BatchOut) -> Result<()> {
        self.check()?;
        self.inner.denoise_into(model, batch, out)
    }

    fn denoise_into_par(
        &mut self,
        model: &str,
        batch: &BatchBuf,
        out: &mut BatchOut,
        exec: &ExecPool,
    ) -> Result<Option<RunStats>> {
        self.check()?;
        self.inner.denoise_into_par(model, batch, out, exec)
    }

    fn models(&self) -> Vec<String> {
        self.inner.models()
    }
}

/// Decorrelated-jitter retry backoff (the AWS-architecture-blog variant):
/// each delay is uniform in `[base, 3 * previous]`, capped — successive
/// retries spread apart *and* desynchronize across independent retriers,
/// which is what stops a transient-fault storm from re-aligning every
/// shard's retry attempt into the same instant. Seeded via the crate's
/// own [`Rng`] so schedules are identical across runs (the determinism
/// pin in the fault unit suite); the fleet seeds each shard's engine with
/// its shard index so shards still decorrelate from *each other*.
#[derive(Debug)]
pub struct JitterBackoff {
    base_ms: u64,
    cap_ms: u64,
    prev_ms: u64,
    rng: Rng,
}

impl JitterBackoff {
    pub fn new(base_ms: u64, cap_ms: u64, seed: u64) -> JitterBackoff {
        JitterBackoff {
            base_ms,
            cap_ms,
            prev_ms: base_ms,
            rng: Rng::new(seed),
        }
    }

    /// Next delay in milliseconds, advancing the sequence.
    pub fn next_ms(&mut self) -> u64 {
        let hi = self.prev_ms.saturating_mul(3).max(self.base_ms + 1);
        let span = (hi - self.base_ms).min(usize::MAX as u64) as usize;
        let ms = (self.base_ms + self.rng.below(span.max(1)) as u64).min(self.cap_ms);
        self.prev_ms = ms.max(self.base_ms);
        ms
    }

    /// Back to the base delay (after a successful attempt). The RNG
    /// stream deliberately keeps advancing — determinism is a property of
    /// the whole run, not of each outage.
    pub fn reset(&mut self) {
        self.prev_ms = self.base_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::sim::gmm::Gmm;

    fn gmm() -> GmmBackend {
        GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05))
    }

    fn run_batch<B: Backend>(be: &mut B) -> Result<()> {
        let mut batch = BatchBuf::new(8, 4);
        let (x, toks) = batch.push_row(0.5);
        x.fill(0.1);
        toks[0] = 1;
        let mut out = BatchOut::default();
        be.denoise_into("gmm", &batch, &mut out)
    }

    #[test]
    fn spec_grammar_round_trips() {
        let spec = FaultSpec::parse("error-every=3,error-at=7,stall-at=5:200,fail-after=40")
            .expect("full grammar");
        assert_eq!(
            spec,
            FaultSpec {
                error_every: 3,
                error_at: 7,
                stall_at: 5,
                stall_ms: 200,
                fail_after: 40,
            }
        );
        // whitespace and empty clauses are tolerated; empty spec = clear
        assert!(FaultSpec::parse("").unwrap().is_clear());
        assert_eq!(FaultSpec::parse(" error-at=2 , ").unwrap().error_at, 2);
    }

    #[test]
    fn spec_grammar_rejects_garbage_loudly() {
        for bad in ["boom", "error-every", "error-at=x", "stall-at=5", "warp=1"] {
            let err = FaultSpec::parse(bad).unwrap_err();
            assert!(err.contains("fault clause") || err.contains("unknown"), "{bad}: {err}");
        }
    }

    #[test]
    fn unarmed_plan_passes_everything_through() {
        let plan = Arc::new(FaultPlan::default());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        for _ in 0..10 {
            run_batch(&mut be).expect("unarmed wrapper is transparent");
        }
        assert!(!plan.armed());
        assert_eq!((plan.errors(), plan.stalls(), plan.fatals()), (0, 0, 0));
        assert_eq!(be.inner().calls, 10, "every batch reached the inner backend");
    }

    #[test]
    fn error_every_fires_on_schedule_and_is_transient() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("error-every=3").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        let mut outcomes = Vec::new();
        for _ in 0..9 {
            outcomes.push(run_batch(&mut be).is_ok());
        }
        assert_eq!(
            outcomes,
            vec![true, true, false, true, true, false, true, true, false]
        );
        assert_eq!(plan.errors(), 3);
        // the injected error classifies as transient; its batch is named
        let err = {
            plan.arm(FaultSpec::parse("error-at=10").unwrap());
            run_batch(&mut be).unwrap_err()
        };
        assert_eq!(classify(&err), FaultClass::Transient);
        let fault = err.downcast_ref::<BackendFault>().unwrap();
        assert_eq!((fault.kind, fault.batch), ("error-at", 10));
    }

    #[test]
    fn fail_after_is_fatal_and_permanent() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("fail-after=2").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        assert!(run_batch(&mut be).is_ok());
        assert!(run_batch(&mut be).is_ok());
        for _ in 0..3 {
            let err = run_batch(&mut be).unwrap_err();
            assert_eq!(classify(&err), FaultClass::Fatal);
        }
        assert_eq!(plan.fatals(), 3);
        assert_eq!(be.inner().calls, 2, "failed batches never reach the backend");
    }

    #[test]
    fn stall_delays_but_still_executes() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("stall-at=2:30").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        run_batch(&mut be).unwrap();
        let t0 = std::time::Instant::now();
        run_batch(&mut be).expect("a stalled batch still completes");
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(plan.stalls(), 1);
        assert_eq!(be.inner().calls, 2);
    }

    #[test]
    fn clear_disarms_mid_run() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("error-every=1").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        assert!(run_batch(&mut be).is_err());
        plan.clear();
        assert!(run_batch(&mut be).is_ok());
        assert_eq!(plan.errors(), 1, "the ledger survives a clear");
    }

    #[test]
    fn unknown_errors_classify_fatal() {
        let plain = anyhow::anyhow!("segfault adjacent badness");
        assert_eq!(classify(&plain), FaultClass::Fatal);
    }

    /// The retry-determinism satellite: same seed → byte-identical backoff
    /// schedule; different seeds (shards) → decorrelated ones.
    #[test]
    fn jitter_backoff_is_seed_deterministic() {
        let schedule = |seed: u64| {
            let mut b = JitterBackoff::new(10, 2_000, seed);
            (0..12).map(|_| b.next_ms()).collect::<Vec<_>>()
        };
        assert_eq!(schedule(7), schedule(7));
        assert_ne!(schedule(0), schedule(1), "shard seeds must decorrelate");
        let s = schedule(3);
        assert!(s.iter().all(|&ms| (10..=2_000).contains(&ms)), "{s:?}");
        // reset returns to base without disturbing determinism
        let mut a = JitterBackoff::new(10, 2_000, 42);
        let mut b = JitterBackoff::new(10, 2_000, 42);
        a.next_ms();
        a.reset();
        b.next_ms();
        b.reset();
        assert_eq!(a.next_ms(), b.next_ms());
    }

    #[test]
    fn zero_base_backoff_never_sleeps() {
        let mut b = JitterBackoff::new(0, 0, 9);
        for _ in 0..8 {
            assert_eq!(b.next_ms(), 0);
        }
    }
}
