//! §Robustness: scheduled fault injection for any [`Backend`].
//!
//! [`FaultyBackend`] wraps a real backend and injects failures into the
//! two batch-execution entry points (`denoise_into`/`denoise_into_par`)
//! on a deterministic schedule — the missing half of the chaos harness:
//! PR 6 could only kill shards from the *outside* (`kill-shard`); this
//! makes the compute substrate itself misbehave, which is what transient
//! device resets, OOM retries and wedged kernels look like in production.
//!
//! The schedule is a [`FaultPlan`]: a lock-free, re-armable set of
//! trigger points over the wrapper's own batch counter (1-based — the
//! first batch a backend executes is batch 1). Plans are parsed from the
//! spec grammar ([`FaultSpec::parse`]) used by `agd serve --fault-spec`
//! and the chaos director's `fault` op:
//!
//! ```text
//!   error-every=N      every Nth batch fails (transient)
//!   error-at=K         batch K fails (transient)
//!   stall-at=K:M       batch K sleeps M ms, then executes normally
//!   fail-after=K       every batch past K fails (fatal, permanent)
//!   error-p=P[:seed=S] each batch fails with probability P (transient,
//!                      seeded — the same (seed, shard, batch) triple
//!                      always decides the same way, so probabilistic
//!                      chaos runs still reproduce exactly)
//! ```
//!
//! A whole spec may be prefixed with `shard=I:` (e.g.
//! `shard=1:error-every=3`) to target one shard: wrappers constructed
//! with [`FaultyBackend::with_shard`] pass every batch through untouched
//! unless their shard index matches. Untargeted specs arm every shard
//! identically, the historical behaviour.
//!
//! Clauses combine with commas (`error-every=3,stall-at=5:200`). Checks
//! run in severity order: fail-after (fatal) → stall → error-at →
//! error-every → error-p. Because plans live behind an `Arc` and every
//! field is atomic, the director can re-arm or clear a plan *while shards
//! are executing* without a lock — and the per-shard batch counter lives
//! on the wrapper (not the plan), so each shard sees the same
//! deterministic schedule regardless of how the fleet interleaves.
//!
//! Injected failures are typed ([`BackendFault`], carrying a
//! [`FaultClass`]): the engine's bounded-retry loop (`--max-batch-retries`)
//! classifies errors via [`classify`] and retries only transients —
//! anything it cannot downcast stays fatal, preserving the historical
//! die-on-first-error behaviour for real backend bugs. Retry pacing is a
//! seeded decorrelated-jitter backoff ([`JitterBackoff`]) so retry storms
//! desynchronize across shards while staying reproducible in tests.
//!
//! §Perf: the unarmed (all-zero) plan is the production configuration —
//! `serve` always wraps the backend so the director can arm faults later.
//! The pass-through check is a handful of relaxed atomic loads and no
//! allocation, pinned by `rust/tests/fault_zero_alloc.rs`.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::backend::{Backend, BatchBuf, BatchOut};
use crate::exec::{ExecPool, RunStats};
use crate::util::rng::Rng;

/// Severity of an injected (or classified) backend failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// Worth retrying: the batch may succeed on a later attempt
    /// (device reset, allocator pressure, a wedged-then-recovered lane).
    Transient,
    /// Permanent: retrying cannot help; the shard's death path runs.
    Fatal,
}

impl FaultClass {
    /// Telemetry label value (`batch_retries_total{class=}`).
    pub fn name(self) -> &'static str {
        match self {
            FaultClass::Transient => "transient",
            FaultClass::Fatal => "fatal",
        }
    }
}

/// A typed injected backend failure. Carried inside `anyhow::Error` so it
/// crosses the existing `Result` plumbing unchanged; the engine recovers
/// the class with [`classify`].
#[derive(Debug, Clone)]
pub struct BackendFault {
    pub class: FaultClass,
    /// Which trigger fired: `error-every` | `error-at` | `fail-after` |
    /// `error-p`.
    pub kind: &'static str,
    /// 1-based batch number (on the injecting wrapper) that tripped.
    pub batch: u64,
}

impl fmt::Display for BackendFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected {} backend fault ({} at batch {})",
            self.class.name(),
            self.kind,
            self.batch
        )
    }
}

impl std::error::Error for BackendFault {}

/// Recover the failure class from any backend error. Unknown errors are
/// [`FaultClass::Fatal`] — a real backend bug must keep running the
/// historical death path, never spin in a retry loop.
pub fn classify(e: &anyhow::Error) -> FaultClass {
    e.downcast_ref::<BackendFault>()
        .map(|f| f.class)
        .unwrap_or(FaultClass::Fatal)
}

/// A parsed fault schedule (see the grammar in the module docs). `0`
/// disables a trigger — batch numbers are 1-based precisely so the
/// all-zero default means "no faults". (No `Eq`: `error_p` is an `f64`.)
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultSpec {
    /// Every Nth batch errors (transient); 0 = off.
    pub error_every: u64,
    /// Batch K errors (transient); 0 = off.
    pub error_at: u64,
    /// Batch K stalls before executing; 0 = off.
    pub stall_at: u64,
    /// Stall duration in milliseconds.
    pub stall_ms: u64,
    /// Every batch past K errors (fatal); 0 = off.
    pub fail_after: u64,
    /// Each batch errors with this probability (transient, seeded);
    /// 0.0 = off.
    pub error_p: f64,
    /// Seed for the `error-p` decision hash (`:seed=S`; default 0).
    pub error_p_seed: u64,
    /// Target one shard (`shard=I:` prefix); `None` = every shard.
    pub shard: Option<usize>,
}

impl FaultSpec {
    /// Parse the comma-joined clause grammar. Errors name the bad clause
    /// and the valid forms — a typo in `--fault-spec` or a scenario file
    /// must fail the run loudly, not silently inject nothing.
    pub fn parse(text: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        // a whole-spec `shard=I:` prefix targets one shard's wrapper
        let mut text = text.trim();
        if let Some(rest) = text.strip_prefix("shard=") {
            let Some((idx, tail)) = rest.split_once(':') else {
                return Err(format!(
                    "fault spec `shard=` prefix wants shard=I:CLAUSES, got `{text}`"
                ));
            };
            spec.shard = Some(idx.trim().parse::<usize>().map_err(|_| {
                format!("fault spec shard index `{}` is not a number", idx.trim())
            })?);
            text = tail;
        }
        for clause in text.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let Some((key, val)) = clause.split_once('=') else {
                return Err(format!(
                    "fault clause `{clause}` is not key=value (valid: \
                     error-every=N, error-at=K, stall-at=K:M, fail-after=K, \
                     error-p=P[:seed=S])"
                ));
            };
            let num = |v: &str| {
                v.parse::<u64>()
                    .map_err(|_| format!("fault clause `{key}`: `{v}` is not a number"))
            };
            match key {
                "error-every" => spec.error_every = num(val)?,
                "error-at" => spec.error_at = num(val)?,
                "fail-after" => spec.fail_after = num(val)?,
                "stall-at" => {
                    let Some((k, ms)) = val.split_once(':') else {
                        return Err(format!(
                            "fault clause `stall-at` wants BATCH:MS, got `{val}`"
                        ));
                    };
                    spec.stall_at = num(k)?;
                    spec.stall_ms = num(ms)?;
                }
                "error-p" => {
                    let (p, seed) = match val.split_once(':') {
                        Some((p, rest)) => {
                            let Some(s) = rest.strip_prefix("seed=") else {
                                return Err(format!(
                                    "fault clause `error-p` wants P or P:seed=S, got `{val}`"
                                ));
                            };
                            (p, num(s)?)
                        }
                        None => (val, 0),
                    };
                    let p: f64 = p.parse().map_err(|_| {
                        format!("fault clause `error-p`: `{p}` is not a probability")
                    })?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!(
                            "fault clause `error-p`: `{p}` is not in [0, 1]"
                        ));
                    }
                    spec.error_p = p;
                    spec.error_p_seed = seed;
                }
                other => {
                    return Err(format!(
                        "unknown fault clause `{other}` (valid: error-every=N, \
                         error-at=K, stall-at=K:M, fail-after=K, error-p=P[:seed=S])"
                    ));
                }
            }
        }
        Ok(spec)
    }

    /// No trigger armed (the pass-through production configuration).
    pub fn is_clear(&self) -> bool {
        *self == FaultSpec::default()
    }
}

/// The live, shared fault schedule: a [`FaultSpec`] as atomics (re-armable
/// mid-run by the chaos director) plus per-kind injection counters. One
/// plan is shared by every shard's wrapper via `Arc`; the batch counters
/// driving the schedule are per-wrapper (see module docs).
#[derive(Debug, Default)]
pub struct FaultPlan {
    error_every: AtomicU64,
    error_at: AtomicU64,
    stall_at: AtomicU64,
    stall_ms: AtomicU64,
    fail_after: AtomicU64,
    /// `error-p` probability as `f64::to_bits` (0 = off — `0.0f64`
    /// to-bits is exactly 0, so the all-zero default stays "no faults").
    error_p_bits: AtomicU64,
    error_p_seed: AtomicU64,
    /// Targeted shard + 1 (`shard=I:` prefix); 0 = every shard. The +1
    /// encoding keeps the all-zero derived default meaning "untargeted".
    target_shard: AtomicU64,
    injected_errors: AtomicU64,
    injected_stalls: AtomicU64,
    injected_fatals: AtomicU64,
}

impl FaultPlan {
    /// Install `spec`, replacing whatever was armed. Counters are kept —
    /// they are a monotonic injection ledger, not part of the schedule.
    pub fn arm(&self, spec: FaultSpec) {
        self.error_every.store(spec.error_every, Ordering::Relaxed);
        self.error_at.store(spec.error_at, Ordering::Relaxed);
        self.stall_at.store(spec.stall_at, Ordering::Relaxed);
        self.stall_ms.store(spec.stall_ms, Ordering::Relaxed);
        self.fail_after.store(spec.fail_after, Ordering::Relaxed);
        self.error_p_bits
            .store(spec.error_p.to_bits(), Ordering::Relaxed);
        self.error_p_seed
            .store(spec.error_p_seed, Ordering::Relaxed);
        self.target_shard.store(
            spec.shard.map(|s| s as u64 + 1).unwrap_or(0),
            Ordering::Relaxed,
        );
    }

    /// Disarm every trigger (the director's `fault clear`).
    pub fn clear(&self) {
        self.arm(FaultSpec::default());
    }

    /// Is any trigger armed?
    pub fn armed(&self) -> bool {
        self.error_every.load(Ordering::Relaxed) != 0
            || self.error_at.load(Ordering::Relaxed) != 0
            || self.stall_at.load(Ordering::Relaxed) != 0
            || self.fail_after.load(Ordering::Relaxed) != 0
            || self.error_p_bits.load(Ordering::Relaxed) != 0
    }

    /// Transient errors injected so far (all wrappers sharing this plan).
    pub fn errors(&self) -> u64 {
        self.injected_errors.load(Ordering::Relaxed)
    }

    /// Stalls injected so far.
    pub fn stalls(&self) -> u64 {
        self.injected_stalls.load(Ordering::Relaxed)
    }

    /// Fatal errors injected so far.
    pub fn fatals(&self) -> u64 {
        self.injected_fatals.load(Ordering::Relaxed)
    }
}

/// A [`Backend`] wrapper injecting its [`FaultPlan`]'s schedule into the
/// batch-execution path. Every other trait method delegates untouched, so
/// wrapping changes *when* batches fail, never what they compute.
pub struct FaultyBackend<B: Backend> {
    inner: B,
    plan: Arc<FaultPlan>,
    /// Batches this wrapper has been asked to execute (1-based in checks).
    batches: u64,
    /// This wrapper's shard index: `shard=I:` specs fire only where it
    /// matches, and it salts the `error-p` decision hash so shards
    /// decorrelate under one shared plan.
    shard: u64,
}

impl<B: Backend> FaultyBackend<B> {
    pub fn new(inner: B, plan: Arc<FaultPlan>) -> FaultyBackend<B> {
        FaultyBackend::with_shard(inner, plan, 0)
    }

    /// A wrapper that knows which shard it serves — what the fleet
    /// installs, so `shard=I:` targeting and per-shard `error-p` salting
    /// work. [`FaultyBackend::new`] is shard 0 (the single-engine case).
    pub fn with_shard(inner: B, plan: Arc<FaultPlan>, shard: u64) -> FaultyBackend<B> {
        FaultyBackend {
            inner,
            plan,
            batches: 0,
            shard,
        }
    }

    /// The wrapped backend (tests reach its counters through here).
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Batches seen by this wrapper (injected failures included).
    pub fn batches_seen(&self) -> u64 {
        self.batches
    }

    /// Run the schedule for the next batch: count it, then fire whichever
    /// trigger matches (severity order — fatal, stall, transient). The
    /// unarmed path is branch-predictable atomic loads, nothing else.
    fn check(&mut self) -> Result<()> {
        self.batches += 1;
        let n = self.batches;
        // `shard=I:` targeting: a plan aimed elsewhere is transparent
        // here (the batch still counts — the schedule is positional on
        // *this* wrapper, matching the untargeted semantics)
        let target = self.plan.target_shard.load(Ordering::Relaxed);
        if target != 0 && target != self.shard + 1 {
            return Ok(());
        }
        let fail_after = self.plan.fail_after.load(Ordering::Relaxed);
        if fail_after != 0 && n > fail_after {
            self.plan.injected_fatals.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(BackendFault {
                class: FaultClass::Fatal,
                kind: "fail-after",
                batch: n,
            }));
        }
        let stall_at = self.plan.stall_at.load(Ordering::Relaxed);
        if stall_at != 0 && n == stall_at {
            let ms = self.plan.stall_ms.load(Ordering::Relaxed);
            self.plan.injected_stalls.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(ms));
        }
        let error_at = self.plan.error_at.load(Ordering::Relaxed);
        if error_at != 0 && n == error_at {
            self.plan.injected_errors.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(BackendFault {
                class: FaultClass::Transient,
                kind: "error-at",
                batch: n,
            }));
        }
        let every = self.plan.error_every.load(Ordering::Relaxed);
        if every != 0 && n % every == 0 {
            self.plan.injected_errors.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(BackendFault {
                class: FaultClass::Transient,
                kind: "error-every",
                batch: n,
            }));
        }
        let p_bits = self.plan.error_p_bits.load(Ordering::Relaxed);
        if p_bits != 0 {
            let p = f64::from_bits(p_bits);
            let seed = self.plan.error_p_seed.load(Ordering::Relaxed);
            if decide(seed, self.shard, n) < p {
                self.plan.injected_errors.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow::Error::new(BackendFault {
                    class: FaultClass::Transient,
                    kind: "error-p",
                    batch: n,
                }));
            }
        }
        Ok(())
    }
}

/// The `error-p` decision hash: a stateless splitmix64 finalizer over the
/// (seed, shard, batch) triple, mapped to a uniform in [0, 1). Stateless
/// on purpose — re-arming the plan mid-run cannot shift which batches
/// fail, and every wrapper sharing a plan decides independently per
/// (shard, batch) without any cross-thread RNG state.
fn decide(seed: u64, shard: u64, batch: u64) -> f64 {
    let mut z = seed
        ^ shard.wrapping_mul(0xD1B5_4A32_D192_ED03)
        ^ batch.wrapping_mul(0x2545_F491_4F6C_DD1D);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    let h = z ^ (z >> 31);
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl<B: Backend> Backend for FaultyBackend<B> {
    fn flat_in(&self, model: &str) -> usize {
        self.inner.flat_in(model)
    }

    fn flat_out(&self, model: &str) -> usize {
        self.inner.flat_out(model)
    }

    fn buckets(&self) -> &[usize] {
        self.inner.buckets()
    }

    fn max_batch(&self, model: &str) -> usize {
        self.inner.max_batch(model)
    }

    fn validate_tokens(&self, model: &str, tokens: &[i32]) -> Result<(), &'static str> {
        self.inner.validate_tokens(model, tokens)
    }

    fn denoise_into(&mut self, model: &str, batch: &BatchBuf, out: &mut BatchOut) -> Result<()> {
        self.check()?;
        self.inner.denoise_into(model, batch, out)
    }

    fn denoise_into_par(
        &mut self,
        model: &str,
        batch: &BatchBuf,
        out: &mut BatchOut,
        exec: &ExecPool,
    ) -> Result<Option<RunStats>> {
        self.check()?;
        self.inner.denoise_into_par(model, batch, out, exec)
    }

    fn models(&self) -> Vec<String> {
        self.inner.models()
    }
}

/// Decorrelated-jitter retry backoff (the AWS-architecture-blog variant):
/// each delay is uniform in `[base, 3 * previous]`, capped — successive
/// retries spread apart *and* desynchronize across independent retriers,
/// which is what stops a transient-fault storm from re-aligning every
/// shard's retry attempt into the same instant. Seeded via the crate's
/// own [`Rng`] so schedules are identical across runs (the determinism
/// pin in the fault unit suite); the fleet seeds each shard's engine with
/// its shard index so shards still decorrelate from *each other*.
#[derive(Debug)]
pub struct JitterBackoff {
    base_ms: u64,
    cap_ms: u64,
    prev_ms: u64,
    rng: Rng,
}

impl JitterBackoff {
    pub fn new(base_ms: u64, cap_ms: u64, seed: u64) -> JitterBackoff {
        JitterBackoff {
            base_ms,
            cap_ms,
            prev_ms: base_ms,
            rng: Rng::new(seed),
        }
    }

    /// Next delay in milliseconds, advancing the sequence.
    pub fn next_ms(&mut self) -> u64 {
        let hi = self.prev_ms.saturating_mul(3).max(self.base_ms + 1);
        let span = (hi - self.base_ms).min(usize::MAX as u64) as usize;
        let ms = (self.base_ms + self.rng.below(span.max(1)) as u64).min(self.cap_ms);
        self.prev_ms = ms.max(self.base_ms);
        ms
    }

    /// Back to the base delay (after a successful attempt). The RNG
    /// stream deliberately keeps advancing — determinism is a property of
    /// the whole run, not of each outage.
    pub fn reset(&mut self) {
        self.prev_ms = self.base_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GmmBackend;
    use crate::sim::gmm::Gmm;

    fn gmm() -> GmmBackend {
        GmmBackend::new(Gmm::axes(8, 4, 3.0, 0.05))
    }

    fn run_batch<B: Backend>(be: &mut B) -> Result<()> {
        let mut batch = BatchBuf::new(8, 4);
        let (x, toks) = batch.push_row(0.5);
        x.fill(0.1);
        toks[0] = 1;
        let mut out = BatchOut::default();
        be.denoise_into("gmm", &batch, &mut out)
    }

    #[test]
    fn spec_grammar_round_trips() {
        let spec = FaultSpec::parse("error-every=3,error-at=7,stall-at=5:200,fail-after=40")
            .expect("full grammar");
        assert_eq!(
            spec,
            FaultSpec {
                error_every: 3,
                error_at: 7,
                stall_at: 5,
                stall_ms: 200,
                fail_after: 40,
                ..FaultSpec::default()
            }
        );
        // whitespace and empty clauses are tolerated; empty spec = clear
        assert!(FaultSpec::parse("").unwrap().is_clear());
        assert_eq!(FaultSpec::parse(" error-at=2 , ").unwrap().error_at, 2);
        // the shard prefix and the probabilistic clause
        let spec = FaultSpec::parse("shard=1:error-every=3,error-p=0.05:seed=42").unwrap();
        assert_eq!(spec.shard, Some(1));
        assert_eq!(spec.error_every, 3);
        assert_eq!(spec.error_p, 0.05);
        assert_eq!(spec.error_p_seed, 42);
        // seed is optional (defaults to 0); a bare probability parses
        let spec = FaultSpec::parse("error-p=1").unwrap();
        assert_eq!((spec.error_p, spec.error_p_seed), (1.0, 0));
        assert!(!spec.is_clear(), "an armed error-p is not a clear spec");
    }

    #[test]
    fn spec_grammar_rejects_garbage_loudly() {
        for bad in ["boom", "error-every", "error-at=x", "stall-at=5", "warp=1"] {
            let err = FaultSpec::parse(bad).unwrap_err();
            assert!(err.contains("fault clause") || err.contains("unknown"), "{bad}: {err}");
        }
        // new-grammar garbage is named just as loudly
        for bad in [
            "shard=x:error-at=1",
            "shard=2",
            "error-p=1.5",
            "error-p=-0.1",
            "error-p=nope",
            "error-p=0.1:sneed=3",
        ] {
            let err = FaultSpec::parse(bad).unwrap_err();
            assert!(
                err.contains("fault clause") || err.contains("fault spec"),
                "{bad}: {err}"
            );
        }
    }

    #[test]
    fn shard_prefix_targets_one_wrapper() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("shard=1:error-every=1").unwrap());
        let mut be0 = FaultyBackend::with_shard(gmm(), plan.clone(), 0);
        let mut be1 = FaultyBackend::with_shard(gmm(), plan.clone(), 1);
        for _ in 0..4 {
            run_batch(&mut be0).expect("shard 0 is not the target");
            run_batch(&mut be1).unwrap_err();
        }
        assert_eq!(be0.inner().calls, 4);
        assert_eq!(be1.inner().calls, 0);
        assert_eq!(plan.errors(), 4, "only the targeted wrapper injects");
        // re-arming untargeted hits every wrapper again — and the
        // bystander's batch counter kept advancing while it was exempt,
        // so positional triggers stay aligned with batches *seen*
        plan.arm(FaultSpec::parse("error-at=5").unwrap());
        run_batch(&mut be0).unwrap_err();
        assert_eq!(be0.batches_seen(), 5);
    }

    #[test]
    fn error_p_is_seed_deterministic() {
        let outcomes = |seed: u64, shard: u64| {
            let plan = Arc::new(FaultPlan::default());
            plan.arm(FaultSpec {
                error_p: 0.5,
                error_p_seed: seed,
                ..FaultSpec::default()
            });
            let mut be = FaultyBackend::with_shard(gmm(), plan, shard);
            (0..32).map(|_| run_batch(&mut be).is_ok()).collect::<Vec<_>>()
        };
        // same (seed, shard) → identical schedule; either axis decorrelates
        assert_eq!(outcomes(42, 0), outcomes(42, 0));
        assert_ne!(outcomes(42, 0), outcomes(43, 0), "seed must matter");
        assert_ne!(outcomes(42, 0), outcomes(42, 1), "shard must salt");
        // p=0.5 over 32 draws: both outcomes occur (vanishing odds otherwise)
        let o = outcomes(42, 0);
        assert!(o.iter().any(|&ok| ok) && o.iter().any(|&ok| !ok), "{o:?}");
        // p=1 always fires and classifies transient with the right kind
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("error-p=1:seed=7").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        let err = run_batch(&mut be).unwrap_err();
        assert_eq!(classify(&err), FaultClass::Transient);
        assert_eq!(err.downcast_ref::<BackendFault>().unwrap().kind, "error-p");
        assert!(plan.armed());
    }

    #[test]
    fn unarmed_plan_passes_everything_through() {
        let plan = Arc::new(FaultPlan::default());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        for _ in 0..10 {
            run_batch(&mut be).expect("unarmed wrapper is transparent");
        }
        assert!(!plan.armed());
        assert_eq!((plan.errors(), plan.stalls(), plan.fatals()), (0, 0, 0));
        assert_eq!(be.inner().calls, 10, "every batch reached the inner backend");
    }

    #[test]
    fn error_every_fires_on_schedule_and_is_transient() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("error-every=3").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        let mut outcomes = Vec::new();
        for _ in 0..9 {
            outcomes.push(run_batch(&mut be).is_ok());
        }
        assert_eq!(
            outcomes,
            vec![true, true, false, true, true, false, true, true, false]
        );
        assert_eq!(plan.errors(), 3);
        // the injected error classifies as transient; its batch is named
        let err = {
            plan.arm(FaultSpec::parse("error-at=10").unwrap());
            run_batch(&mut be).unwrap_err()
        };
        assert_eq!(classify(&err), FaultClass::Transient);
        let fault = err.downcast_ref::<BackendFault>().unwrap();
        assert_eq!((fault.kind, fault.batch), ("error-at", 10));
    }

    #[test]
    fn fail_after_is_fatal_and_permanent() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("fail-after=2").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        assert!(run_batch(&mut be).is_ok());
        assert!(run_batch(&mut be).is_ok());
        for _ in 0..3 {
            let err = run_batch(&mut be).unwrap_err();
            assert_eq!(classify(&err), FaultClass::Fatal);
        }
        assert_eq!(plan.fatals(), 3);
        assert_eq!(be.inner().calls, 2, "failed batches never reach the backend");
    }

    #[test]
    fn stall_delays_but_still_executes() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("stall-at=2:30").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        run_batch(&mut be).unwrap();
        let t0 = std::time::Instant::now();
        run_batch(&mut be).expect("a stalled batch still completes");
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(plan.stalls(), 1);
        assert_eq!(be.inner().calls, 2);
    }

    #[test]
    fn clear_disarms_mid_run() {
        let plan = Arc::new(FaultPlan::default());
        plan.arm(FaultSpec::parse("error-every=1").unwrap());
        let mut be = FaultyBackend::new(gmm(), plan.clone());
        assert!(run_batch(&mut be).is_err());
        plan.clear();
        assert!(run_batch(&mut be).is_ok());
        assert_eq!(plan.errors(), 1, "the ledger survives a clear");
    }

    #[test]
    fn unknown_errors_classify_fatal() {
        let plain = anyhow::anyhow!("segfault adjacent badness");
        assert_eq!(classify(&plain), FaultClass::Fatal);
    }

    /// The retry-determinism satellite: same seed → byte-identical backoff
    /// schedule; different seeds (shards) → decorrelated ones.
    #[test]
    fn jitter_backoff_is_seed_deterministic() {
        let schedule = |seed: u64| {
            let mut b = JitterBackoff::new(10, 2_000, seed);
            (0..12).map(|_| b.next_ms()).collect::<Vec<_>>()
        };
        assert_eq!(schedule(7), schedule(7));
        assert_ne!(schedule(0), schedule(1), "shard seeds must decorrelate");
        let s = schedule(3);
        assert!(s.iter().all(|&ms| (10..=2_000).contains(&ms)), "{s:?}");
        // reset returns to base without disturbing determinism
        let mut a = JitterBackoff::new(10, 2_000, 42);
        let mut b = JitterBackoff::new(10, 2_000, 42);
        a.next_ms();
        a.reset();
        b.next_ms();
        b.reset();
        assert_eq!(a.next_ms(), b.next_ms());
    }

    #[test]
    fn zero_base_backoff_never_sleeps() {
        let mut b = JitterBackoff::new(0, 0, 9);
        for _ in 0..8 {
            assert_eq!(b.next_ms(), 0);
        }
    }
}
