//! The scripted chaos director (§Robustness): `scenarios/*.txt` →
//! faults injected against a live listener + fleet.
//!
//! A scenario file is one op per line (`#` comments and blank lines
//! skipped). Connection names are arbitrary identifiers; a connection is
//! created by `connect` and drops its socket on `disconnect` (or at the
//! end of the run):
//!
//! ```text
//! connect a                  open TCP connection `a` to the server
//! send a {"prompt": ...}     write one protocol line (rest of line verbatim)
//! expect-ok a                read a's next reply; fail if it has `error`
//! expect-code a queue_full   read a's next reply; fail unless code matches
//! expect-id a 3              await the reply echoing wire id 3 (pipelined
//!                            connections answer out of order; progress
//!                            events are skipped, other ids stashed for
//!                            their own expect); fail if it has `error`
//! expect-id-code a 3 canceled  same await, but fail unless code matches
//! expect-closed a            fail unless the server closed a's socket
//! send-raw a bytes…          raw bytes, no newline (\n \r \t \\ \xNN escapes)
//! send-raw-repeat a 61 8192  one byte (hex) repeated N times, no newline
//! slowloris a                one byte of an unfinished line, no newline
//! disconnect a               drop a's socket mid-whatever
//! kill-shard 0               inject a crash into shard 0 ([`Fleet::kill_shard`])
//! fault error-every=3        arm the fleet's backend fault plan
//!                            ([`crate::chaos::fault::FaultSpec`] grammar)
//! fault clear                disarm every scheduled fault
//! wait-respawn 0 2000        block until shard 0 is placeable again
//!                            (supervisor respawn), failing after the
//!                            timeout in ms
//! drain                      fleet drain (graceful quiesce) from inside
//! sleep 25                   wall-clock pause, ms
//! ```
//!
//! `expect-ok` replies are collected into [`Director::replies`] with the
//! request line that produced them, so the harness can assert survivor
//! completions byte-identical to a clean single-shard run
//! (`rust/tests/chaos_integration.rs`). Raw/slowloris writes ignore
//! broken-pipe errors — the scenario may legitimately race a server that
//! already replied and closed.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::fleet::Fleet;
use crate::util::json::{self, Value};

/// One scenario operation (one line of a `scenarios/*.txt` file).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Connect(String),
    Send { conn: String, line: String },
    ExpectOk(String),
    ExpectCode { conn: String, code: String },
    /// Await the reply echoing wire id `id` on a pipelined connection
    /// (skipping progress events, stashing other ids); fail on `error`.
    ExpectId { conn: String, id: u64 },
    /// Await wire id `id`'s reply and require its error `code`.
    ExpectIdCode { conn: String, id: u64, code: String },
    ExpectClosed(String),
    SendRaw { conn: String, bytes: Vec<u8> },
    SendRawRepeat { conn: String, byte: u8, count: usize },
    Slowloris(String),
    Disconnect(String),
    KillShard(usize),
    /// Arm the fleet's fault plan with a spec, or `clear` to disarm
    /// (§Robustness). The grammar is validated at script parse time (a
    /// bad spec names its line) and re-parsed cheaply at execution.
    Fault(String),
    /// Poll until the shard is placeable again (supervisor respawn),
    /// failing after `timeout_ms`.
    WaitRespawn { shard: usize, timeout_ms: u64 },
    Drain,
    Sleep(u64),
}

/// Decode the `send-raw` escape set: `\n`, `\r`, `\t`, `\\`, `\xNN`.
fn unescape(text: &str) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(text.len());
    let mut chars = text.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            let mut buf = [0u8; 4];
            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
            continue;
        }
        match chars.next() {
            Some('n') => out.push(b'\n'),
            Some('r') => out.push(b'\r'),
            Some('t') => out.push(b'\t'),
            Some('\\') => out.push(b'\\'),
            Some('x') => {
                let hi = chars.next().ok_or_else(|| anyhow!("truncated \\x escape"))?;
                let lo = chars.next().ok_or_else(|| anyhow!("truncated \\x escape"))?;
                let byte = u8::from_str_radix(&format!("{hi}{lo}"), 16)
                    .map_err(|_| anyhow!("bad \\x escape `\\x{hi}{lo}`"))?;
                out.push(byte);
            }
            other => bail!("bad escape `\\{}`", other.map(String::from).unwrap_or_default()),
        }
    }
    Ok(out)
}

/// Parse a scenario script. Errors name the offending 1-based line.
pub fn parse_script(text: &str) -> Result<Vec<Op>> {
    let mut ops = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let op = parse_op(line).map_err(|e| anyhow!("scenario line {}: {e}", idx + 1))?;
        ops.push(op);
    }
    Ok(ops)
}

fn parse_op(line: &str) -> Result<Op> {
    let (verb, rest) = line.split_once(char::is_whitespace).unwrap_or((line, ""));
    let rest = rest.trim();
    let one_word = |what: &str| -> Result<String> {
        if rest.is_empty() || rest.contains(char::is_whitespace) {
            bail!("`{verb}` takes exactly one {what}");
        }
        Ok(rest.to_owned())
    };
    Ok(match verb {
        "connect" => Op::Connect(one_word("connection name")?),
        "send" => {
            let (conn, payload) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| anyhow!("`send` needs a connection and a payload"))?;
            Op::Send {
                conn: conn.to_owned(),
                line: payload.trim().to_owned(),
            }
        }
        "expect-ok" => Op::ExpectOk(one_word("connection name")?),
        "expect-code" => {
            let (conn, code) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| anyhow!("`expect-code` needs a connection and a code"))?;
            Op::ExpectCode {
                conn: conn.to_owned(),
                code: code.trim().to_owned(),
            }
        }
        "expect-id" => {
            let parts: Vec<&str> = rest.split_whitespace().collect();
            let [conn, id] = parts.as_slice() else {
                bail!("`expect-id` needs: conn id");
            };
            Op::ExpectId {
                conn: (*conn).to_owned(),
                id: id.parse().map_err(|_| anyhow!("bad wire id `{id}`"))?,
            }
        }
        "expect-id-code" => {
            let parts: Vec<&str> = rest.split_whitespace().collect();
            let [conn, id, code] = parts.as_slice() else {
                bail!("`expect-id-code` needs: conn id code");
            };
            Op::ExpectIdCode {
                conn: (*conn).to_owned(),
                id: id.parse().map_err(|_| anyhow!("bad wire id `{id}`"))?,
                code: (*code).to_owned(),
            }
        }
        "expect-closed" => Op::ExpectClosed(one_word("connection name")?),
        "send-raw" => {
            let (conn, payload) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| anyhow!("`send-raw` needs a connection and bytes"))?;
            Op::SendRaw {
                conn: conn.to_owned(),
                bytes: unescape(payload.trim())?,
            }
        }
        "send-raw-repeat" => {
            let parts: Vec<&str> = rest.split_whitespace().collect();
            let [conn, byte, count] = parts.as_slice() else {
                bail!("`send-raw-repeat` needs: conn byte-hex count");
            };
            Op::SendRawRepeat {
                conn: (*conn).to_owned(),
                byte: u8::from_str_radix(byte, 16)
                    .map_err(|_| anyhow!("bad hex byte `{byte}`"))?,
                count: count.parse().map_err(|_| anyhow!("bad count `{count}`"))?,
            }
        }
        "slowloris" => Op::Slowloris(one_word("connection name")?),
        "disconnect" => Op::Disconnect(one_word("connection name")?),
        "kill-shard" => Op::KillShard(
            one_word("shard index")?
                .parse()
                .map_err(|_| anyhow!("bad shard index `{rest}`"))?,
        ),
        "fault" => {
            let spec = one_word("fault spec (or `clear`)")?;
            if spec != "clear" {
                // validate the grammar here so the error names the line
                crate::chaos::fault::FaultSpec::parse(&spec)
                    .map_err(|e| anyhow!("bad fault spec: {e}"))?;
            }
            Op::Fault(spec)
        }
        "wait-respawn" => {
            let parts: Vec<&str> = rest.split_whitespace().collect();
            let [shard, timeout] = parts.as_slice() else {
                bail!("`wait-respawn` needs: shard timeout-ms");
            };
            Op::WaitRespawn {
                shard: shard
                    .parse()
                    .map_err(|_| anyhow!("bad shard index `{shard}`"))?,
                timeout_ms: timeout
                    .parse()
                    .map_err(|_| anyhow!("bad timeout `{timeout}`"))?,
            }
        }
        "drain" => {
            if !rest.is_empty() {
                bail!("`drain` takes no arguments");
            }
            Op::Drain
        }
        "sleep" => Op::Sleep(
            one_word("millisecond count")?
                .parse()
                .map_err(|_| anyhow!("bad sleep duration `{rest}`"))?,
        ),
        other => bail!("unknown op `{other}`"),
    })
}

/// An `expect-ok` reply paired with the request line that produced it.
#[derive(Debug, Clone)]
pub struct Reply {
    pub conn: String,
    pub request_line: String,
    pub value: Value,
}

struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    /// Request lines sent but not yet consumed by an expect op, FIFO —
    /// the line protocol answers in order per connection.
    pending: VecDeque<String>,
    /// Replies read while hunting for a specific wire id, parked for the
    /// expect op that wants them (pipelined replies interleave freely).
    stash: Vec<Value>,
}

/// Interprets a parsed scenario against a live server + its fleet handle.
pub struct Director<'a> {
    fleet: &'a Fleet,
    addr: SocketAddr,
    timeout: Duration,
    conns: HashMap<String, Conn>,
    /// Every `expect-ok` reply, for golden comparison after the run.
    pub replies: Vec<Reply>,
}

impl<'a> Director<'a> {
    pub fn new(fleet: &'a Fleet, addr: SocketAddr) -> Director<'a> {
        Director {
            fleet,
            addr,
            // generous: expect ops wait on real generation work
            timeout: Duration::from_secs(10),
            conns: HashMap::new(),
            replies: Vec::new(),
        }
    }

    /// Run a scenario script start to finish; the first failed op aborts
    /// with its line's context.
    pub fn run(&mut self, script: &str) -> Result<()> {
        for op in parse_script(script)? {
            self.step(&op).with_context(|| format!("executing {op:?}"))?;
        }
        Ok(())
    }

    fn conn(&mut self, name: &str) -> Result<&mut Conn> {
        self.conns
            .get_mut(name)
            .ok_or_else(|| anyhow!("connection `{name}` is not open"))
    }

    /// Write raw bytes, tolerating a peer that already closed: chaos
    /// scenarios legitimately race the server's hang-up (e.g. an
    /// oversized frame answered and closed mid-send).
    fn write_raw(&mut self, name: &str, bytes: &[u8]) -> Result<()> {
        let conn = self.conn(name)?;
        match conn.writer.write_all(bytes).and_then(|()| conn.writer.flush()) {
            Ok(()) => Ok(()),
            Err(e) if matches!(
                e.kind(),
                ErrorKind::BrokenPipe | ErrorKind::ConnectionReset | ErrorKind::ConnectionAborted
            ) =>
            {
                Ok(())
            }
            Err(e) => Err(e.into()),
        }
    }

    fn read_reply(&mut self, name: &str) -> Result<Value> {
        let conn = self.conn(name)?;
        let mut line = String::new();
        let n = conn
            .reader
            .read_line(&mut line)
            .with_context(|| format!("reading reply on `{name}`"))?;
        anyhow::ensure!(n > 0, "server closed `{name}` instead of replying");
        json::parse(line.trim()).map_err(|e| anyhow!("reply on `{name}` is not JSON: {line:?} ({e})"))
    }

    /// Read until the reply echoing `id` arrives: progress events are
    /// skipped, replies for other ids are stashed for their own expect
    /// op, and a previously stashed match is consumed first.
    fn read_reply_for_id(&mut self, name: &str, id: u64) -> Result<Value> {
        let want = Some(id as f64);
        let conn = self.conn(name)?;
        if let Some(pos) = conn
            .stash
            .iter()
            .position(|v| v.get("id").and_then(Value::as_f64) == want)
        {
            return Ok(conn.stash.remove(pos));
        }
        loop {
            let mut line = String::new();
            let n = conn
                .reader
                .read_line(&mut line)
                .with_context(|| format!("reading reply for id {id} on `{name}`"))?;
            anyhow::ensure!(n > 0, "server closed `{name}` before replying to id {id}");
            let v = json::parse(line.trim())
                .map_err(|e| anyhow!("reply on `{name}` is not JSON: {line:?} ({e})"))?;
            if v.get("event").and_then(Value::as_str) == Some("progress") {
                continue;
            }
            if v.get("id").and_then(Value::as_f64) == want {
                return Ok(v);
            }
            conn.stash.push(v);
        }
    }

    /// Pull the sent request line carrying `"id": <id>` out of the
    /// pending set (pipelined expects consume out of FIFO order).
    fn take_request_for_id(&mut self, name: &str, id: u64) -> Result<String> {
        let conn = self.conn(name)?;
        let pos = conn.pending.iter().position(|l| {
            json::parse(l)
                .ok()
                .and_then(|v| v.get("id").and_then(Value::as_f64))
                == Some(id as f64)
        });
        Ok(pos.and_then(|p| conn.pending.remove(p)).unwrap_or_default())
    }

    fn step(&mut self, op: &Op) -> Result<()> {
        match op {
            Op::Connect(name) => {
                let stream = TcpStream::connect_timeout(&self.addr, self.timeout)
                    .with_context(|| format!("connecting `{name}`"))?;
                stream.set_read_timeout(Some(self.timeout)).ok();
                let reader = BufReader::new(stream.try_clone().context("clone stream")?);
                self.conns.insert(
                    name.clone(),
                    Conn {
                        writer: stream,
                        reader,
                        pending: VecDeque::new(),
                        stash: Vec::new(),
                    },
                );
            }
            Op::Send { conn: name, line } => {
                let payload = format!("{line}\n");
                self.write_raw(name, payload.as_bytes())?;
                self.conn(name)?.pending.push_back(line.clone());
            }
            Op::ExpectOk(name) => {
                let v = self.read_reply(name)?;
                anyhow::ensure!(
                    v.get("error").is_none(),
                    "expected a completion on `{name}`, got {}",
                    json::to_string(&v)
                );
                let request_line = self
                    .conn(name)?
                    .pending
                    .pop_front()
                    .unwrap_or_default();
                self.replies.push(Reply {
                    conn: name.clone(),
                    request_line,
                    value: v,
                });
            }
            Op::ExpectCode { conn: name, code } => {
                let v = self.read_reply(name)?;
                let got = v.get("code").and_then(Value::as_str).unwrap_or("");
                anyhow::ensure!(
                    got == code,
                    "expected code `{code}` on `{name}`, got {}",
                    json::to_string(&v)
                );
                self.conn(name)?.pending.pop_front();
            }
            Op::ExpectId { conn: name, id } => {
                let v = self.read_reply_for_id(name, *id)?;
                anyhow::ensure!(
                    v.get("error").is_none(),
                    "expected a completion for id {id} on `{name}`, got {}",
                    json::to_string(&v)
                );
                let request_line = self.take_request_for_id(name, *id)?;
                self.replies.push(Reply {
                    conn: name.clone(),
                    request_line,
                    value: v,
                });
            }
            Op::ExpectIdCode { conn: name, id, code } => {
                let v = self.read_reply_for_id(name, *id)?;
                let got = v.get("code").and_then(Value::as_str).unwrap_or("");
                anyhow::ensure!(
                    got == code,
                    "expected code `{code}` for id {id} on `{name}`, got {}",
                    json::to_string(&v)
                );
                self.take_request_for_id(name, *id)?;
            }
            Op::ExpectClosed(name) => {
                let conn = self.conn(name)?;
                let mut line = String::new();
                match conn.reader.read_line(&mut line) {
                    Ok(0) => {}
                    // the server closing with unread client bytes in its
                    // receive buffer surfaces as a reset, not clean EOF
                    Err(e) if matches!(
                        e.kind(),
                        ErrorKind::ConnectionReset | ErrorKind::ConnectionAborted | ErrorKind::BrokenPipe
                    ) => {}
                    Ok(_) => bail!("`{name}` still open: got line {line:?}"),
                    Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                        bail!("`{name}` still open after {:?}", self.timeout)
                    }
                    Err(e) => return Err(e.into()),
                }
                self.conns.remove(name);
            }
            Op::SendRaw { conn, bytes } => self.write_raw(conn, bytes)?,
            Op::SendRawRepeat { conn, byte, count } => {
                let chunk = vec![*byte; *count];
                self.write_raw(conn, &chunk)?;
            }
            Op::Slowloris(name) => self.write_raw(name, b"{")?,
            Op::Disconnect(name) => {
                self.conns
                    .remove(name)
                    .ok_or_else(|| anyhow!("connection `{name}` is not open"))?;
            }
            Op::KillShard(i) => {
                anyhow::ensure!(
                    self.fleet.kill_shard(*i),
                    "kill-shard {i}: no such shard or already dead"
                );
            }
            Op::Fault(spec) => {
                let plan = self.fleet.fault_plan().ok_or_else(|| {
                    anyhow!(
                        "no fault plan installed — the fleet was launched without \
                         FaultyBackend wrapping (serve wires it unconditionally)"
                    )
                })?;
                if spec == "clear" {
                    plan.clear();
                } else {
                    plan.arm(
                        crate::chaos::fault::FaultSpec::parse(spec)
                            .map_err(|e| anyhow!("bad fault spec: {e}"))?,
                    );
                }
            }
            Op::WaitRespawn { shard, timeout_ms } => {
                let deadline = std::time::Instant::now() + Duration::from_millis(*timeout_ms);
                while !self.fleet.shard_alive(*shard) {
                    anyhow::ensure!(
                        std::time::Instant::now() < deadline,
                        "shard {shard} not respawned within {timeout_ms}ms \
                         (is --shard-respawn on?)"
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
            Op::Drain => {
                self.fleet.drain();
            }
            Op::Sleep(ms) => std::thread::sleep(Duration::from_millis(*ms)),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_op_set() {
        let script = r#"
            # a comment
            connect a
            send a {"prompt": "red circle", "steps": 8}
            expect-ok a
            expect-code a queue_full
            send-raw a not json\n
            send-raw-repeat a 61 8192
            slowloris a
            expect-closed a
            disconnect a
            kill-shard 1
            fault error-every=3,stall-at=2:50
            fault clear
            wait-respawn 1 2000
            drain
            sleep 25
            expect-id b 3
            expect-id-code b 4 canceled
        "#;
        let ops = parse_script(script).unwrap();
        assert_eq!(ops.len(), 17);
        assert_eq!(ops[0], Op::Connect("a".into()));
        let Op::Send { conn, line } = &ops[1] else { panic!("{:?}", ops[1]) };
        assert_eq!(conn, "a");
        assert_eq!(line, r#"{"prompt": "red circle", "steps": 8}"#);
        assert_eq!(ops[3], Op::ExpectCode { conn: "a".into(), code: "queue_full".into() });
        let Op::SendRaw { bytes, .. } = &ops[4] else { panic!() };
        assert_eq!(bytes, b"not json\n");
        assert_eq!(
            ops[5],
            Op::SendRawRepeat { conn: "a".into(), byte: 0x61, count: 8192 }
        );
        assert_eq!(ops[9], Op::KillShard(1));
        assert_eq!(ops[10], Op::Fault("error-every=3,stall-at=2:50".into()));
        assert_eq!(ops[11], Op::Fault("clear".into()));
        assert_eq!(ops[12], Op::WaitRespawn { shard: 1, timeout_ms: 2000 });
        assert_eq!(ops[13], Op::Drain);
        assert_eq!(ops[14], Op::Sleep(25));
        assert_eq!(ops[15], Op::ExpectId { conn: "b".into(), id: 3 });
        assert_eq!(
            ops[16],
            Op::ExpectIdCode { conn: "b".into(), id: 4, code: "canceled".into() }
        );
    }

    #[test]
    fn escapes_decode_and_bad_ones_fail() {
        assert_eq!(unescape(r"a\nb\t\\\xff").unwrap(), b"a\nb\t\\\xff");
        assert_eq!(unescape(r"\x00\x7b").unwrap(), vec![0u8, 0x7b]);
        assert!(unescape(r"\q").is_err());
        assert!(unescape(r"\x2").is_err());
        assert!(unescape(r"\xzz").is_err());
    }

    #[test]
    fn parse_errors_name_the_line() {
        let err = parse_script("connect a\nwarp b\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        assert!(err.to_string().contains("warp"), "{err}");
        let err = parse_script("send a\n").unwrap_err();
        assert!(err.to_string().contains("payload"), "{err}");
        let err = parse_script("kill-shard x\n").unwrap_err();
        assert!(err.to_string().contains("shard index"), "{err}");
        let err = parse_script("drain now\n").unwrap_err();
        assert!(err.to_string().contains("no arguments"), "{err}");
        let err = parse_script("connect a b\n").unwrap_err();
        assert!(err.to_string().contains("exactly one"), "{err}");
        // fault specs are validated at parse time, naming the line
        let err = parse_script("fault error-every=x\n").unwrap_err();
        assert!(err.to_string().contains("bad fault spec"), "{err}");
        assert!(err.to_string().contains("line 1"), "{err}");
        let err = parse_script("wait-respawn 0\n").unwrap_err();
        assert!(err.to_string().contains("timeout-ms"), "{err}");
        let err = parse_script("expect-id a\n").unwrap_err();
        assert!(err.to_string().contains("conn id"), "{err}");
        let err = parse_script("expect-id-code a 3\n").unwrap_err();
        assert!(err.to_string().contains("conn id code"), "{err}");
        let err = parse_script("expect-id a x\n").unwrap_err();
        assert!(err.to_string().contains("bad wire id"), "{err}");
    }
}
