//! Trace capture + the completion digest (§Robustness).
//!
//! `agd serve --trace-out FILE` appends one JSONL record per *admitted*
//! request — the capture hook lives in `server::dispatch_line` and fires
//! only when the fleet answered with a completion, so a trace is a record
//! of work the server actually did, replayable as-is:
//!
//! ```text
//! {"offset_us": 18234, "client_id": "web-1",
//!  "digest": "9f1c0d2a33b41e07",
//!  "envelope": {"prompt": "red circle", "policy": "cfg", "steps": 8,
//!               "guidance": 2.0, "seed": 7, "image": true,
//!               "client_id": "web-1"}}
//! ```
//!
//! * `offset_us` — arrival offset in microseconds from the sink's epoch
//!   (the instant the sink was created, i.e. server start). Replay
//!   re-issues requests on this clock, scaled by `--speed`.
//! * `envelope` — the client's request object verbatim (already parsed
//!   once by the serving path; re-serialized canonically).
//! * `digest` — FNV-1a 64 over the completion's image bits + NFE counts
//!   ([`completion_digest`]). Because the mini-JSON writer round-trips
//!   every `f32` exactly through `f64`, the same digest is computable
//!   from a *reply line* on the client side ([`reply_digest`]) — that is
//!   what lets `agd replay` assert byte-identical completions over the
//!   wire. Replies without an `"image"` field (the envelope didn't ask
//!   for one) cannot be digest-checked and count as unverified.
//!
//! The sink serializes appends behind a mutex and flushes per record, so
//! a crashed (or chaos-killed) server still leaves a complete prefix.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::request::Completion;
use crate::util::json::{self, Value};

/// One captured request: arrival offset, the request envelope verbatim,
/// and the completion digest the replayer will check against.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub offset_us: u64,
    pub client_id: Option<String>,
    /// Completion digest ([`completion_digest`]); absent in hand-written
    /// traces, which replay without verification.
    pub digest: Option<String>,
    /// The request object to re-issue (serialized form of `envelope`).
    pub envelope: Value,
}

impl TraceRecord {
    /// The protocol line this record re-issues on replay.
    pub fn request_line(&self) -> String {
        json::to_string(&self.envelope)
    }

    /// Whether the envelope asks for the image — only those replies carry
    /// enough bytes to digest-check.
    pub fn wants_image(&self) -> bool {
        self.envelope
            .get("image")
            .and_then(Value::as_bool)
            .unwrap_or(false)
    }
}

/// Append-only JSONL trace writer (`--trace-out`). Shared across
/// connection-handler threads behind an `Arc`.
pub struct TraceSink {
    epoch: Instant,
    out: Mutex<BufWriter<File>>,
}

impl TraceSink {
    /// Open `path` for appending (created if missing); the epoch for
    /// `offset_us` is now.
    pub fn create(path: &str) -> Result<TraceSink> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening --trace-out {path}"))?;
        Ok(TraceSink {
            epoch: Instant::now(),
            out: Mutex::new(BufWriter::new(file)),
        })
    }

    /// Microseconds since the sink's epoch — sampled at request arrival,
    /// *before* the fleet runs it, so replay reproduces arrival spacing
    /// rather than completion spacing.
    pub fn arrival_offset_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Append one record. IO errors are logged, not propagated — tracing
    /// must never fail a request that already completed.
    pub fn record(
        &self,
        offset_us: u64,
        envelope: &Value,
        client_id: Option<&str>,
        digest: &str,
    ) {
        let rec = json::obj(vec![
            ("offset_us", json::num(offset_us as f64)),
            (
                "client_id",
                client_id.map(json::s).unwrap_or(Value::Null),
            ),
            ("digest", json::s(digest)),
            ("envelope", envelope.clone()),
        ]);
        let line = json::to_string(&rec);
        let mut out = self.out.lock().expect("trace sink lock");
        if writeln!(out, "{line}").and_then(|()| out.flush()).is_err() {
            log::warn!("trace sink: append failed (record dropped)");
        }
    }
}

/// Read a JSONL trace, sorted by `offset_us` (stable, so equal offsets
/// keep file order). Blank lines are skipped; a malformed line is an
/// error naming its line number.
pub fn read_trace(path: &str) -> Result<Vec<TraceRecord>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let mut records = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| anyhow!("{path}:{}: bad trace record: {e}", idx + 1))?;
        let envelope = v
            .get("envelope")
            .cloned()
            .ok_or_else(|| anyhow!("{path}:{}: trace record has no `envelope`", idx + 1))?;
        records.push(TraceRecord {
            offset_us: v.get("offset_us").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            client_id: v
                .get("client_id")
                .and_then(Value::as_str)
                .map(str::to_owned),
            digest: v.get("digest").and_then(Value::as_str).map(str::to_owned),
            envelope,
        });
    }
    records.sort_by_key(|r| r.offset_us);
    Ok(records)
}

/// FNV-1a 64 over the bytes that define a completion's identity: every
/// image `f32`'s bit pattern, then `nfes`, `cfg_steps`, and
/// `truncated_at` (`u64::MAX` encodes `None`). Policy *display* names are
/// deliberately excluded — they echo formatting, not math.
pub fn digest_parts(
    image: &[f32],
    nfes: usize,
    cfg_steps: usize,
    truncated_at: Option<usize>,
) -> String {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    };
    for &px in image {
        eat(&px.to_bits().to_le_bytes());
    }
    eat(&(nfes as u64).to_le_bytes());
    eat(&(cfg_steps as u64).to_le_bytes());
    eat(
        &truncated_at
            .map(|t| t as u64)
            .unwrap_or(u64::MAX)
            .to_le_bytes(),
    );
    format!("{h:016x}")
}

/// Digest of a server-side [`Completion`].
pub fn completion_digest(c: &Completion) -> String {
    digest_parts(&c.image, c.nfes, c.cfg_steps, c.truncated_at)
}

/// Digest of a *reply line* as a client sees it — `None` unless the reply
/// carries an image (f64 → f32 narrowing is exact here: every value was
/// an f32 on the server, and the JSON writer round-trips it losslessly).
pub fn reply_digest(v: &Value) -> Option<String> {
    let image: Vec<f32> = v
        .get("image")?
        .as_f64_vec()?
        .into_iter()
        .map(|f| f as f32)
        .collect();
    let nfes = v.get("nfes").and_then(Value::as_usize)?;
    let cfg_steps = v.get("cfg_steps").and_then(Value::as_usize)?;
    let truncated_at = v.get("truncated_at").and_then(Value::as_usize);
    Some(digest_parts(&image, nfes, cfg_steps, truncated_at))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn completion(image: Vec<f32>) -> Completion {
        Completion {
            id: 1,
            policy: "cfg(s=2)".into(),
            image,
            nfes: 16,
            cfg_steps: 8,
            truncated_at: None,
            gammas: vec![],
            gammas_eps: vec![],
            trajectory: None,
            iterates: vec![],
            timeline: None,
        }
    }

    #[test]
    fn digest_matches_between_completion_and_reply_line() {
        // awkward floats included: the JSON round trip must not move them
        let c = completion(vec![0.1, -3.5e-8, 1.0 / 3.0, f32::MIN_POSITIVE]);
        let line = crate::server::completion_to_line(&c, 1.0, true);
        let v = json::parse(&line).unwrap();
        assert_eq!(reply_digest(&v).unwrap(), completion_digest(&c));
    }

    #[test]
    fn digest_is_sensitive_to_every_part() {
        let base = completion(vec![0.5, -0.25]);
        let d0 = completion_digest(&base);
        let mut c = completion(vec![0.5, -0.250001]);
        assert_ne!(completion_digest(&c), d0, "image bits");
        c = completion(vec![0.5, -0.25]);
        c.nfes = 17;
        assert_ne!(completion_digest(&c), d0, "nfes");
        c.nfes = 16;
        c.truncated_at = Some(3);
        assert_ne!(completion_digest(&c), d0, "truncated_at");
        // and stable across calls
        assert_eq!(completion_digest(&base), d0);
    }

    #[test]
    fn reply_without_image_has_no_digest() {
        let c = completion(vec![0.5]);
        let line = crate::server::completion_to_line(&c, 1.0, false);
        assert_eq!(reply_digest(&json::parse(&line).unwrap()), None);
    }

    #[test]
    fn sink_roundtrips_through_read_trace() {
        let path = std::env::temp_dir().join(format!(
            "agd_trace_test_{}.jsonl",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_owned();
        let _ = std::fs::remove_file(&path);
        {
            let sink = TraceSink::create(&path).unwrap();
            let env1 = json::parse(
                r#"{"prompt": "red circle", "steps": 8, "image": true, "client_id": "a"}"#,
            )
            .unwrap();
            let env2 = json::parse(r#"{"prompt": "blue square", "steps": 4}"#).unwrap();
            // out-of-order offsets: read_trace must sort
            sink.record(500, &env2, None, "00000000000000ff");
            sink.record(100, &env1, Some("a"), "00000000000000aa");
        }
        let recs = read_trace(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].offset_us, 100);
        assert_eq!(recs[0].client_id.as_deref(), Some("a"));
        assert_eq!(recs[0].digest.as_deref(), Some("00000000000000aa"));
        assert!(recs[0].wants_image());
        assert!(!recs[1].wants_image());
        // the request line re-parses to the original envelope
        let v = json::parse(&recs[0].request_line()).unwrap();
        assert_eq!(v.req("prompt").as_str(), Some("red circle"));
        // appending more records accumulates (append mode)
        {
            let sink = TraceSink::create(&path).unwrap();
            let env = json::parse(r#"{"prompt": "red cross"}"#).unwrap();
            sink.record(50, &env, None, "0000000000000001");
        }
        assert_eq!(read_trace(&path).unwrap().len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn read_trace_rejects_malformed_lines() {
        let path = std::env::temp_dir().join(format!(
            "agd_trace_bad_{}.jsonl",
            std::process::id()
        ));
        std::fs::write(&path, "{\"offset_us\": 1}\n").unwrap();
        let err = read_trace(path.to_str().unwrap()).unwrap_err();
        assert!(err.to_string().contains("no `envelope`"), "{err}");
        std::fs::write(&path, "not json\n").unwrap();
        assert!(read_trace(path.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
