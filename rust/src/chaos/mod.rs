//! §Robustness: trace-driven load replay + scripted chaos harness.
//!
//! Every §Perf and §Scale claim in this repo is pinned by golden-sampler
//! equivalence, but those proofs run in-process or in virtual time. This
//! module is the correctness backstop for the *real* socket path: it
//! records what a live server actually served, replays it against
//! another server at adjustable speed, and injects scripted faults —
//! shard crashes, client disconnects, slowloris writers, malformed
//! frames, drains under load — asserting that survivors stay
//! byte-identical and failures shed with structured codes.
//!
//! Three std-only layers (like [`crate::exec`] and [`crate::fleet`]):
//!
//! * [`trace`] — capture (`agd serve --trace-out FILE` appends one JSONL
//!   record per admitted request: arrival offset, envelope, client id,
//!   completion digest) and the FNV-1a completion digest computable on
//!   both ends of the wire.
//! * [`replay`] — `agd replay --trace FILE --speed X --connections N`:
//!   open-loop re-issue over real TCP, recording wire-latency
//!   p50/p95/p99, shed codes, and digest matches into
//!   `BENCH_replay.json` ([`crate::perfstat`]).
//! * [`director`] — `scenarios/*.txt` fault scripts interpreted against
//!   a live listener + [`crate::fleet::Fleet`]
//!   (`rust/tests/chaos_integration.rs` runs the corpus; see the
//!   scenario grammar in [`director`]'s docs).
//!
//! The invariant under test is the fleet one restated under failure:
//! **faults change who gets served, never what a survivor is served.**
//! A kill-shard, a dropped client, or a drain may shed requests (with
//! `shard_failed` / `draining` / `queue_full` codes), but every
//! completion that does arrive is byte-identical to a clean
//! single-shard run — placement, crashes and load never leak into the
//! math.

pub mod director;
pub mod replay;
pub mod trace;

pub use director::{parse_script, Director, Op, Reply};
pub use replay::{replay, ReplayConfig, ReplayOutcome};
pub use trace::{completion_digest, read_trace, reply_digest, TraceRecord, TraceSink};
