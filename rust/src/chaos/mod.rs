//! §Robustness: trace-driven load replay + scripted chaos harness.
//!
//! Every §Perf and §Scale claim in this repo is pinned by golden-sampler
//! equivalence, but those proofs run in-process or in virtual time. This
//! module is the correctness backstop for the *real* socket path: it
//! records what a live server actually served, replays it against
//! another server at adjustable speed, and injects scripted faults —
//! shard crashes, backend failure storms, client disconnects, slowloris
//! writers, malformed frames, drains under load — asserting that
//! survivors stay byte-identical and failures shed with structured codes.
//!
//! Four std-only layers (like [`crate::exec`] and [`crate::fleet`]):
//!
//! * [`trace`] — capture (`agd serve --trace-out FILE` appends one JSONL
//!   record per admitted request: arrival offset, envelope, client id,
//!   completion digest) and the FNV-1a completion digest computable on
//!   both ends of the wire.
//! * [`replay`] — `agd replay --trace FILE --speed X --connections N`:
//!   open-loop re-issue over real TCP, recording wire-latency
//!   p50/p95/p99, shed codes, digest matches, and the fleet's survival
//!   counters (retries/salvages/respawns) into `BENCH_replay.json`
//!   ([`crate::perfstat`]).
//! * [`fault`] — [`FaultyBackend`]: scheduled fault injection *inside*
//!   the compute path (transient errors, stalls, permanent failure),
//!   armed by `agd serve --fault-spec` or the director's `fault` op,
//!   plus the typed transient/fatal error classes and the seeded
//!   [`JitterBackoff`] behind the engine's bounded batch retry.
//! * [`director`] — `scenarios/*.txt` fault scripts interpreted against
//!   a live listener + [`crate::fleet::Fleet`]
//!   (`rust/tests/chaos_integration.rs` runs the corpus; see the
//!   scenario grammar in [`director`]'s docs).
//!
//! The invariant under test is the fleet one restated under failure:
//! **faults change who gets served — and when, and on which shard —
//! never what a survivor is served.** A kill-shard, a dropped client, or
//! a drain may shed requests (with `shard_failed` / `draining` /
//! `queue_full` codes), but every completion that does arrive is
//! byte-identical to a clean single-shard run — placement, crashes,
//! retries, salvage and load never leak into the math. The survival
//! layer (engine retry, fleet salvage + respawn — `docs/ROBUSTNESS.md`)
//! strengthens the shedding half: faults the fleet can absorb produce
//! *completions*, not codes, and those completions are still
//! byte-identical to a fault-free run.

pub mod director;
pub mod fault;
pub mod replay;
pub mod trace;

pub use director::{parse_script, Director, Op, Reply};
pub use fault::{classify, BackendFault, FaultClass, FaultPlan, FaultSpec, FaultyBackend, JitterBackoff};
pub use replay::{fetch_survival, replay, ReplayConfig, ReplayOutcome, SurvivalCounters};
pub use trace::{completion_digest, read_trace, reply_digest, TraceRecord, TraceSink};
