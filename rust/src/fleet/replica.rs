//! The shard engine thread: one engine replica pumping its own queue.
//!
//! Each replica owns a full engine stack — backend instance, scheduler,
//! admission budget, worker pool, buffer pool — constructed *inside* the
//! thread (the PJRT client is thread-affine). The loop is the fleet's
//! generalization of the old single-engine server loop: admit jobs, pump,
//! reply per request; plus the shard-side fleet duties:
//!
//! * **load publication** — after every message and pump the thread
//!   publishes [`Engine::load`] into the shared [`ShardLoad`], and settles
//!   the router's placement reservation when it picks a job up;
//! * **deadline-infeasible shedding** (`--shed-infeasible`) — a tracked
//!   per-NFE service rate ([`ServiceRate`], EWMA-free cumulative
//!   micros/NFE) prices the queued backlog; a request whose `deadline_ms`
//!   cannot cover it is refused with `deadline_infeasible` and counted in
//!   `deadline_shed_total{policy=}`;
//! * **drain** — a [`ShardMsg::Drain`] waiter is acknowledged as soon as
//!   the engine is idle (all admitted work completed, nothing dropped);
//! * **shutdown** — [`ShardMsg::Shutdown`] lets the loop return at the
//!   next idle point, which is what makes fleet threads joinable.
//!
//! A fatal pump error (a backend failure the engine's bounded retry
//! could not absorb — see [`Engine::set_batch_retries`]) runs the death
//! path ([`die`]): never-started jobs are salvaged back out of the
//! engine and handed to the fleet supervisor for re-placement, the death
//! line is logged (so an operator sees why even if nothing scrapes
//! metrics again), every truly mid-flight job is refused with
//! `"code": "shard_failed"` ([`ShardFailed`]), and the shard is marked
//! dead in its [`ShardLoad`] (the router stops placing onto it; the
//! death ticks the load's persistent ledger behind
//! `shard_died_total{shard=}`) before the thread exits — the rest of the
//! fleet keeps serving, and with `--shard-respawn` the supervisor brings
//! this shard back. The chaos harness's [`ShardMsg::Crash`] injection
//! (`Fleet::kill_shard`, driven by [`crate::chaos`]) exercises the
//! *same* path between batch steps, which is what finally runs this code
//! instead of only reading it.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::Backend;
use crate::coordinator::checkpoint::RequestCheckpoint;
use crate::coordinator::engine::{Engine, ProgressNote};
use crate::coordinator::request::{Completion, Request};
use crate::fleet::router::ShardLoad;
use crate::fleet::{ScopedShed, ShardFailed, SuperMsg};
use crate::sched::{AdmitError, Telemetry};
use crate::server::error_to_line;
use crate::util::logev::log_event;

/// Where a job's replies land. The threaded front end blocks on a plain
/// mpsc channel per request; the reactor registers a wakeup target so one
/// poll thread can multiplex thousands of connections without a blocked
/// receiver each. Both paths carry the same typed [`JobReply`]s.
#[derive(Clone)]
pub enum ReplyTo {
    /// Classic per-request channel (threaded server, fleet tests).
    Channel(Sender<JobReply>),
    /// §Scale: push-and-wake sink owned by a reactor connection.
    Target(Arc<dyn ReplyTarget>),
}

impl ReplyTo {
    /// Deliver one reply; a gone receiver is ignored (disconnected client).
    pub fn send(&self, reply: JobReply) {
        match self {
            ReplyTo::Channel(tx) => {
                let _ = tx.send(reply);
            }
            ReplyTo::Target(t) => t.deliver(reply),
        }
    }
}

/// A reply sink that front-ends implement to receive shard-thread pushes:
/// enqueue the reply somewhere bounded and wake the owning event loop.
/// Implementations must never block the shard thread.
pub trait ReplyTarget: Send + Sync {
    fn deliver(&self, reply: JobReply);
}

/// A placed request travelling router → shard thread.
pub struct Job {
    pub req: Request,
    /// Worst-case NFE cost the router reserved (settled on pickup).
    pub cost: usize,
    /// Arrival instant at the front door (latency is measured from here,
    /// like the single-engine server did).
    pub started: Instant,
    pub reply: ReplyTo,
    /// §Robustness: mid-flight snapshot salvaged off a dead shard
    /// (`--checkpoint-steps`). `Some` routes the job through
    /// [`Engine::try_resume`] on the receiving shard instead of a fresh
    /// submit, so the trajectory re-enters at the recorded step.
    pub checkpoint: Option<Box<RequestCheckpoint>>,
}

/// What a shard sends back on a job's reply channel. Completions stay
/// typed (not pre-rendered lines) so embedders — the fleet integration
/// tests, future front-ends — get bit-exact images; the server renders
/// the protocol line connection-side where `want_image` is known.
pub enum JobReply {
    /// The request completed after `ms` milliseconds in the fleet.
    Done(Box<Completion>, f64),
    /// The request was refused or failed; the payload is the protocol
    /// error line.
    Error(String),
    /// A per-step progress sample for an opted-in (`"progress": true`)
    /// request — zero or more of these precede the terminal
    /// `Done`/`Error`. Receivers that cannot stream (the threaded
    /// front end's blocking recv loop) simply skip them.
    Progress(ProgressNote),
}

/// One shard's stats snapshot for `{"cmd": "stats"}` aggregation.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    pub scheduler: &'static str,
    pub active: usize,
    pub queue_depth: usize,
    pub queued_nfes: usize,
    pub batches: usize,
    pub items: usize,
    pub mean_occupancy: f64,
    /// §Observability: span-ring events overwritten before being drained
    /// (monotonic — surfaced per shard in `{"cmd": "stats"}`).
    pub spans_dropped: u64,
    pub telemetry: Telemetry,
}

/// What the fleet sends to a shard thread.
pub(crate) enum ShardMsg {
    Job(Job),
    /// Reply with the shard's stats snapshot (stats/metrics aggregation).
    Stats(Sender<ShardStats>),
    /// §Observability: drain the shard's span ring (`{"cmd": "spans"}`).
    Spans(Sender<crate::trace::SpanBatch>),
    /// Acknowledge once the engine is idle (nothing queued or executing).
    Drain(Sender<()>),
    /// Wire-level cancellation: pull the identified request back out of
    /// the engine ([`Engine::cancel`]) and answer its pending reply with
    /// the structured `canceled` line. Unknown/already-completed ids are
    /// ignored — the shard channel is FIFO, so a job always precedes its
    /// own cancel, and a miss means the completion already won the race.
    Cancel(u64),
    /// Finish in-flight work, then exit the thread.
    Shutdown,
    /// Chaos injection ([`crate::fleet::Fleet::kill_shard`]): run the
    /// fatal death path as if the engine pump had failed — between batch
    /// steps, so a mid-flight kill leaves work genuinely in flight.
    Crash,
}

/// Cumulative observed service rate: wall micros per executed NFE. Fed by
/// every pump; prices the backlog for `--shed-infeasible`. Cumulative
/// (not windowed) keeps it allocation-free and monotone-stable; the GMM
/// oracle and a warmed PJRT artifact both have near-constant per-NFE cost.
#[derive(Debug, Default)]
pub struct ServiceRate {
    nfes: u64,
    micros: u64,
}

impl ServiceRate {
    pub fn observe(&mut self, items: usize, elapsed: Duration) {
        self.nfes += items as u64;
        self.micros += elapsed.as_micros() as u64;
    }

    /// Milliseconds per NFE — `None` until at least one timed NFE exists.
    pub fn per_nfe_ms(&self) -> Option<f64> {
        if self.nfes == 0 || self.micros == 0 {
            return None;
        }
        Some(self.micros as f64 / self.nfes as f64 / 1000.0)
    }
}

/// Per-admitted-job bookkeeping on the shard thread.
struct Pending {
    started: Instant,
    reply: ReplyTo,
}

/// Run one shard's engine loop until shutdown (or a fatal error).
pub(crate) fn run_replica<B: Backend>(
    shard: usize,
    mut engine: Engine<B>,
    rx: Receiver<ShardMsg>,
    load: Arc<ShardLoad>,
    shed_infeasible: bool,
    super_tx: Sender<SuperMsg>,
) {
    // exported span batches carry this shard's id (§Observability)
    engine.set_shard(shard);
    let mut jobs: HashMap<u64, Pending> = HashMap::new();
    let mut waiters: Vec<Sender<()>> = Vec::new();
    let mut rate = ServiceRate::default();
    // reusable buffer for per-step progress notes (capacity ping-pongs
    // with the engine's own buffer; permanently empty unless a request
    // opted in)
    let mut notes: Vec<ProgressNote> = Vec::new();
    let mut shutdown = false;
    let mut crashed = false;
    loop {
        // idle: acknowledge drains, honour shutdown, block for work
        if engine.idle() && !crashed {
            for w in waiters.drain(..) {
                let _ = w.send(());
            }
            if shutdown {
                return;
            }
            match rx.recv() {
                Ok(msg) => {
                    handle_msg(
                        shard, &mut engine, &mut jobs, &mut waiters, &mut shutdown,
                        &mut crashed, &load, &rate, shed_infeasible, msg,
                    );
                }
                Err(_) => return, // fleet dropped → shut down
            }
        }
        // soak up everything already queued before pumping
        loop {
            match rx.try_recv() {
                Ok(msg) => {
                    handle_msg(
                        shard, &mut engine, &mut jobs, &mut waiters, &mut shutdown,
                        &mut crashed, &load, &rate, shed_infeasible, msg,
                    );
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if engine.idle() {
                        for w in waiters.drain(..) {
                            let _ = w.send(());
                        }
                        return;
                    }
                    break;
                }
            }
        }
        // an injected crash lands here — between batch steps, like a real
        // pump failure would, with any mid-flight work still in `jobs`
        // (the shard channel is FIFO: jobs placed before the Crash were
        // already soaked up above)
        if crashed {
            die(
                shard,
                &mut engine,
                &mut jobs,
                &load,
                &super_tx,
                "injected chaos crash (kill-shard)".into(),
            );
            return;
        }
        let t0 = Instant::now();
        let before = engine.items();
        match engine.pump() {
            Ok(completions) => {
                let executed = engine.items() - before;
                if executed > 0 {
                    rate.observe(executed, t0.elapsed());
                }
                // stream progress before this round's completions so a
                // request's final line is always the last it receives
                engine.drain_progress(&mut notes);
                for n in &notes {
                    if let Some(job) = jobs.get(&n.id) {
                        job.reply.send(JobReply::Progress(*n));
                    }
                }
                for c in completions {
                    if let Some(job) = jobs.remove(&c.id) {
                        let ms = job.started.elapsed().as_secs_f64() * 1e3;
                        job.reply.send(JobReply::Done(Box::new(c), ms));
                    }
                }
                let l = engine.load();
                load.publish(l.active, l.queued_nfes);
            }
            Err(e) => {
                die(
                    shard,
                    &mut engine,
                    &mut jobs,
                    &load,
                    &super_tx,
                    format!("engine pump failed: {e:#}"),
                );
                return;
            }
        }
    }
}

/// The shard death path, shared by real pump failures and injected
/// crashes. §Robustness ordering, deliberate:
///
/// 1. **salvage** — pull back every admitted request the engine can hand
///    to a survivor ([`Engine::salvage_all`]): never-started requests
///    restart from step 0 with the same init noise, and — with
///    `--checkpoint-steps` armed — started requests carry their latest
///    [`RequestCheckpoint`] and resume at the recorded step; either way
///    the eventual completion is byte-identical to an undisturbed run;
/// 2. **log the death line** (through [`log_event`], with the monotonic
///    event stamp) — a dead shard's registry is never scraped again, so
///    the log line is the one artifact guaranteed to survive, and it
///    carries the salvage/refusal split an operator needs first;
/// 3. **refuse** the truly mid-flight jobs with the structured
///    `shard_failed` line (its message names how many jobs were salvaged
///    instead of shed);
/// 4. **mark the load dead** — placement skips the shard,
///    `shard_died_total{shard=}` ticks its persistent ledger;
/// 5. **notify the supervisor**, handing it the salvaged jobs (re-placed
///    onto survivors) and, with `--shard-respawn`, triggering the
///    rebuild.
fn die<B: Backend>(
    shard: usize,
    engine: &mut Engine<B>,
    jobs: &mut HashMap<u64, Pending>,
    load: &ShardLoad,
    super_tx: &Sender<SuperMsg>,
    reason: String,
) {
    let mut salvaged = Vec::new();
    for s in engine.salvage_all() {
        if let Some(p) = jobs.remove(&s.req.id) {
            salvaged.push(Job {
                req: s.req,
                cost: s.cost,
                started: p.started,
                reply: p.reply,
                checkpoint: s.checkpoint,
            });
        }
    }
    let resumed = salvaged.iter().filter(|j| j.checkpoint.is_some()).count();
    let unstarted = salvaged.len() - resumed;
    let e = anyhow::Error::new(ShardFailed {
        shard,
        reason: format!(
            "{reason} ({unstarted} never-started job(s) salvaged to survivors, \
             {resumed} checkpointed job(s) resuming)"
        ),
    });
    let line = error_to_line(&e);
    log_event(
        log::Level::Error,
        &format!("shard-{shard}"),
        &format!(
            "fatal, marking dead ({} mid-flight job(s) refused, {} salvaged, {resumed} resuming): {line}",
            jobs.len(),
            salvaged.len()
        ),
    );
    for (_, job) in jobs.drain() {
        job.reply.send(JobReply::Error(line.clone()));
    }
    load.mark_dead();
    let _ = super_tx.send(SuperMsg::Died { shard, salvaged });
}

#[allow(clippy::too_many_arguments)]
fn handle_msg<B: Backend>(
    shard: usize,
    engine: &mut Engine<B>,
    jobs: &mut HashMap<u64, Pending>,
    waiters: &mut Vec<Sender<()>>,
    shutdown: &mut bool,
    crashed: &mut bool,
    load: &ShardLoad,
    rate: &ServiceRate,
    shed_infeasible: bool,
    msg: ShardMsg,
) {
    match msg {
        ShardMsg::Job(job) => admit(engine, jobs, load, rate, shed_infeasible, job),
        ShardMsg::Stats(reply) => {
            let l = engine.load();
            let _ = reply.send(ShardStats {
                shard,
                scheduler: engine.scheduler_name(),
                active: l.active,
                queue_depth: l.queue_depth,
                queued_nfes: l.queued_nfes,
                batches: engine.batches(),
                items: engine.items(),
                mean_occupancy: engine.mean_occupancy(),
                spans_dropped: engine.spans_dropped(),
                telemetry: engine.telemetry().clone(),
            });
        }
        ShardMsg::Spans(reply) => {
            let _ = reply.send(engine.drain_spans());
        }
        ShardMsg::Drain(reply) => {
            if engine.idle() {
                let _ = reply.send(());
            } else {
                waiters.push(reply);
            }
        }
        ShardMsg::Cancel(id) => {
            // safe between pumps: the replica thread only handles messages
            // when no batch is executing, so the engine can tear the
            // request down without racing a delivery
            if engine.cancel(id) {
                if let Some(p) = jobs.remove(&id) {
                    let e = anyhow::Error::new(crate::fleet::Canceled { id });
                    p.reply.send(JobReply::Error(error_to_line(&e)));
                }
                let l = engine.load();
                load.publish(l.active, l.queued_nfes);
            }
        }
        ShardMsg::Shutdown => *shutdown = true,
        ShardMsg::Crash => *crashed = true,
    }
}

/// Shard-side admission: the deadline-feasibility gate, then the engine's
/// own validation + per-shard budgets. A refusal replies immediately and
/// never touches the queue; either way the router's reservation settles.
fn admit<B: Backend>(
    engine: &mut Engine<B>,
    jobs: &mut HashMap<u64, Pending>,
    load: &ShardLoad,
    rate: &ServiceRate,
    shed_infeasible: bool,
    job: Job,
) {
    let Job {
        mut req,
        cost,
        started,
        reply,
        checkpoint,
    } = job;
    // §Observability: the queue stage — front-door arrival to engine
    // admission, minus the admission/placement time the router already
    // stamped (the engine reconstructs monotonic start times from these)
    if req.trace {
        let total_us = started.elapsed().as_micros() as u64;
        req.span_queue_us = total_us
            .saturating_sub(req.span_admission_us)
            .saturating_sub(req.span_placement_us);
    }
    // deadline-aware shedding: refuse work that cannot finish in time
    // given this shard's backlog and observed service rate. Skipped until
    // a rate exists — the first requests after a cold start must land.
    // The estimate prices a FIFO drain of the whole backlog: a
    // *worst-case* bound. Under the deadline/cost-aware schedulers a
    // tight-deadline request may actually run far sooner than the bound
    // says, so on deep queues this gate over-sheds urgent work — pair
    // `--shed-infeasible` with fifo (its honest regime), or accept that
    // it trades false rejections for never burning NFEs on a reply that
    // would arrive late.
    if shed_infeasible {
        if let (Some(deadline), Some(per_nfe_ms)) = (req.deadline_ms, rate.per_nfe_ms()) {
            let backlog = engine.queued_nfes() + cost;
            let estimated = per_nfe_ms * backlog as f64;
            if (deadline as f64) < estimated {
                let policy = req.policy.kind();
                engine
                    .telemetry_mut()
                    .inc("deadline_shed_total", &[("policy", policy.as_str())], 1);
                let e = anyhow::Error::new(AdmitError::DeadlineInfeasible {
                    deadline_ms: deadline,
                    estimated_ms: estimated.ceil() as u64,
                    queued_nfes: backlog,
                });
                reply.send(JobReply::Error(error_to_line(&e)));
                load.settle(cost);
                return;
            }
        }
    }
    let id = req.id;
    // §Robustness: a salvaged checkpoint re-enters mid-trajectory through
    // the resume path; everything else is a fresh submit. Error handling
    // is identical — a resume that no longer fits is refused like any
    // malformed request.
    let admitted = match &checkpoint {
        Some(ck) => engine.try_resume(req, ck),
        None => engine.try_submit(req),
    };
    match admitted {
        Ok(()) => {
            jobs.insert(id, Pending { started, reply });
        }
        Err(e @ AdmitError::Invalid { .. }) => {
            // malformed, not over-budget: no shed scope on the line
            reply.send(JobReply::Error(error_to_line(&anyhow::Error::new(e))));
        }
        Err(e) => {
            let scoped = ScopedShed {
                scope: "shard",
                inner: e,
            };
            reply.send(JobReply::Error(error_to_line(&anyhow::Error::new(scoped))));
        }
    }
    load.settle(cost);
    let l = engine.load();
    load.publish(l.active, l.queued_nfes);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_rate_prices_backlog() {
        let mut r = ServiceRate::default();
        assert_eq!(r.per_nfe_ms(), None, "cold start must not shed");
        r.observe(0, Duration::from_micros(500));
        assert_eq!(r.per_nfe_ms(), None, "command-only pumps carry no NFEs");
        r.observe(10, Duration::from_millis(20));
        let per = r.per_nfe_ms().unwrap();
        assert!((per - 2.05).abs() < 0.01, "{per}"); // 20.5ms / 10 NFEs
        // cumulative: more observations refine, never reset
        r.observe(10, Duration::from_millis(20));
        let per2 = r.per_nfe_ms().unwrap();
        assert!(per2 < per && per2 > 1.9, "{per2}");
    }
}
